"""CI perf-regression gate — fresh smoke ratios vs committed baselines.

The perf-smoke job reruns every benchmark at ``--small`` size, which
overwrites the ``BENCH_*.json`` files in the workspace. This script
compares the *headline speedup ratios* of those fresh files against the
versions committed at HEAD (via ``git show``): absolute cycle counts
and wall times scale with trace length and machine, but the fast-vs-
oracle ratios are size-insensitive enough to gate on. A fresh ratio
below ``TOLERANCE`` (default 0.7) times its committed value fails the
build — that is a real engine regression, not smoke-size noise.

Keys whose ratios are noise-bound at parity (e.g. the serving load
sweep, which is simulation-bound by design) are deliberately not
gated; the table below is the single source of truth for what is.

Usage::

    PYTHONPATH=src python scripts/check_perf_regressions.py [--ref HEAD]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
TOLERANCE = 0.7

# bench file -> dotted paths of the gated headline ratios
GATED: dict[str, list[str]] = {
    "BENCH_trace_engine.json": [
        "workloads.gcn_style.pipeline.speedup",
        "workloads.cnn_style.pipeline.speedup",
        "workloads.gcn_style.hit_rate_oracle.speedup",
        "workloads.cnn_style.hit_rate_oracle.speedup",
    ],
    "BENCH_dram_sched.json": [
        "fast_path_speedup_vs_oracle_w32",
    ],
    "BENCH_serving.json": [
        "simulator.speedup",
    ],
    "BENCH_autotune.json": [
        "headline_speedup_batched_vs_oracle",
    ],
    # Model-trace zoo acceptance gates (PR 10): geometry_differs is 1/0
    # and configs_covered_frac is a fraction of the 10 registry archs —
    # both must hold at --small size (the ratio floor catches any drop).
    "BENCH_model_traces.json": [
        "gate.geometry_differs",
        "gate.configs_covered_frac",
    ],
}


def _dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def _committed(name: str, ref: str) -> dict | None:
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:{name}"], cwd=REPO, check=True,
            capture_output=True, text=True).stdout
    except subprocess.CalledProcessError:
        return None
    return json.loads(blob)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the committed baselines")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args()

    failures, checked = [], 0
    for name, paths in GATED.items():
        fresh_path = REPO / name
        if not fresh_path.exists():
            failures.append(f"{name}: fresh file missing — did the "
                            "smoke step run?")
            continue
        base = _committed(name, args.ref)
        if base is None:
            print(f"  {name}: no committed baseline at {args.ref} — "
                  "skipping (first PR for this benchmark)")
            continue
        fresh = json.loads(fresh_path.read_text())
        for path in paths:
            want, got = _dig(base, path), _dig(fresh, path)
            if want is None:
                print(f"  {name}:{path}: not in baseline — skipping")
                continue
            checked += 1
            if got is None:
                failures.append(f"{name}:{path}: present in baseline "
                                "but missing from fresh run")
                continue
            floor = args.tolerance * float(want)
            status = "ok" if float(got) >= floor else "FAIL"
            print(f"  {name}:{path}: fresh {got} vs committed {want} "
                  f"(floor {floor:.2f}) {status}")
            if status == "FAIL":
                failures.append(
                    f"{name}:{path}: {got} < {args.tolerance} x {want}")

    if failures:
        print(f"\nperf gate: {len(failures)} regression(s):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nperf gate: {checked} headline ratio(s) within "
          f"{args.tolerance}x of committed baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
