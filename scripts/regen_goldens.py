"""Regenerate the golden pipeline snapshots in ``tests/goldens/``.

Run from the repo root after an *intentional* model change:

    PYTHONPATH=src:tests/core python scripts/regen_goldens.py

then review the JSON diffs — every changed number is a modeled-behavior
change the PR must be able to explain. The case definitions live in
``tests/core/golden_cases.py`` (shared with the checking test, so the
writer and the checker can never disagree).
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "tests", "core"))

from golden_cases import (CASES, GOLDEN_DIR, SERVING_CASES,  # noqa: E402
                          golden_record)


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name in list(CASES) + list(SERVING_CASES):
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        record = golden_record(name)
        with open(path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}  (makespan={record['makespan_fpga_cycles']:.1f})")


if __name__ == "__main__":
    main()
