"""Regenerate the golden pipeline snapshots in ``tests/goldens/``.

Run from the repo root after an *intentional* model change:

    PYTHONPATH=src:tests/core python scripts/regen_goldens.py

then review the JSON diffs — every changed number is a modeled-behavior
change the PR must be able to explain. The case definitions live in
``tests/core/golden_cases.py`` (shared with the checking test, so the
writer and the checker can never disagree).

``--traces`` additionally *recaptures* the pinned per-family model
traces in ``tests/goldens/traces/`` from the live models
(``repro.data.model_traces``) before re-snapshotting their records.
Without the flag, the existing trace files are kept and only the
simulate() records are recomputed — the right default, since the trace
bytes should change only when model/capture behavior intentionally
changes.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "tests", "core"))

from golden_cases import (CASES, GOLDEN_DIR,  # noqa: E402
                          MODEL_TRACE_CASES, SERVING_CASES, golden_record)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--traces", action="store_true",
                    help="recapture the pinned model traces from the "
                         "live models before re-snapshotting")
    args = ap.parse_args()
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    if args.traces:
        from repro.data.model_traces import write_pinned_traces
        write_pinned_traces()
    for name in list(CASES) + list(SERVING_CASES) + list(MODEL_TRACE_CASES):
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        record = golden_record(name)
        with open(path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}  (makespan={record['makespan_fpga_cycles']:.1f})")


if __name__ == "__main__":
    main()
