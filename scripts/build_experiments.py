"""Assemble EXPERIMENTS.md from the dry-run / optimized / perf JSONL
records plus the benchmark CSV. Re-runnable:

  PYTHONPATH=src python scripts/build_experiments.py
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.report import dryrun_table, fmt_bytes, load, roofline_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def read_jsonl(path):
    p = os.path.join(ROOT, path)
    return load(p) if os.path.exists(p) else []


def perf_log():
    recs = []
    p = os.path.join(ROOT, "experiments_perf.jsonl")
    if os.path.exists(p):
        for line in open(p):
            recs.append(json.loads(line))
    return recs


def opt_vs_base_table(base, opt):
    bmap = {r["cell"]: r for r in base if "error" not in r}
    rows = ["| cell | baseline frac | optimized frac | gain | "
            "baseline bound s | optimized bound s |",
            "|---|---|---|---|---|---|"]
    for r in sorted(opt, key=lambda x: x["cell"]):
        if "error" in r or r["cell"] not in bmap:
            continue
        b = bmap[r["cell"]]
        bb = max(b["compute_s"], b["memory_s"], b["collective_s"])
        ob = max(r["compute_s"], r["memory_s"], r["collective_s"])
        gain = r["roofline_fraction"] / max(b["roofline_fraction"], 1e-12)
        rows.append(
            f"| {r['cell']} | {b['roofline_fraction']:.4f} | "
            f"{r['roofline_fraction']:.4f} | {gain:.2f}x | {bb:.4f} | "
            f"{ob:.4f} |")
    return "\n".join(rows)


def bench_section():
    path = os.path.join(ROOT, "bench_output.txt")
    if not os.path.exists(path):
        path = "/tmp/bench_all.txt"
    if not os.path.exists(path):
        return "(benchmarks not yet captured)"
    keep = [l.strip() for l in open(path)
            if l.startswith(("fig7/", "fig8/width1B", "fig9/optimum",
                             "autotune/"))]
    return "```\n" + "\n".join(keep) + "\n```"


HEADER = """# EXPERIMENTS

Reproduction + beyond-paper performance record for *Programmable
FPGA-based Memory Controller* (Wijeratne et al., 2021) on the JAX/TPU
framework described in DESIGN.md. Hardware model: TPU v5e — 197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI (4 links) per chip; meshes
16x16 = 256 chips (single pod) and 2x16x16 = 512 chips (multi-pod).

Measurement substrate (CPU container, no TPU): every cell is
`.lower().compile()`d for the production meshes; FLOPs/bytes come from
`compiled.cost_analysis()`, collective bytes from parsing the
SPMD-partitioned HLO, with scanned-layer costs extrapolated exactly via
1-group/2-group unrolled compiles (XLA bills while-loop bodies once; the
extrapolation identity is verified in
`tests/distribution/test_sharded.py::test_cost_extrapolation_exact_on_unrollable_model`).

**Known backend bias (documented, uniform across cells):** XLA-CPU
legalizes bf16 matmuls/collectives to f32, inflating byte counts up to 2x,
and its `convert`-op traffic inflates the memory term for every cell
(per-op attribution in §Perf). Term *deltas* between variants remain
meaningful; absolute roofline fractions are conservative lower bounds.
Extrapolation error bars, measured against fully-unrolled ground truth on
a toy config: ~6-9% FLOPs, ~15% collective bytes (fusion boundaries and
depth-dependent collective combining); both shrink with model scale as the
uniform layer term dominates.
"""

PAPER_VALIDATION = """
## §Paper-validation (the faithful-reproduction gate)

All paper claims are reproduced on the cycle-level DDR4-2400 open-row
simulator (`repro.core.timing`) — the same metric (total memory access
time) the paper reports, with the commercial-IP baseline modeled as a
shallow greedy reorder window (MIG-like; `window=1` = pure FIFO):

| claim (paper) | reproduced | where |
|---|---|---|
| GCN access time −27% | **−29.4%** vs MIG-like baseline (−44% vs FIFO), DMA 91% of time (paper: 99%) | `benchmarks/fig7_workloads.py` |
| CNN access time −58% ("up to") | **−47.6%** vs MIG-like (−50.8% vs FIFO), cache hit 96%, DMA 75% (paper: 80%) | same |
| 20x bulk-vs-narrow interface | **12.8x** at 1 B interface width (conservative burst model charges CAS per burst; same simulator both paths) | `benchmarks/fig8_interface_width.py` |
| batch 32–64 optimal | **64** under the paper's own criterion (performance per LUT/FF-class resource, Fig. 6's ~3x/doubling); raw throughput keeps improving to 512, matching Fig. 9's monotone total-time curve | `benchmarks/fig9_schedule_time.py` |
| Eq. 1 schedule time | exact: `t_schedule(N) = N + log2N(log2N+1)/2 + L_cond`, network stage count asserted in kernel tests | `tests/core/test_timing.py`, `tests/kernels/test_bitonic_sort.py` |
| Table III / Fig. 5 / Fig. 6 resource scaling | linear VMEM scaling with line width x count x ways / channels x buffers; constant-logic scheduler with log²N stages | `benchmarks/table3_*.py`, `fig5_*.py`, `fig6_*.py` |
| weak consistency model | property-tested: single-type batches, same-address arrival order preserved under reordering, batch FIFO service | `tests/core/test_scheduler.py` |

Key benchmark lines (full CSV in `bench_output.txt`):
"""

PERF_NARRATIVE = """
## §Perf — hillclimb log (hypothesis → change → measure → verdict)

Three cells selected per the methodology: **qwen2-moe/train_4k** (worst
useful-FLOPs ratio 0.02 AND the paper-representative cell — MoE dispatch
is the controller scheduler), **mixtral/train_4k** (most collective-bound:
19.8 s collective term at baseline), **granite/decode_32k** (serving cell,
collective ~ memory, worst roofline-fraction class).

### qwen2-moe-a2.7b / train_4k  (baseline frac 0.0107 → 0.0203)

| # | hypothesis | change | before → after (dominant terms) | verdict |
|---|---|---|---|---|
| 1 | per-stage attribution shows the GShard one-hot cumsum position computation is billed O(n·E)-quadratically (1.69e16 of 1.82e16 layer FLOPs); replacing it with the **paper's scheduler** — stable sort by expert/row id, slot = offset in the sorted run — removes it at identical semantics (bit-exact incl. drop behaviour, tested) | `moe_dispatch="sort"` | compute 16.51 s → **0.44 s** (38x); useful ratio 0.02 → 0.77 | **confirmed** — the paper's reorder-by-row idea, applied at cluster scale, IS the fix |
| 2 | CE-loss logits (1M x 152k) dominate HBM bytes; chunked CE with rematerialized logits should cut the memory term | `loss_chunks=16` | memory 28.74 → 30.01 s | **refuted** — per-layer traffic dominates (outer incl. loss = 0.4% of bytes by G1/G2 differencing); XLA-CPU bills op bytes regardless of chunk residency. Kept as opt-in feature (real TPU VMEM-residency win not measurable here) |
| 3 | per-op attribution of the 103.7 GiB/device temps: GSPMD **replicates the scatter operand** because dispatch indices span the capacity-sharded dim; keeping the dispatch buffers sharded on the embedding dim through scatter/gather (resharding only around the expert einsums) makes the scatter partitionable | sharding constraints around dispatch | temps 103.7 → **21.8 GiB/dev** (4.8x); HBM bytes 6.03e15 → 3.47e15; collective 14.4 → 6.5 s | **confirmed** |

### mixtral-8x7b / train_4k  (baseline frac 0.036 → 0.150)

| # | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| 1 | carry over the qwen2 fixes | sort dispatch + scatter sharding | coll 19.8 → 9.5 s; memory 44.9 → 23.8 s; frac 0.036 → 0.068 | **confirmed** |
| 2 | `dots_saveable` remat saves one forward recompute → weight all-gathers 3→2 passes, collective −33% | `remat_policy="dots"` | coll 9.5 → 8.9 s (−7%), compute −25%, but temps 28 → **174 GiB/dev** | **refuted** (weight-AG is a small AG share; bwd re-gathers regardless; capacity cost catastrophic) — reverted |
| 3 | remaining 818 GB/dev all-reduce comes from the *global* scatter (partial buffers all-reduced across data shards). The paper's schedulers are **bounded and per-controller** (Table I batch ≤ 512, one per PE group); restoring that structure — GShard-style local groups, one scheduler instance per data shard, scatter batch-dim sharded — makes dispatch collective-free | `num_groups = DP shards` (group-local sort/scatter/capacity) | memory 23.8 → **10.7 s**, coll 9.5 → **5.7 s** (CP 260 → 2 GB); frac 0.068 → **0.150** | **confirmed** — second instance of the paper's structure fixing a scale bottleneck |
| 4 | larger flash-attention KV blocks rewrite online-softmax accumulators fewer times → memory term down | `attn_kv_block 1024→4096` | memory 10.7 → 12.2 s | **refuted** (bigger score blocks outweigh accumulator savings at S=4k) — reverted; the real fix is the Pallas `flash_attention` kernel whose accumulators live in VMEM (validated interpret-mode; not lowerable on the CPU dry-run mesh) |

### granite-34b / decode_32k  (baseline frac 0.003 → 0.0034, collective −112x)

| # | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| 1 | HLO dump shows ZeRO-3 weight all-gathers (f32-legalized) re-run **every decode step** (11 GB/dev/token-step) — training sharding is wrong for serving; replicating weights across the data axis (sharded only over model: 4.3 GB/dev, fits 16 GB HBM) removes them | serving rules `w_fsdp=None` | collective 0.0592 → **0.00053 s** (112x); AG 11 GB → 0.05 GB/step | **confirmed** |
| 2 | the same layout helps every serve cell | apply to all decode/long cells | dense decode_32k: +5–18% bound; **long_500k regressed 5–25x** (mamba2 bound 0.0002 → 0.0054 s) and MoE decode −13% | **refuted as a universal rule** — with batch 1 the FSDP(+TP) layout is 256-way 2D tensor parallelism: tiny psums beat 16x more weight reads; MoE expert weights too large to replicate |
| 3 | serving weight layout must be **batch- and arch-conditional**: replicate over data iff dense arch and global_batch ≥ DP shards; keep 2D sharding for batch-1 long-context and MoE serving | `sharding.serving_weight_overrides` (now the serve default) | regressed cells restored to their best layout; granite decode keeps the 112x | **confirmed** |
| 4 | decode is now at its memory floor: per-step bytes = weights + KV shard — arithmetic-intensity-bound at batch 128 (ideal frac ≤ ~0.5 by 2·N·B/weight-bytes); remaining gap is f32-legalization inflation | (analysis) | memory term 0.0695 s ≈ floor | stop on layout ideas |
| 5 | int8 KV cache (per-position/head scales, dequantize at read) halves the cache share of the floor; weights still dominate at batch 128 so the total moves modestly | `kv_cache_dtype="int8"` | memory 0.0695 → **0.0620 s** (−11%); cache state −44%; decode accuracy within 0.5–0.7% rel (tested, error non-compounding over steps) | **confirmed** — opt-in serving feature |

### Extension: expert-parallel dispatch (jamba-v0.1-52b / train_4k)

The paper's DMA engine at cluster scale: `models/moe_ep.py` implements
true expert parallelism under `shard_map` — tokens stable-sorted by
*destination shard* (row = expert owner), packed into per-destination
staging buffers (bounded send capacity = the paper's per-controller
batches), moved with one `all_to_all` bulk transfer each way, experts
whole on their owner shard. Bit-matches the TP dispatch at ample capacity
(`tests/distribution`), gradients flow through the shard_map.

| strategy | compute s | memory s | collective s | frac | verdict |
|---|---|---|---|---|---|
| TP (default) | 2.20 | 11.13 | 5.08 | **0.135** | best overall at v5e single-pod scale |
| EP (`--moe-strategy ep`) | 2.25 | 17.58 | **1.53** | 0.085 | collective term 3.3x lower — preferable when interconnect-bound (cross-pod DP+EP, slower links, larger TP degrees); memory term pays for unsharded expert FFN intermediates + data-replicated expert weights |

A measured *term trade*, not a dominance: the framework exposes both and
the autotuner-style choice belongs to the deployment (EP additionally
requires `E % tp == 0`, no shared experts — jamba qualifies, mixtral/qwen2
do not at tp=16).

### Stop criteria & residuals

Each cell stopped after consecutive <5% candidates on the dominant term.
The dominant residual everywhere is the memory term's `convert` traffic
(XLA-CPU bf16→f32 legalization — per-op attribution: 539 GiB/dev of
convert outputs in one qwen2 layer vs 18 GiB of dot outputs), which does
not exist on real TPUs. On-target, the same artifacts would be
re-profiled with `xprof`; the structural fixes above (dispatch FLOPs,
scatter partitioning, group-local scheduling, serving weight layout) are
backend-independent.
"""

FEATURES = """
## Beyond-paper optimizations & production features (summary)

* **Sort-based MoE dispatch** (paper's scheduler at cluster scale) — 38x
  compute-term reduction on fine-grained MoE; bit-exact vs naive dispatch.
* **Group-local schedulers** (paper's bounded per-controller batches) —
  collective-free dispatch; 2.2x memory / 1.7x collective on mixtral.
* **Dispatch-buffer sharding discipline** — 4.8x peak-memory reduction.
* **Serving weight layout** (replicate-over-data) — 112x decode collective
  reduction; production serve path defaults.
* **Expert parallelism** (`moe_strategy="ep"`) — shard_map all-to-all
  dispatch, value-matching TP; measured term trade (collective 3.3x lower
  / memory 1.6x higher on jamba) — the deployment chooses.
* **int8 KV cache** (`kv_cache_dtype="int8"`) — 44% cache-state reduction,
  <1% decode error (non-compounding, tested over multi-step decode).
* **Chunked cross-entropy** (`loss_chunks`) — opt-in; exact (tested
  value+grad); benefits VMEM residency on real TPUs.
* **Remat policy knob** (`remat_policy`) — nothing/dots tradeoff measured.
* **Pallas kernels** — bitonic scheduler network, revisit-dedup sorted
  gather, cache tag/LRU pipelines, multi-channel DMA, flash attention with
  block-causal skip; all interpret-validated against jnp oracles.
* **Fault tolerance** — stateless data pipeline (exact resume, tested
  bitwise), atomic async checkpoints, elastic mesh restore (tested on a
  shrunk mesh), straggler watchdog + rescale planner, int8 error-feedback
  gradient compression for the cross-pod axis.
"""


def main() -> None:
    base = read_jsonl("experiments_dryrun.jsonl")
    opt = read_jsonl("experiments_optimized.jsonl")

    ok = [r for r in base if "error" not in r]
    parts = [HEADER]
    parts.append("\n## §Dry-run\n")
    parts.append(
        f"All **{len(ok)}/{len(base)}** (architecture x shape x mesh) cells "
        "lower + compile successfully on both production meshes — 33 "
        "supported cells x {16x16, 2x16x16} (the 40-cell assignment minus "
        "documented skips: encoder-only decode, full-attention long_500k; "
        "see DESIGN.md §5). `memory_analysis()`/`cost_analysis()` per cell:\n")
    parts.append(dryrun_table(base))
    parts.append(
        "\nMulti-pod (2pod) rows prove the `pod` axis shards: per-device "
        "state/temp bytes halve for train cells (DP over pods) while "
        "global FLOPs are preserved.\n\nProvenance: baseline MoE cells "
        "were measured with the naive cumsum dispatch and the global "
        "(ungrouped) scheduler — the pre-§Perf defaults; decode cells "
        "with training (ZeRO-3) weight sharding. The optimized table "
        "below uses the current framework defaults that §Perf derived.\n")

    parts.append("\n## §Roofline (single-pod, 256 chips) — baseline\n")
    parts.append(
        "Terms per the assignment: compute = HLO_FLOPs/(chips·197e12), "
        "memory = HLO_bytes/(chips·819e9), collective = per-device "
        "collective bytes/(4·50e9). MODEL_FLOPS = 6·N_active·D (train) or "
        "2·N_active·tokens (serve).\n")
    parts.append(roofline_table(base, "1pod"))
    parts.append("\n### Multi-pod (512 chips) — baseline\n")
    parts.append(roofline_table(base, "2pod"))
    parts.append("""
Per-cell bottleneck notes (what would move the dominant term down):
* *train cells* — memory-bound everywhere: activation+convert traffic;
  levers = dispatch sharding (MoE, confirmed), microbatching, Pallas flash
  (VMEM accumulators), bf16-native backend.
* *prefill 32k* — yi/qwen2 compute-bound (yi: replicated inner attention for
  56 heads on a 16-way axis — padding-free layouts are the lever; qwen2:
  dispatch FLOPs, fixed in §Perf); others memory-bound on score/convert
  traffic.
* *decode* — memory-bound at the weight+KV read floor once serving layout
  fixed (§Perf); useful ratios < 0.5 reflect per-token weight reads at
  modest batch.
* *long_500k* — state-dominated (SSM state or ring KV): trivially small
  terms; bottleneck is launch overhead, not data movement.
""")

    if opt:
        parts.append("\n## §Roofline — optimized (current framework "
                     "defaults)\n")
        parts.append(
            "Baseline vs optimized (sort dispatch + group-local scheduler "
            "+ dispatch sharding for MoE cells; replicated serving weights "
            "for decode cells):\n")
        parts.append(opt_vs_base_table(base, opt))

    parts.append(PERF_NARRATIVE)
    parts.append(PAPER_VALIDATION)
    parts.append(bench_section())
    parts.append(FEATURES)

    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
