"""Quickstart: the memory controller + a model in five minutes (CPU-safe).

1. Configure a memory controller (the paper's Table I knobs).
2. Route an irregular gather through it — value-identical, locality-
   optimized.
3. Train a reduced yi-34b-family model for a handful of steps.
4. Serve a few tokens from it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MemoryController, MemoryControllerConfig,
                        simulate_dram_access)
from repro.core.config import (CacheConfig, ChannelConfig, DMAConfig,
                               SchedulerConfig)
from repro.launch.train import Trainer, TrainerConfig


def demo_controller():
    print("=== 1/3: programmable memory controller ===")
    cfg = MemoryControllerConfig(
        scheduler=SchedulerConfig(batch_size=64, timeout_cycles=16),
        cache=CacheConfig(num_lines=4096, associativity=4),
        dma=DMAConfig(num_parallel_dma=4),
        channels=ChannelConfig(num_channels=4),
    )
    print(cfg.describe())

    mc = MemoryController(cfg)
    table = jnp.asarray(np.random.default_rng(0).standard_normal((4096, 64)),
                        jnp.float32)
    idx = jnp.asarray(np.random.default_rng(1).integers(0, 4096, 1024))
    out = mc.gather(table, idx)                 # scheduler-path gather
    assert jnp.allclose(out, table[idx])

    # Full staged pipeline: arbiters -> address map -> cache filter ->
    # batch scheduler -> channel-parallel DRAM service -> DMA overlap.
    base = simulate_dram_access(np.asarray(idx) * 256)
    res = mc.simulate(None, np.asarray(idx), None, 256)
    print(f"modeled DRAM cycles: {base.total_fpga_cycles:.0f} -> "
          f"{res.makespan_fpga_cycles:.0f} "
          f"({1 - res.makespan_fpga_cycles / base.total_fpga_cycles:.0%} "
          f"saved, cache hit rate {res.cache_hit_rate:.2f})")
    print("per-stage cycle breakdown:",
          {k: round(v) for k, v in res.breakdown().items()}, "\n")


def demo_train():
    print("=== 2/3: train a reduced yi-34b for 15 steps ===")
    out = Trainer(TrainerConfig(arch="yi-34b", smoke=True, steps=15,
                                batch_override=8, seq_override=64,
                                log_every=5)).run()
    print(f"final loss {out['final_loss']:.3f}\n")
    return out


def demo_serve():
    print("=== 3/3: serve ===")
    from repro.launch.serve import Request, Server
    server = Server("yi-34b", smoke=True)
    reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i,
                    max_new_tokens=4) for i in range(3)]
    stats = server.serve(reqs)
    print(f"{stats.requests} requests, outputs: "
          f"{[r.output for r in reqs]}")


if __name__ == "__main__":
    demo_controller()
    demo_train()
    demo_serve()
