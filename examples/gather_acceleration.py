"""GCN-style gather acceleration — the paper's Fig. 7a scenario end-to-end.

A graph workload gathers vertex features (bulk) and adjacency rows
(cacheable) from "HBM" (a big table). We run the access stream through
the controller and through the naive path, compare modeled DRAM time
(cycle-level simulator) AND actual JAX wall time of the fused
sort->gather->unsort against the plain gather.

Run:  PYTHONPATH=src python examples/gather_acceleration.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HotRowCache, MemoryController,
                        PAPER_COMBINED_CONFIG, PAPER_EVAL_CONFIG)
from repro.core.cache_engine import hit_rate_oracle
from repro.core.timing import simulate_dram_access

N_VERT = 16_384
FEAT = 256
N_EDGES = 100_000


def main():
    rng = np.random.default_rng(0)
    features = jnp.asarray(rng.standard_normal((N_VERT, FEAT)), jnp.float32)

    # power-law neighbor visits (hubs dominate — cacheable)
    dst = jnp.asarray((rng.zipf(1.15, N_EDGES) - 1) % N_VERT, jnp.int32)

    mc = MemoryController(PAPER_EVAL_CONFIG)

    # --- modeled DRAM access time (the paper's metric) ---
    base = simulate_dram_access(np.asarray(dst) * FEAT * 4)
    opt = mc.modeled_gather_time(np.asarray(dst), row_bytes=FEAT * 4)
    print(f"modeled access cycles : naive={base.total_fpga_cycles:,.0f} "
          f"controller={opt.total_fpga_cycles:,.0f} "
          f"({1 - opt.total_fpga_cycles / base.total_fpga_cycles:.0%} "
          "saved)")

    # --- full staged pipeline: cache + scheduler + 4 channels composed ---
    # (the headline configuration; per-stage breakdown sums to makespan)
    res = MemoryController(PAPER_COMBINED_CONFIG).simulate(
        None, np.asarray(dst), None, FEAT * 4)
    print(f"combined pipeline     : makespan="
          f"{res.makespan_fpga_cycles:,.0f} cycles "
          f"(cache hit rate {res.cache_hit_rate:.1%}, "
          f"{1 - res.makespan_fpga_cycles / base.total_fpga_cycles:.0%} "
          "saved vs naive)")
    print("  stage breakdown     :",
          {k: round(v) for k, v in res.breakdown().items()})

    # --- cache engine on the hub vertices ---
    hot = HotRowCache.build(features,
                            np.argsort(np.bincount(np.asarray(dst),
                                                   minlength=N_VERT))[-512:])
    hit = float(hot.hit_mask(dst).mean())
    print(f"hot-row cache hit rate on hubs: {hit:.1%}")
    line_hits, lr = hit_rate_oracle(PAPER_EVAL_CONFIG.cache,
                                    np.asarray(dst))
    print(f"LRU cache-engine hit rate     : {lr:.1%}")

    # --- wall time: plain vs scheduler-path gather (jitted) ---
    plain = jax.jit(lambda t, i: jnp.take(t, i, axis=0))
    routed = jax.jit(mc.gather)
    for name, fn in (("plain", plain), ("controller", routed)):
        fn(features, dst).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            fn(features, dst).block_until_ready()
        print(f"wall time {name:11s}: "
              f"{(time.perf_counter() - t0) / 10 * 1e3:.2f} ms/gather")
    out = routed(features, dst)
    assert jnp.allclose(out, features[dst]), "value identity violated"
    print("value identity: OK")


if __name__ == "__main__":
    main()
