"""Batched serving with scheduler-policy admission (paper Fig. 2 applied
to inference requests): bursts of requests are batched under
(batch_size, timeout) rules, prefilled together, decoded in lockstep.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import numpy as np

from repro.core.config import SchedulerConfig
from repro.launch.serve import Request, Server


def main() -> None:
    server = Server("mixtral-8x7b", smoke=True,
                    sched=SchedulerConfig(batch_size=4, timeout_cycles=8))
    rng = np.random.default_rng(0)

    # three bursts of traffic with idle gaps longer than the timeout
    reqs = []
    t = 0
    for burst, size in enumerate((4, 6, 2)):
        for _ in range(size):
            reqs.append(Request(
                rid=len(reqs),
                prompt=rng.integers(0, server.cfg.vocab_size,
                                    rng.integers(8, 20)).astype(np.int32),
                max_new_tokens=6, arrival_cycle=t))
            t += 1
        t += 50                       # inter-burst gap > timeout

    batches = server.admit(reqs)
    print(f"admission: {len(reqs)} requests -> "
          f"{[len(b) for b in batches]} batches "
          "(batch_size=4, timeout=8 cycles)")
    stats = server.serve(reqs)
    print(f"served {stats.requests} requests, "
          f"{stats.decode_steps} lockstep decode steps, "
          f"{stats.prefill_tokens} prefill tokens in {stats.wall_s:.1f}s")
    for r in reqs[:3]:
        print(f"  req{r.rid}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
