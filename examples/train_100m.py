"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A scaled member of the yi/llama family (10 layers, d=640, GQA 8/4 heads,
32k vocab ~ 106M params) trained on the deterministic zipf pipeline with
the full production stack: memory-controller embedding path, AdamW,
cosine schedule, remat, async checkpointing, straggler watchdog.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse

from repro.launch.train import Trainer, TrainerConfig
from repro.optim.adamw import OptimizerConfig

# yi/llama family scaled to ~100M parameters
OVERRIDES = dict(num_layers=10, d_model=640, num_heads=8, num_kv_heads=4,
                 head_dim=80, d_ff=2048, vocab_size=32_000)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/train_100m_ckpt")
    args = ap.parse_args()

    tc = TrainerConfig(
        arch="yi-34b", arch_overrides=OVERRIDES, steps=args.steps,
        batch_override=args.batch, seq_override=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10,
        opt=OptimizerConfig(peak_lr=1e-3, warmup_steps=30,
                            total_steps=args.steps))
    trainer = Trainer(tc)
    n_params = trainer.cfg.param_count()
    print(f"[100m] model: {n_params / 1e6:.0f}M params "
          f"({trainer.cfg.num_layers}L d={trainer.cfg.d_model} "
          f"ff={trainer.cfg.d_ff})")
    out = trainer.run()
    first = sum(out["history"][:10]) / 10
    last = sum(out["history"][-10:]) / 10
    print(f"[100m] loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({trainer.watchdog.median_step_s * 1e3:.0f} ms/step median)")


if __name__ == "__main__":
    main()
