"""Gradient compression for cross-pod all-reduce (distributed-opt trick).

The pod axis is pure data parallelism over the slowest links (inter-pod
ICI/DCN), so its gradient all-reduce is the most bandwidth-exposed
collective in a multi-pod step. ``compressed_psum`` halves (bf16) or
quarters (int8, per-tensor scale + error feedback) the bytes on that axis.

Error feedback keeps a residual buffer per tensor: the quantization error
of step t is added back into the gradient at step t+1, making the
compression unbiased over time (SGD-EF; Karimireddy et al. 2019).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, residuals, axis_name: str, *,
                    mode: str = "int8"):
    """All-reduce a gradient pytree over ``axis_name`` with compression.

    Must run inside shard_map/pmap context that defines ``axis_name``.
    Returns (mean_grads, new_residuals).
    """
    n = jax.lax.psum(1, axis_name)

    def reduce_leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        if mode == "int8":
            q, scale = compress_int8(g32)
            # sum int8 payloads in int32 to avoid overflow; scales are
            # device-local so psum the dequantized contribution instead.
            summed = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32)
                                  * scale, axis_name)
            approx = summed / n
            new_r = g32 - decompress_int8(q, scale)
        elif mode == "bf16":
            approx = jax.lax.psum(g32.astype(jnp.bfloat16), axis_name
                                  ).astype(jnp.float32) / n
            new_r = g32 - g32.astype(jnp.bfloat16).astype(jnp.float32)
        else:
            approx = jax.lax.psum(g32, axis_name) / n
            new_r = jnp.zeros_like(g32)
        return approx.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [reduce_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
