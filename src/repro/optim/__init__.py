"""Optimizer substrate: sharded AdamW + schedules + gradient compression."""

from repro.optim.adamw import (OptimizerConfig, adamw_update, init_opt_state,
                               opt_state_specs, lr_schedule)
from repro.optim.compress import (compress_int8, decompress_int8,
                                  compressed_psum)

__all__ = ["OptimizerConfig", "adamw_update", "init_opt_state",
           "opt_state_specs", "lr_schedule", "compress_int8",
           "decompress_int8", "compressed_psum"]
