"""AdamW with fp32 moments over bf16 params, ZeRO-sharded states.

Moments inherit each parameter's PartitionSpec, so optimizer memory scales
down with both the FSDP (data) and TP (model) axes — the ZeRO-2/3 layout.
Update math runs in fp32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(step: jnp.ndarray, cfg: OptimizerConfig) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio·peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    f32_like = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32_like, params),
        "v": jax.tree.map(f32_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params) -> Dict[str, Any]:
    f32_like = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32_like, abstract_params),
        "v": jax.tree.map(f32_like, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_state_specs(param_specs):
    from jax.sharding import PartitionSpec as P
    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(
    grads, opt_state, params, cfg: OptimizerConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step (with global-norm clipping). Returns
    (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, cfg)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
