"""Launch layer: meshes, dry-run, roofline analysis, train/serve drivers."""
