"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSONL records.

Usage: python -m repro.launch.report experiments_dryrun.jsonl
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # keep the latest record per cell
    by_cell = {}
    for r in recs:
        by_cell[r["cell"]] = r
    return list(by_cell.values())


def fmt_bytes(b: float) -> str:
    if b >= 1 << 30:
        return f"{b / (1 << 30):.2f}G"
    if b >= 1 << 20:
        return f"{b / (1 << 20):.1f}M"
    return f"{b / 1024:.0f}K"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| cell | mesh | compile s | state B/dev | temp B/dev | "
            "HLO FLOPs (global) | collective B/dev (AG/AR/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: x["cell"]):
        if "error" in r:
            rows.append(f"| {r['cell']} | — | FAILED: {r['error'][:60]} | "
                        "| | | |")
            continue
        ma = r.get("memory_analysis", {})
        det = r.get("collectives_detail", {})
        coll = "/".join(fmt_bytes(det.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        arch_shape, mesh = r["cell"].rsplit("/", 1)
        rows.append(
            f"| {arch_shape} | {mesh} | {r['compile_s']:.0f} | "
            f"{fmt_bytes(r['state_bytes_per_device'])} | "
            f"{fmt_bytes(ma.get('temp_size_in_bytes', 0))} | "
            f"{r['hlo_flops']:.3e} | {coll} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict], mesh: str = "1pod") -> str:
    rows = ["| arch/shape | compute s | memory s | collective s | "
            "bottleneck | MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: x["cell"]):
        if "error" in r or not r["cell"].endswith("/" + mesh):
            continue
        arch_shape = r["cell"].rsplit("/", 1)[0]
        rows.append(
            f"| {arch_shape} | {r['compute_s']:.4f} | {r['memory_s']:.4f} |"
            f" {r['collective_s']:.4f} | **{r['bottleneck']}** | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if "error" not in r]
    fails = [r for r in recs if "error" in r]
    lines = [f"cells compiled OK: {len(ok)}; failed: {len(fails)}"]
    pods1 = [r for r in ok if r["cell"].endswith("1pod")]
    if pods1:
        worst = min(pods1, key=lambda r: r["roofline_fraction"])
        coll = max(pods1, key=lambda r: r["collective_s"]
                   / max(r["compute_s"] + r["memory_s"], 1e-30))
        lines.append(f"worst roofline fraction: {worst['cell']} "
                     f"({worst['roofline_fraction']:.3f})")
        lines.append(f"most collective-exposed: {coll['cell']} "
                     f"(coll {coll['collective_s']:.4f}s vs bound "
                     f"{max(coll['compute_s'], coll['memory_s']):.4f}s)")
    return "\n".join(lines)


def main() -> None:
    recs = load(sys.argv[1] if len(sys.argv) > 1
                else "experiments_dryrun.jsonl")
    print("## Summary\n")
    print(summary(recs))
    print("\n## Dry-run table\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs, "1pod"))
    print("\n## Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(recs, "2pod"))


if __name__ == "__main__":
    main()
