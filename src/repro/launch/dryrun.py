import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init); 512 placeholder host devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.

Per cell this driver:
  1. builds the jitted entry point (train_step / serve_prefill / serve_step)
     with NamedSharding in/out specs,
  2. ``.lower().compile()`` — success proves the sharding config is
     coherent (no mismatched specs, no unsupported collective, no
     compile-time OOM),
  3. records ``memory_analysis()`` + ``cost_analysis()``,
  4. extracts roofline terms. XLA cost analysis counts while-loop bodies
     once, so scanned-layer costs are *extrapolated exactly*: two small
     unrolled variants (1 and 2 layer-groups) are also compiled and the
     per-group cost is their difference:
         total = cost(G1) + (num_groups - 1) * (cost(G2) - cost(G1)).
     The full scanned compile remains the compile-proof + memory source.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import dataclasses
import json
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch, supported_shapes
from repro.configs.registry import ARCH_IDS, canonical
from repro.data.synthetic import batch_specs
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models.lm import build_lm
from repro.optim.adamw import (OptimizerConfig, abstract_opt_state,
                               adamw_update, opt_state_specs)

MARGIN = 256   # decode cache slack; multiple of 256 keeps seq-sharding even


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def estimate_state_bytes_per_device(abstract_tree, spec_tree, mesh) -> float:
    """Analytic per-device bytes of a sharded pytree (params/opt/cache)."""
    total = 0.0
    for leaf, spec in zip(jax.tree.leaves(abstract_tree),
                          jax.tree.leaves(
                              spec_tree,
                              is_leaf=lambda x: isinstance(x, P))):
        shard_elems = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        for axis_entry in spec:
            if axis_entry is None:
                continue
            axes = (axis_entry,) if isinstance(axis_entry, str) \
                else axis_entry
            for ax in axes:
                shard_elems /= mesh.shape[ax]
        total += shard_elems * jnp.dtype(leaf.dtype).itemsize
    return total


def build_cell(arch_name: str, shape_name: str, mesh, *,
               moe_strategy: str = "tp", overrides: Dict[str, Any] = None,
               sharding_overrides: Dict[str, Any] = None):
    """Returns (jitted_fn, abstract_args, state_bytes_per_device, cfg)."""
    cfg = get_arch(arch_name)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    lm = build_lm(cfg, mesh, global_batch=shape.global_batch,
                  moe_strategy=moe_strategy)
    if sharding_overrides is None and shape.kind == "decode":
        # production serving layout (see sharding.serving_weight_overrides)
        from repro.models.sharding import serving_weight_overrides
        sharding_overrides = serving_weight_overrides(
            cfg, shape.global_batch, mesh)
    if sharding_overrides:
        # e.g. {"w_fsdp": None} — serving replicates weights across the
        # data axis instead of gathering them every decode step (§Perf).
        lm.rules = dataclasses.replace(lm.rules, **sharding_overrides)
    rules = lm.rules
    pspecs = lm.param_specs()
    aparams = lm.abstract_params()
    state_bytes = estimate_state_bytes_per_device(aparams, pspecs, mesh)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig()
        ospecs = opt_state_specs(pspecs)
        aopt = abstract_opt_state(aparams)
        bshapes, bspecs = batch_specs(cfg, shape, rules)
        state_bytes += estimate_state_bytes_per_device(aopt, ospecs, mesh)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lm.loss, has_aux=True)(params, batch)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, loss, {**metrics, **om}

        fn = jax.jit(
            train_step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                          _named(mesh, bspecs)),
            donate_argnums=(0, 1),
        )
        args = (aparams, aopt, bshapes)

    elif shape.kind == "prefill":
        bshapes, bspecs = batch_specs(cfg, shape, rules)
        bshapes.pop("labels"), bspecs.pop("labels")
        cspecs = lm.cache_specs()

        def serve_prefill(params, batch):
            logits, cache, cur = lm.prefill(params, batch,
                                            max_len=shape.seq_len + MARGIN)
            return logits, cache, cur

        fn = jax.jit(
            serve_prefill,
            in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
            out_shardings=(None, _named(mesh, cspecs), None),
        )
        args = (aparams, bshapes)

    else:   # decode
        B = shape.global_batch
        acache = lm.init_cache(B, shape.seq_len + MARGIN, abstract=True)
        cspecs = lm.cache_specs()
        state_bytes += estimate_state_bytes_per_device(acache, cspecs, mesh)

        def serve_step(params, token, cache, cur_len):
            return lm.decode_step(params, token, cache, cur_len)

        fn = jax.jit(
            serve_step,
            in_shardings=(_named(mesh, pspecs),
                          NamedSharding(mesh, rules.spec("batch")),
                          _named(mesh, cspecs),
                          NamedSharding(mesh, P())),
            donate_argnums=(2,),
        )
        args = (aparams,
                jax.ShapeDtypeStruct((B,), jnp.int32),
                acache,
                jax.ShapeDtypeStruct((), jnp.int32))

    return fn, args, state_bytes, cfg, shape


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
             moe_strategy: str = "tp", skip_extrapolation: bool = False,
             overrides: Dict[str, Any] = None,
             sharding_overrides: Dict[str, Any] = None) -> Dict[str, Any]:
    arch_name = canonical(arch_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cell = f"{arch_name}/{shape_name}/{'2pod' if multi_pod else '1pod'}"
    rec: Dict[str, Any] = {"cell": cell, "chips": chips,
                           "moe_strategy": moe_strategy}

    t0 = time.time()
    fn, args, state_bytes, cfg, shape = build_cell(
        arch_name, shape_name, mesh, moe_strategy=moe_strategy,
        overrides=overrides, sharding_overrides=sharding_overrides)
    lowered = fn.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    # --- memory ---
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: getattr(ma, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:      # pragma: no cover - backend specific
        rec["memory_analysis"] = {"error": str(e)}
    rec["state_bytes_per_device"] = state_bytes

    # --- cost extrapolation over layer groups ---
    period = cfg.scan_period
    groups = cfg.num_layers // period
    if skip_extrapolation or groups <= 2:
        reports = [roofline.analyze("full", compiled, chips=chips,
                                    model_flops=0.0)]
        flops, hbm, coll = (reports[0].hlo_flops, reports[0].hbm_bytes,
                            reports[0].collective_bytes)
        det = reports[0].collectives_detail
    else:
        sub = {}
        for g in (1, 2):
            sfn, sargs, _, _, _ = build_cell(
                arch_name, shape_name, mesh, moe_strategy=moe_strategy,
                overrides={**(overrides or {}),
                           "num_layers": g * period, "scan_layers": False},
                sharding_overrides=sharding_overrides)
            scomp = sfn.lower(*sargs).compile()
            sub[g] = roofline.analyze(f"G{g}", scomp, chips=chips,
                                      model_flops=0.0)
        flops = sub[1].hlo_flops + (groups - 1) * (
            sub[2].hlo_flops - sub[1].hlo_flops)
        hbm = sub[1].hbm_bytes + (groups - 1) * (
            sub[2].hbm_bytes - sub[1].hbm_bytes)
        coll = sub[1].collective_bytes + (groups - 1) * (
            sub[2].collective_bytes - sub[1].collective_bytes)
        det = {k: sub[1].collectives_detail[k] + (groups - 1) * (
            sub[2].collectives_detail[k] - sub[1].collectives_detail[k])
            for k in sub[1].collectives_detail}

    n_active = cfg.active_param_count()
    report = roofline.RooflineReport(
        name=cell, chips=chips, hlo_flops=flops, hbm_bytes=hbm,
        collective_bytes=coll, collectives_detail=det,
        model_flops=roofline.model_flops_for(cfg, shape, n_active),
        bytes_per_device=state_bytes)
    rec.update({
        "hlo_flops": flops, "hbm_bytes": hbm, "collective_bytes": coll,
        "collectives_detail": det,
        "model_flops": report.model_flops,
        "compute_s": report.compute_s, "memory_s": report.memory_s,
        "collective_s": report.collective_s,
        "bottleneck": report.bottleneck,
        "useful_flops_ratio": report.useful_flops_ratio,
        "roofline_fraction": report.roofline_fraction,
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true",
                    help="run every supported (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-strategy", default="tp", choices=("tp", "ep"))
    ap.add_argument("--out", type=str, default=None,
                    help="append JSON records here")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in supported_shapes(get_arch(a)):
                cells.append((a, s))
    else:
        if not args.arch:
            ap.error("--arch or --all required")
        shapes = ([args.shape] if args.shape
                  else supported_shapes(get_arch(canonical(args.arch))))
        cells = [(args.arch, s) for s in shapes]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    for arch, shp in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shp, multi_pod=mp,
                               moe_strategy=args.moe_strategy)
                status = "OK"
            except Exception as e:   # noqa: BLE001 - report and continue
                rec = {"cell": f"{canonical(arch)}/{shp}/"
                               f"{'2pod' if mp else '1pod'}",
                       "error": f"{type(e).__name__}: {e}"}
                status = "FAIL"
            print(f"[{status}] {rec['cell']}: "
                  + (f"compile={rec.get('compile_s')}s "
                     f"flops={rec.get('hlo_flops', 0):.3e} "
                     f"bottleneck={rec.get('bottleneck')}"
                     if status == "OK" else rec["error"]))
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
