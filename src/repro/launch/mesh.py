"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module can never touch jax device state — required for the dry-run's
XLA_FLAGS ordering contract.

Topology: one v5e pod contributes a 16x16 (data, model) mesh (256 chips);
multi-pod prepends a pure-DP ``pod`` axis (2x16x16 = 512 chips). The same
functions serve the elastic runtime, which re-invokes them with whatever
device count survives a failure.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    if devices is None:
        devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, have {len(devices)} "
            "(dry-run must set --xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU distribution tests (8 fake devices)."""
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()[:need]
    return jax.make_mesh(shape, axes, devices=devs)
