"""Production training driver: data → step → checkpoint → restart.

Fault-tolerance posture (exercised by tests/examples on CPU, designed for
multi-pod):
  * batches are pure functions of (seed, step) — no pipeline state;
  * async sharded checkpoints every ``--ckpt-every`` steps, atomic rename;
  * on start, the driver resumes from the latest valid checkpoint and
    *re-shards* it onto whatever mesh the surviving fleet forms
    (``runtime.elastic`` plans the mesh, ``checkpoint`` re-distributes);
  * a step-time watchdog flags stragglers; the default policy checkpoints
    and exits with a rescale plan for the scheduler to act on.

Usage (CPU smoke):
  python -m repro.launch.train --arch yi-34b --smoke --steps 20
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, ShapeConfig, get_arch
from repro.data.synthetic import SyntheticDataset
from repro.models.lm import build_lm
from repro.optim.adamw import (OptimizerConfig, adamw_update, init_opt_state,
                               opt_state_specs)
from repro.runtime import StepWatchdog, plan_rescale


def make_train_step(lm, opt_cfg: OptimizerConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss, has_aux=True)(params, batch)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **om}
    return train_step


@dataclasses.dataclass
class TrainerConfig:
    arch: str = "yi-34b"
    shape: str = "train_4k"
    smoke: bool = False
    steps: int = 100
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    batch_override: Optional[int] = None
    seq_override: Optional[int] = None
    arch_overrides: Optional[dict] = None   # ArchConfig field replacements
    log_every: int = 10
    opt: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)


class Trainer:
    """Owns mesh, state, data and the restart loop."""

    def __init__(self, tc: TrainerConfig, mesh=None):
        self.tc = tc
        cfg = get_arch(tc.arch, smoke=tc.smoke)
        if tc.arch_overrides:
            cfg = dataclasses.replace(cfg, **tc.arch_overrides)
        shape = SHAPES[tc.shape]
        if tc.seq_override or tc.batch_override:
            shape = ShapeConfig(
                name="custom", kind="train",
                seq_len=tc.seq_override or shape.seq_len,
                global_batch=tc.batch_override or shape.global_batch)
        self.shape = shape
        self.mesh = mesh
        self.lm = build_lm(cfg, mesh, global_batch=shape.global_batch)
        self.cfg = cfg
        self.data = SyntheticDataset(cfg, shape, seed=tc.seed,
                                     batch_override=tc.batch_override)
        self.watchdog = StepWatchdog()
        self.ckpt = (CheckpointManager(tc.ckpt_dir, save_every=tc.ckpt_every)
                     if tc.ckpt_dir else None)

        step_fn = make_train_step(self.lm, tc.opt)
        if mesh is not None:
            pspecs = self.lm.param_specs()
            ospecs = opt_state_specs(pspecs)
            named = lambda t: jax.tree.map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda x: isinstance(x, P))
            self.step_fn = jax.jit(step_fn,
                                   in_shardings=(named(pspecs),
                                                 named(ospecs), None),
                                   donate_argnums=(0, 1))
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- state ---------------------------------------------------------------
    def init_state(self):
        params = self.lm.init(jax.random.key(self.tc.seed))
        return params, init_opt_state(params), 0

    def restore_or_init(self):
        params, opt_state, start = self.init_state()
        if self.ckpt:
            tree = {"params": params, "opt": opt_state}
            specs = None
            if self.mesh is not None:
                p = self.lm.param_specs()
                specs = {"params": p, "opt": opt_state_specs(p)}
            step, restored = self.ckpt.restore_latest(tree, mesh=self.mesh,
                                                      specs=specs)
            if step is not None:
                print(f"[train] resumed from step {step}")
                return restored["params"], restored["opt"], step
        return params, opt_state, start

    # -- loop ----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        params, opt_state, start = self.restore_or_init()
        history = []
        for step in range(start, self.tc.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            self.watchdog.start()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            loss = float(metrics["loss"])
            alert = self.watchdog.stop(step)
            history.append(loss)
            if step % self.tc.log_every == 0:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}")
            if alert is not None:
                print(f"[train] STRAGGLER step={alert.step} "
                      f"x{alert.ratio:.1f} baseline "
                      f"{alert.baseline_s * 1e3:.0f}ms — checkpoint + "
                      "rescale plan:")
                if self.mesh is not None:
                    plan = plan_rescale(
                        tuple(self.mesh.shape.values()),
                        tuple(self.mesh.axis_names),
                        available_devices=len(jax.devices()),
                        global_batch=self.shape.global_batch)
                    print("[train]   " + plan.describe())
            if self.ckpt:
                self.ckpt.maybe_save(step + 1,
                                     {"params": params, "opt": opt_state})
        if self.ckpt:
            self.ckpt.wait()
        return {"final_loss": history[-1] if history else None,
                "history": history,
                "median_step_s": self.watchdog.median_step_s,
                "params": params}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    tc = TrainerConfig(arch=args.arch, shape=args.shape, smoke=args.smoke,
                       steps=args.steps, batch_override=args.batch,
                       seq_override=args.seq, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, seed=args.seed)
    out = Trainer(tc).run()
    print(f"[train] done: final_loss={out['final_loss']:.4f} "
          f"median_step={out['median_step_s'] * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
