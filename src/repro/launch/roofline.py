"""Roofline-term extraction from compiled dry-run artifacts.

Sources:
  * ``compiled.cost_analysis()`` → HLO FLOPs and HBM bytes accessed.
  * ``compiled.as_text()`` → post-SPMD per-device HLO; collective bytes are
    the summed operand sizes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute ops (cost_analysis does not report
    collectives).

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,512,448]{2,1,0}" — capture dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<shapes>\([^=]*?\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes of the per-device program.

    Post-partitioning HLO references operands by name only, so sizes are
    derived from each collective's *output* shape and replica-group size g:

      all-gather      operand total = output            (gathered result)
      all-reduce      operand       = output
      reduce-scatter  operand       = output x g
      all-to-all      operand       = output
      collective-permute operand    = output

    ``-done`` halves of async pairs are skipped (the ``-start`` carries the
    payload); the start tuple's last element is the result shape.
    """
    totals = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        kind = m.group("kind")
        shapes = [_shape_bytes(sm.group(1), sm.group(2))
                  for sm in _SHAPE_RE.finditer(m.group("shapes"))]
        if not shapes:
            continue
        if m.group("shapes").startswith("("):
            if m.group("suffix") == "-start":
                # (operand_alias, result[, tokens]) — payload = result = max
                out_bytes = max(shapes)
            else:
                out_bytes = sum(shapes)   # tuple collective: sum members
        else:
            out_bytes = shapes[0]
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 1
        if kind == "reduce-scatter":
            out_bytes *= g
        totals[kind] += out_bytes
    return totals


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    hlo_flops: float              # per-device program FLOPs x chips = global
    hbm_bytes: float
    collective_bytes: float       # per-device summed operand bytes
    collectives_detail: Dict[str, int]
    model_flops: float            # 6·N·D analytic
    bytes_per_device: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # collective_bytes is already per-device; each device drives its own
        # links (4 usable ICI links on a v5e 2D torus).
        return self.collective_bytes / (4 * ICI_BW_PER_LINK)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=lambda k: terms[k])

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """model-FLOPs time at peak / achievable bound time — the score."""
        ideal_s = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal_s / max(self.bound_s, 1e-30)

    def row(self) -> str:
        return (f"| {self.name} | {self.hlo_flops:.3e} | "
                f"{self.compute_s * 1e3:.2f} | {self.memory_s * 1e3:.2f} | "
                f"{self.collective_s * 1e3:.2f} | {self.bottleneck} | "
                f"{self.useful_flops_ratio:.2f} | "
                f"{self.roofline_fraction:.2f} |")


def analyze(name: str, compiled, *, chips: int, model_flops: float,
            bytes_per_device: Optional[float] = None) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    det = collective_bytes_from_hlo(text)
    return RooflineReport(
        name=name, chips=chips,
        # cost_analysis on the SPMD-partitioned module reports the
        # per-device program; scale to global.
        hlo_flops=flops * chips,
        hbm_bytes=hbm * chips,
        collective_bytes=float(sum(det.values())),
        collectives_detail=det,
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
    )


def model_flops_for(cfg, shape, n_params_active: int) -> float:
    """6·N_active·D for training, 2·N_active·D for inference."""
    mult = 6.0 if shape.kind == "train" else 2.0
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    return mult * n_params_active * tokens
