"""Serving driver: the memory-controller scheduler applied to requests.

The paper's scheduler batches memory requests under (batch_size, timeout)
bounds before servicing them; this driver applies the identical policy to
*inference requests*: arrivals accumulate into a prefill batch until the
batch is full or the timeout expires (``core.scheduler.form_batches`` — the
same code path the DRAM scheduler uses), then the batch is prefetched and
decoded in lockstep. Cache-line vs DMA routing maps to decode (latency-
critical, prioritized) vs prefill (bulk, throughput) — decode steps run
ahead of admitting new prefill work, mirroring the cache-priority rule.

Each served batch also drives the *modeled* memory system: the KV-cache
access stream of prefill + lockstep decode (page reads/appends per
request, stamped with open-loop arrival times) is replayed through
``MemoryController.simulate`` (ARCHITECTURE §9), so a serve run reports
modeled p50/p95/p99 memory sojourn per tenant next to the functional
outputs. ``Request.tenant`` maps to the controller port — weighted
arbitration + starvation cap is what protects a latency-SLO tenant from
a bandwidth hog sharing the controller (tests/launch/test_serve.py).

CPU-runnable demo: ``python -m repro.launch.serve --arch yi-34b --smoke``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.config import MemoryControllerConfig, SchedulerConfig
from repro.core.controller import MemoryController
from repro.core.scheduler import form_batches
from repro.models.lm import build_lm

#: KV page granularity of the modeled access stream (bytes per token row)
KV_PAGE_BYTES = 256


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    arrival_cycle: int = 0
    tenant: int = 0             # controller port this request issues from
    output: Optional[List[int]] = None


@dataclasses.dataclass
class ServeStats:
    batches: int = 0
    requests: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    wall_s: float = 0.0
    # modeled memory-system latency (FPGA cycles) of the KV access stream
    modeled_p50_cycles: float = 0.0
    modeled_p95_cycles: float = 0.0
    modeled_p99_cycles: float = 0.0
    modeled_makespan_cycles: float = 0.0
    modeled_per_tenant: Dict[int, dict] = dataclasses.field(
        default_factory=dict)
    # per-tenant SLO attainment + cycle-attribution blame (populated
    # only when the server was built with ``slo_cycles``): tenant ->
    # {n, attainment, violations, dominant_blame} where dominant_blame
    # is the attribution component (telemetry.COMPONENTS) contributing
    # the most cycles to that tenant's violating requests.
    modeled_slo_attainment: Dict[int, dict] = dataclasses.field(
        default_factory=dict)


class Server:
    """Batched prefill + lockstep decode with scheduler-based admission."""

    def __init__(self, arch: str, *, smoke: bool = False, mesh=None,
                 sched: SchedulerConfig | None = None,
                 mem: MemoryControllerConfig | None = None,
                 arb_policy: str = "round_robin",
                 arb_weights=None,
                 decode_interval_cycles: int = 64,
                 slo_cycles: float | None = None):
        self.cfg = get_arch(arch, smoke=smoke)
        if self.cfg.family == "encoder":
            raise ValueError("encoder-only architectures do not decode")
        self.lm = build_lm(self.cfg, mesh)
        self.sched = sched or SchedulerConfig(batch_size=8, timeout_cycles=32)
        self.controller = MemoryController(mem or MemoryControllerConfig())
        self.arb_policy = arb_policy
        self.arb_weights = arb_weights
        self.decode_interval_cycles = int(decode_interval_cycles)
        #: modeled per-request sojourn SLO (FPGA cycles). Setting it
        #: turns on lifecycle tracing of the KV replay so the serve
        #: stats carry per-tenant attainment + attribution blame.
        self.slo_cycles = None if slo_cycles is None else float(slo_cycles)
        self.params = self.lm.init(jax.random.key(0))
        self._prefill = jax.jit(
            lambda p, b, ml: self.lm.prefill(p, b, max_len=ml),
            static_argnums=(2,))
        self._decode = jax.jit(self.lm.decode_step)

    def admit(self, requests: List[Request]) -> List[List[Request]]:
        """Scheduler-policy batch formation over the arrival stream."""
        if not requests:
            return []
        batches = form_batches(
            addrs=[r.rid for r in requests],
            rw=[0] * len(requests),
            arrival_cycle=[r.arrival_cycle for r in requests],
            config=self.sched)
        by_id = {r.rid: r for r in requests}
        return [[by_id[int(a)] for a in b.addr] for b in batches]

    def run_batch(self, batch: List[Request], stats: ServeStats) -> None:
        S = max(len(r.prompt) for r in batch)
        prompts = np.stack([np.pad(r.prompt, (S - len(r.prompt), 0))
                            for r in batch])     # left-pad to align ends
        max_new = max(r.max_new_tokens for r in batch)
        max_len = S + max_new + 8
        logits, cache, cur = self._prefill(
            self.params, {"tokens": jnp.asarray(prompts)}, max_len)
        stats.prefill_tokens += int(prompts.size)
        outs = [[] for _ in batch]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    outs[i].append(int(tok[i]))
            logits, cache = self._decode(self.params, tok, cache, cur)
            cur = cur + 1
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            stats.decode_steps += 1
        for r, o in zip(batch, outs):
            r.output = o
        stats.batches += 1
        stats.requests += len(batch)

    def kv_trace(self, batches: List[List[Request]]):
        """Modeled KV-cache access stream of the batched-decode plan.

        Per batch: prefill appends every prompt token's KV page at the
        admission instant (the batch's last arrival); each lockstep
        decode step ``s`` then appends the new token's page and reads
        the latest context page plus one strided cold page,
        ``decode_interval_cycles`` apart. Requests keep their tenant as
        the controller port, so the stream is exactly what
        ``MemoryController.simulate`` arbitrates between tenants.
        Returns ``(pe_id, rows, rw, arrival_cycle)`` in arrival order.
        """
        pe: List[int] = []
        rows: List[int] = []
        rw: List[int] = []
        arr: List[float] = []

        def emit(r, row, is_write, t):
            pe.append(r.tenant)
            rows.append(row)
            rw.append(is_write)
            arr.append(t)

        for batch in batches:
            base = float(max(r.arrival_cycle for r in batch))
            for r in batch:
                s0 = len(r.prompt)
                kv0 = r.rid * (s0 + r.max_new_tokens + 8)
                for p in range(s0):         # prefill: write prompt KV
                    emit(r, kv0 + p, 1, base)
                for s in range(r.max_new_tokens):
                    t = base + (s + 1) * self.decode_interval_cycles
                    emit(r, kv0 + s0 + s, 1, t)        # append new page
                    emit(r, kv0 + s0 + s - 1, 0, t)    # latest context
                    emit(r, kv0 + (s * 7) % max(1, s0), 0, t)  # cold page
        order = np.argsort(np.asarray(arr, np.float64), kind="stable")
        return (np.asarray(pe, np.int64)[order],
                np.asarray(rows, np.int64)[order],
                np.asarray(rw, np.int32)[order],
                np.asarray(arr, np.float64)[order])

    def model_memory(self, batches: List[List[Request]],
                     stats: ServeStats) -> None:
        """Replay the KV stream through the memory controller's
        open-loop serving pipeline and record modeled latency.

        With ``slo_cycles`` set, the replay runs under a
        :class:`~repro.core.telemetry.TraceRecorder` and each tenant's
        SLO attainment is attributed: violating requests' sojourns are
        decomposed (:class:`~repro.core.telemetry.CycleAttribution`)
        and the dominant component — the answer to "*why* is this
        tenant missing its SLO" (arbitration starvation vs reorder
        slip vs refresh vs replay ...) — lands in the stats.
        """
        pe, rows, rw, arr = self.kv_trace(batches)
        if rows.size == 0:
            return
        trace = None
        if self.slo_cycles is not None:
            from repro.core.telemetry import TraceRecorder
            trace = TraceRecorder()
        res = self.controller.simulate(
            pe, rows, rw, KV_PAGE_BYTES,
            arbiter_policy=self.arb_policy, weights=self.arb_weights,
            arrival_cycle=arr, open_loop=True, trace=trace)
        s = res.serving
        stats.modeled_p50_cycles = s.p50_sojourn
        stats.modeled_p95_cycles = s.p95_sojourn
        stats.modeled_p99_cycles = s.p99_sojourn
        stats.modeled_makespan_cycles = res.makespan_fpga_cycles
        stats.modeled_per_tenant = s.per_port
        if trace is not None:
            from repro.core.telemetry import CycleAttribution
            att = CycleAttribution.from_pipeline(res, trace)
            for p in np.unique(att.pe_id):
                m = att.pe_id == p
                viol = m & (att.sojourn > self.slo_cycles)
                blame = None
                if viol.any():
                    blame = max(
                        ((k, float(v[viol].sum()))
                         for k, v in att.components.items()),
                        key=lambda kv: kv[1])[0]
                stats.modeled_slo_attainment[int(p)] = {
                    "n": int(m.sum()),
                    "violations": int(viol.sum()),
                    "attainment": float(1.0 - viol.sum() / m.sum()),
                    "dominant_blame": blame,
                }

    def serve(self, requests: List[Request]) -> ServeStats:
        stats = ServeStats()
        t0 = time.time()
        batches = self.admit(requests)
        for batch in batches:
            self.run_batch(batch, stats)
        self.model_memory(batches, stats)
        stats.wall_s = time.time() - t0
        return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slo-cycles", type=float, default=None,
                    help="modeled sojourn SLO; turns on per-tenant "
                         "attainment attribution")
    args = ap.parse_args()

    server = Server(args.arch, smoke=args.smoke,
                    slo_cycles=args.slo_cycles)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, server.cfg.vocab_size, args.prompt_len
                    ).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    arrival_cycle=i * 3)
            for i in range(args.requests)]
    stats = server.serve(reqs)
    print(f"[serve] {stats.requests} requests in {stats.batches} batches, "
          f"{stats.decode_steps} decode steps, "
          f"{stats.prefill_tokens} prefill tokens, {stats.wall_s:.1f}s")
    print(f"[serve] modeled KV latency (FPGA cycles): "
          f"p50={stats.modeled_p50_cycles:.1f} "
          f"p95={stats.modeled_p95_cycles:.1f} "
          f"p99={stats.modeled_p99_cycles:.1f}")
    for p, rec in sorted(stats.modeled_slo_attainment.items()):
        print(f"[serve] tenant {p}: SLO attainment "
              f"{100 * rec['attainment']:.1f}% "
              f"({rec['violations']}/{rec['n']} violations, "
              f"blame={rec['dominant_blame']})")
    print(f"[serve] sample output: {reqs[0].output}")


if __name__ == "__main__":
    main()
