"""Serving driver: the memory-controller scheduler applied to requests.

The paper's scheduler batches memory requests under (batch_size, timeout)
bounds before servicing them; this driver applies the identical policy to
*inference requests*: arrivals accumulate into a prefill batch until the
batch is full or the timeout expires (``core.scheduler.form_batches`` — the
same code path the DRAM scheduler uses), then the batch is prefetched and
decoded in lockstep. Cache-line vs DMA routing maps to decode (latency-
critical, prioritized) vs prefill (bulk, throughput) — decode steps run
ahead of admitting new prefill work, mirroring the cache-priority rule.

CPU-runnable demo: ``python -m repro.launch.serve --arch yi-34b --smoke``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.config import SchedulerConfig
from repro.core.scheduler import form_batches
from repro.models.lm import build_lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    arrival_cycle: int = 0
    output: Optional[List[int]] = None


@dataclasses.dataclass
class ServeStats:
    batches: int = 0
    requests: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    wall_s: float = 0.0


class Server:
    """Batched prefill + lockstep decode with scheduler-based admission."""

    def __init__(self, arch: str, *, smoke: bool = False, mesh=None,
                 sched: SchedulerConfig | None = None):
        self.cfg = get_arch(arch, smoke=smoke)
        if self.cfg.family == "encoder":
            raise ValueError("encoder-only architectures do not decode")
        self.lm = build_lm(self.cfg, mesh)
        self.sched = sched or SchedulerConfig(batch_size=8, timeout_cycles=32)
        self.params = self.lm.init(jax.random.key(0))
        self._prefill = jax.jit(
            lambda p, b, ml: self.lm.prefill(p, b, max_len=ml),
            static_argnums=(2,))
        self._decode = jax.jit(self.lm.decode_step)

    def admit(self, requests: List[Request]) -> List[List[Request]]:
        """Scheduler-policy batch formation over the arrival stream."""
        if not requests:
            return []
        batches = form_batches(
            addrs=[r.rid for r in requests],
            rw=[0] * len(requests),
            arrival_cycle=[r.arrival_cycle for r in requests],
            config=self.sched)
        by_id = {r.rid: r for r in requests}
        return [[by_id[int(a)] for a in b.addr] for b in batches]

    def run_batch(self, batch: List[Request], stats: ServeStats) -> None:
        S = max(len(r.prompt) for r in batch)
        prompts = np.stack([np.pad(r.prompt, (S - len(r.prompt), 0))
                            for r in batch])     # left-pad to align ends
        max_new = max(r.max_new_tokens for r in batch)
        max_len = S + max_new + 8
        logits, cache, cur = self._prefill(
            self.params, {"tokens": jnp.asarray(prompts)}, max_len)
        stats.prefill_tokens += int(prompts.size)
        outs = [[] for _ in batch]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    outs[i].append(int(tok[i]))
            logits, cache = self._decode(self.params, tok, cache, cur)
            cur = cur + 1
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            stats.decode_steps += 1
        for r, o in zip(batch, outs):
            r.output = o
        stats.batches += 1
        stats.requests += len(batch)

    def serve(self, requests: List[Request]) -> ServeStats:
        stats = ServeStats()
        t0 = time.time()
        for batch in self.admit(requests):
            self.run_batch(batch, stats)
        stats.wall_s = time.time() - t0
        return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    server = Server(args.arch, smoke=args.smoke)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, server.cfg.vocab_size, args.prompt_len
                    ).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    arrival_cycle=i * 3)
            for i in range(args.requests)]
    stats = server.serve(reqs)
    print(f"[serve] {stats.requests} requests in {stats.batches} batches, "
          f"{stats.decode_steps} decode steps, "
          f"{stats.prefill_tokens} prefill tokens, {stats.wall_s:.1f}s")
    print(f"[serve] sample output: {reqs[0].output}")


if __name__ == "__main__":
    main()
