"""Chrome-trace-event (Perfetto) export of a :class:`TraceRecorder`.

Renders the per-request lifecycle stream recorded by
``repro.core.telemetry`` into the JSON object format every Chrome
``about:tracing`` / Perfetto build ingests (ARCHITECTURE §11):

* one *process* per memory channel (``pid = channel + 1``) holding a
  ``timeline`` thread (refresh windows, outage windows, idle gaps, bus
  turnarounds as duration slices) plus one ``bank b`` thread per
  touched bank (every DRAM issue as a slice — class, attempt and ECC
  outcome in ``args``);
* one ``ports`` process (``pid = PORTS_PID``) with a thread per port
  carrying each request's whole-sojourn slice (open-loop runs only —
  closed-loop runs have no arrival stamps);
* two counter tracks per channel — ``queue_depth`` (arrived/granted
  but not completed) and ``reorder_occupancy`` (inside the reorder
  window / in service);
* ``M``-phase metadata naming every process and thread.

Timestamps are nanoseconds-derived microseconds (the trace-event
unit): DRAM-clock events map through ``t_mem_ns`` plus the uniform
pre-DRAM pipeline shift, FPGA-cycle arrival stamps through
``t_fpga_ns`` — both land on one shared timeline, so a request's
arrival, issues and completion line up across tracks.

``validate_chrome_trace`` is a dependency-free structural validator
(the CI trace-smoke step runs it on an exported golden); it raises
``ValueError`` with the offending event on any violation and returns
per-phase counts on success.
"""

from __future__ import annotations

import json

import numpy as np

#: pid of the synthetic "ports" process (channel pids are 1-based and
#: small, so this never collides).
PORTS_PID = 1000

_TIMELINE_TID = 0
_BANK_TID_BASE = 1


def _cat(kind: str) -> str:
    return {"refresh": "dram", "outage": "ras", "idle": "front",
            "turn": "dram", "issue": "dram"}.get(kind, "trace")


def to_chrome_trace(recorder, *, max_request_slices: int | None = None
                    ) -> dict:
    """Render ``recorder`` as a Chrome trace-event JSON object.

    ``max_request_slices`` truncates the per-request sojourn track (the
    only track that scales with request count rather than event count);
    ``None`` keeps every request. Truncation is recorded in
    ``otherData.request_slices_dropped`` — never silent.
    """
    if recorder.timings is None:
        raise ValueError("recorder was never finalized — run a "
                         "simulation with trace=<recorder> first")
    t_mem = float(recorder.timings.t_mem_ns)
    t_fpga = float(recorder.timings.t_fpga_ns)
    pre_ns = float(recorder.pre_fpga) * t_fpga

    def us_dram(t: float) -> float:
        return (t * t_mem + pre_ns) / 1000.0

    def us_fpga(t: float) -> float:
        return t * t_fpga / 1000.0

    ev_out: list[dict] = []
    meta: list[dict] = []

    def name_proc(pid: int, name: str) -> None:
        meta.append({"ph": "M", "name": "process_name", "pid": pid,
                     "tid": 0, "args": {"name": name}})

    def name_thread(pid: int, tid: int, name: str) -> None:
        meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                     "tid": tid, "args": {"name": name}})

    complete_us: dict[int, float] = {}     # seq -> completion (us)
    outcome_by_seq: dict[int, str] = {}

    for k, ct in sorted(recorder.channels.items()):
        pid = k + 1
        name_proc(pid, f"channel {k}")
        name_thread(pid, _TIMELINE_TID, "timeline")
        banks_seen: set[int] = set()
        # counter deltas: (ts_us, d_queue, d_reorder)
        deltas: list[tuple[float, int, int]] = []
        for e in ct.events:
            kind = e[0]
            if kind in ("refresh", "outage", "idle"):
                t0, t1 = us_dram(e[1]), us_dram(e[2])
                ev_out.append({"ph": "X", "name": kind, "cat": _cat(kind),
                               "ts": t0, "dur": max(0.0, t1 - t0),
                               "pid": pid, "tid": _TIMELINE_TID})
            elif kind == "turn":
                t0 = us_dram(e[1])
                ev_out.append({"ph": "X", "name": f"turn:{e[2]}",
                               "cat": "dram", "ts": t0,
                               "dur": e[3] * t_mem / 1000.0,
                               "pid": pid, "tid": _TIMELINE_TID,
                               "args": {"penalty_dram_clocks": int(e[3])}})
            elif kind == "issue":
                _, t, req, bank, row, cls, cost, attempt, outcome = e
                b = int(bank)
                banks_seen.add(b)
                seq = ct.resolve(req)
                ev_out.append({
                    "ph": "X", "name": f"issue:{cls}", "cat": "dram",
                    "ts": us_dram(t), "dur": cost * t_mem / 1000.0,
                    "pid": pid, "tid": _BANK_TID_BASE + b,
                    "args": {"seq": seq, "row": int(row),
                             "attempt": int(attempt),
                             "outcome": outcome}})
                outcome_by_seq[seq] = outcome
            elif kind in ("grant", "window", "readmit"):
                deltas.append((us_dram(e[1]), 0, +1))
            elif kind in ("complete", "drop"):
                t_us = us_dram(e[1])
                deltas.append((t_us, -1, -1))
                complete_us[ct.resolve(e[2])] = t_us
                if kind == "drop":
                    outcome_by_seq[ct.resolve(e[2])] = "dropped"
        for b in sorted(banks_seen):
            name_thread(pid, _BANK_TID_BASE + b, f"bank {b}")
        # arrivals (open-loop) feed the channel's queue-depth counter
        if recorder.open_loop and ct.req_ids is not None \
                and recorder.arrival_fpga is not None:
            for s in ct.req_ids.tolist():
                deltas.append((us_fpga(float(recorder.arrival_fpga[s])),
                               +1, 0))
        deltas.sort(key=lambda d: d[0])
        q = r = 0
        for ts, dq, dr in deltas:
            if dq:
                q += dq
                ev_out.append({"ph": "C", "name": f"ch{k} queue_depth",
                               "ts": ts, "pid": pid,
                               "args": {"requests": q}})
            if dr:
                r += dr
                ev_out.append({"ph": "C",
                               "name": f"ch{k} reorder_occupancy",
                               "ts": ts, "pid": pid,
                               "args": {"requests": r}})

    dropped_slices = 0
    if recorder.open_loop and recorder.arrival_fpga is not None:
        name_proc(PORTS_PID, "ports")
        pe = recorder.pe_by_seq
        n = int(recorder.arrival_fpga.shape[0])
        ports_seen: set[int] = set()
        limit = n if max_request_slices is None else max_request_slices
        for s in range(n):
            if s >= limit:
                dropped_slices = n - limit
                break
            end = complete_us.get(s)
            if end is None:
                continue
            t0 = us_fpga(float(recorder.arrival_fpga[s]))
            port = int(pe[s]) if pe is not None else 0
            ports_seen.add(port)
            ev_out.append({
                "ph": "X", "name": "request", "cat": "request",
                "ts": t0, "dur": max(0.0, end - t0),
                "pid": PORTS_PID, "tid": port,
                "args": {"seq": s,
                         "outcome": outcome_by_seq.get(s, "ok")}})
        for p in sorted(ports_seen):
            name_thread(PORTS_PID, p, f"port {p}")

    return {
        "traceEvents": meta + ev_out,
        "displayTimeUnit": "ns",
        "otherData": {
            "generator": "repro.launch.tracing",
            "num_channels": int(recorder.meta.get("num_channels", 0)),
            "open_loop": bool(recorder.open_loop),
            "n_events": int(recorder.n_events),
            "makespan_fpga_cycles": float(recorder.makespan_fpga),
            "request_slices_dropped": int(dropped_slices),
        },
    }


def validate_chrome_trace(obj) -> dict:
    """Structural validation against the trace-event JSON object format.

    Checks the envelope, then every event by phase: ``X`` slices need
    numeric non-negative ``ts``/``dur`` and integer ``pid``/``tid``;
    ``C`` counters need numeric-valued ``args``; ``M`` metadata must be
    ``process_name``/``thread_name`` with a string ``args.name``.
    Raises ``ValueError`` naming the first offending event; returns
    per-phase counts on success.
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    counts = {"X": 0, "C": 0, "M": 0}

    def bad(i, e, why):
        raise ValueError(f"traceEvents[{i}] {why}: {e!r}")

    for i, e in enumerate(events):
        if not isinstance(e, dict):
            bad(i, e, "is not an object")
        ph = e.get("ph")
        if ph not in counts:
            bad(i, e, f"has unsupported phase {ph!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            bad(i, e, "needs a non-empty string 'name'")
        if not isinstance(e.get("pid"), int):
            bad(i, e, "needs an integer 'pid'")
        if ph == "X":
            for f in ("ts", "dur"):
                v = e.get(f)
                if not isinstance(v, (int, float)) or v < 0:
                    bad(i, e, f"needs numeric non-negative {f!r}")
            if not isinstance(e.get("tid"), int):
                bad(i, e, "needs an integer 'tid'")
        elif ph == "C":
            v = e.get("ts")
            if not isinstance(v, (int, float)) or v < 0:
                bad(i, e, "needs numeric non-negative 'ts'")
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                bad(i, e, "needs a non-empty 'args' object")
            for key, val in args.items():
                if not isinstance(val, (int, float)):
                    bad(i, e, f"counter series {key!r} must be numeric")
        else:                                   # "M"
            if e["name"] not in ("process_name", "thread_name"):
                bad(i, e, "metadata name must be process_name/"
                          "thread_name")
            args = e.get("args")
            if not isinstance(args, dict) \
                    or not isinstance(args.get("name"), str):
                bad(i, e, "metadata needs args.name string")
        counts[ph] += 1
    if counts["X"] == 0:
        raise ValueError("trace has no duration slices")
    return counts


def write_chrome_trace(path, recorder, **kwargs) -> dict:
    """Export ``recorder`` to ``path`` (validated first); returns the
    validator's per-phase counts."""
    obj = to_chrome_trace(recorder, **kwargs)
    counts = validate_chrome_trace(obj)
    with open(path, "w") as fh:
        json.dump(obj, fh)
    return counts


def _to_jsonable(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, dict):
        return {k: _to_jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_to_jsonable(v) for v in x]
    return x


def write_attribution(path, attribution, top_k: int = 10) -> dict:
    """Dump a :class:`~repro.core.telemetry.CycleAttribution` rollup as
    JSON; returns the written object."""
    obj = _to_jsonable(attribution.as_dict(top_k=top_k))
    with open(path, "w") as fh:
        json.dump(obj, fh, indent=2)
    return obj
