"""Logical-axis sharding rules (t5x/MaxText style, minimal).

Arrays are annotated with *logical* axis names; ``Rules`` maps them onto
mesh axes. One place to retarget the whole framework when the mesh changes
(single-pod ``(data, model)`` vs multi-pod ``(pod, data, model)``), when a
shape degenerates (``long_500k`` has batch=1 — batch can't shard), or when
a hillclimb wants a different layout (e.g. expert-parallel MoE).

Conventions:
  activations: batch/seq/embed/heads/kv_seq
  weights:     w_fsdp (ZeRO-3 shard dim), w_tp (tensor-parallel dim),
               w_vocab_tp (vocab-sharded head), expert (MoE expert dim)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    batch: Axis = ("pod", "data")
    seq: Axis = None
    embed: Axis = None          # activation d_model: replicated (Megatron)
    heads: Axis = "model"
    kv_heads: Axis = None       # only sharded when divisible by the TP axis
    kv_seq: Axis = "model"      # decode KV cache: flash-decoding split
    vocab: Axis = "model"
    expert_capacity: Axis = "data"
    w_fsdp: Axis = "data"       # ZeRO-3: shard weights, all-gather at use
    w_tp: Axis = "model"        # Megatron TP dim
    w_vocab_tp: Axis = "model"
    expert: Axis = None         # MoE expert dim ("model" under EP)
    expert_in: Axis = "data"    # expert-weight d_model dim (FSDP under TP)
    expert_out: Axis = "model"  # expert-weight FFN dim (TP); None under EP
    layers: Axis = None         # stacked-scan leading dim

    def spec(self, *logical: Optional[str]) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
            else:
                parts.append(getattr(self, name))
        return P(*parts)


def make_rules(mesh: Optional[Mesh], *, global_batch: int = 0,
               moe_strategy: str = "tp", num_kv_heads: int = 0,
               num_heads: int = 0) -> Rules:
    """Build rules adapted to the mesh topology and workload shape.

    Head dims are only mapped to the TP axis when they divide it — a
    non-divisible constraint (8 KV heads on a 16-way axis) makes GSPMD
    invent split layouts that force involuntary full rematerialization.
    """
    if mesh is None:
        # Single-device tests: everything replicated.
        return Rules(batch=None, heads=None, kv_seq=None, vocab=None,
                     w_fsdp=None, w_tp=None, w_vocab_tp=None,
                     expert_capacity=None, expert_in=None, expert_out=None)
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    batch: Axis = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    batch_size_on_mesh = 1
    for a in (batch_axes or ()):
        batch_size_on_mesh *= mesh.shape[a]
    kv_seq: Axis = "model"
    cap: Axis = "data" if "data" in names else None
    if global_batch and global_batch < batch_size_on_mesh:
        # Degenerate batch (long_500k B=1): free the batch axes and use them
        # for the KV/state sequence dim instead.
        batch = None
        kv_seq = tuple(a for a in ("data", "model") if a in names)
        cap = None
    expert: Axis = None
    expert_in: Axis = "data"
    expert_out: Axis = "model"
    if moe_strategy == "ep":
        # shard_map all-to-all dispatch (models/moe_ep.py): experts live
        # whole on their owner shard, replicated over data
        expert, expert_in, expert_out = "model", None, None
    tp = mesh.shape.get("model", 1)
    heads_ax: Axis = "model" if (num_heads == 0 or num_heads % tp == 0) \
        else None
    kv_ax: Axis = "model" if (num_kv_heads and num_kv_heads % tp == 0) \
        else None
    return Rules(batch=batch, kv_seq=kv_seq, expert=expert,
                 expert_in=expert_in, expert_out=expert_out,
                 expert_capacity=cap, heads=heads_ax, kv_heads=kv_ax)


def serving_weight_overrides(cfg, global_batch: int,
                             mesh: Optional[Mesh]) -> dict:
    """Rule overrides for the serve path (§Perf, granite-decode hillclimb).

    Batched *dense* decode replicates weights across the data axis — the
    per-step ZeRO-3 all-gathers (11 GB/dev/step measured on granite) cost
    more than the extra HBM reads. Batch-1 long-context decode and MoE
    serving keep 2D (FSDP x TP) weight sharding: with tiny activations the
    psum'd 256-way-sharded matmuls read 16x less weight per device, which
    measured 5-25x better on long_500k, and MoE expert weights are too
    large to replicate profitably.
    """
    if mesh is None or cfg.moe is not None:
        return {}
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    return {"w_fsdp": None} if global_batch >= dp else {}


def shard(x, rules: Rules, *logical, mesh: Optional[Mesh] = None):
    """with_sharding_constraint by logical names (no-op off-mesh)."""
    if mesh is None or not _in_jit():
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.spec(*logical)))


def _in_jit() -> bool:
    return True  # constraints are harmless outside jit in recent JAX


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
