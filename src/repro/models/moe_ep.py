"""Expert-parallel MoE dispatch via shard_map all-to-all.

The paper's DMA engine, at cluster scale: each model shard owns
``E / tp`` experts; token requests are *sorted by destination shard* (the
scheduler's row = the expert's owner), packed into per-destination staging
buffers (the DMA buffers), and moved with one ``all_to_all`` bulk transfer
instead of scattered traffic. Everything inside the shard_map body is
device-local, which sidesteps the GSPMD scatter-partitioning limits the
§Perf log documents for the pure-pjit expert sharding.

Token layout: activations arrive model-replicated (Megatron convention);
the body first claims a 1/tp slice of its tokens per model shard (2D
data x model token sharding for the MoE block), dispatches with one
all_to_all each way, and all-gathers the combined outputs back to the
replicated layout — the gather replaces the dense path's output psum.

Scope: requires ``num_experts % tp == 0`` and no shared experts (jamba:
16e on the 16-way model axis → one expert per shard, Switch-style).
Capacity: per-(source, destination) send capacity — the paper's bounded
per-controller batches; dropped requests contribute zero, as in the TP
path.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.core import capture as capture_mod
from repro.models import layers


def moe_ffn_ep(p, x, cfg: ArchConfig, mesh, *, no_drop: bool = False):
    """EP replacement for the routed part of ``blocks.moe_ffn``.

    Returns (out, aux). Value-matches the TP dispatch at ample capacity
    (property-tested on a multi-device mesh); drop behaviour differs
    (per-destination send capacity vs per-expert capacity), inherent to EP.
    """
    m = cfg.moe
    assert m.num_shared_experts == 0, "EP path: no shared experts"
    tp = mesh.shape["model"]
    assert m.num_experts % tp == 0, "EP needs E % tp == 0"
    e_loc = m.num_experts // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    all_axes = batch_axes + ("model",)

    B, S, D = x.shape

    # Trace capture happens out here: the shard_map body only ever sees
    # tracers, so the router is re-evaluated eagerly (capture-only —
    # never feeds the data plane) to report the global dispatch.
    if capture_mod.active_capture() is not None \
            and capture_mod.is_concrete(x):
        xn_g = layers.rms_norm(x, p["ln"]).reshape(B * S, D)
        probs_g = jax.nn.softmax(
            (xn_g @ p["router"]).astype(jnp.float32), axis=-1)
        _, top_e_g = jax.lax.top_k(probs_g, m.top_k)
        from repro.models.blocks import capture_moe_dispatch
        capture_moe_dispatch(top_e_g, B * S, D, jnp.dtype(x.dtype).itemsize)

    @partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(
            {"ln": P(), "router": P(),
             "w_gate": P("model", None, None),
             "w_up": P("model", None, None),
             "w_down": P("model", None, None)},
            P(batch_axes, None, None),
        ),
        out_specs=(P(batch_axes, None, None), {"load_balance": P(),
                                               "router_z": P()}),
        # outputs ARE replicated over 'model' (all_gather / pmean above)
        # but the static VMA checker cannot prove it
        check_vma=False,
    )
    def body(pl, xl):
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        assert T % tp == 0, "tokens per data shard must divide the TP axis"
        t_loc = T // tp
        my = jax.lax.axis_index("model")

        xn = layers.rms_norm(xl, pl["ln"])
        # claim this model shard's token slice (2D token sharding)
        flat = jax.lax.dynamic_slice_in_dim(
            xn.reshape(T, D), my * t_loc, t_loc, axis=0)

        logits = (flat @ pl["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        # aux losses over the global batch (mean over every shard's slice)
        me = jax.lax.pmean(probs.mean(0), all_axes)
        counts = jnp.zeros((m.num_experts,), jnp.float32).at[
            top_e.reshape(-1)].add(1.0) / (t_loc * m.top_k)
        ce = jax.lax.pmean(counts, all_axes)
        aux = {
            "load_balance": m.num_experts * jnp.sum(me * ce),
            "router_z": m.router_z_coef * jax.lax.pmean(
                jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2), all_axes),
        }

        # ---- scheduler: sort requests by destination shard (row owner) ---
        n = t_loc * m.top_k
        e_flat = top_e.reshape(-1)
        if no_drop:
            c_send = n
        else:
            c_send = int(math.ceil(n / tp * m.capacity_factor))
            if c_send >= 64:
                c_send = -(-c_send // 128) * 128
            c_send = min(n, c_send)
        owner = e_flat // e_loc                       # destination shard
        order = jnp.argsort(owner, stable=True)       # bitonic analogue
        owner_s = jnp.take(owner, order)
        run_start = jnp.searchsorted(owner_s, jnp.arange(tp))
        pos = (jnp.arange(n) - jnp.take(run_start, owner_s)
               ).astype(jnp.int32)
        slot = jnp.where(pos < c_send, pos, c_send)   # drop slot

        tok_of = jnp.take(jnp.repeat(jnp.arange(t_loc), m.top_k), order)
        eid_of = jnp.take(e_flat % e_loc, order)      # local expert id

        send_tok = jnp.zeros((tp, c_send + 1, D), xl.dtype
                             ).at[owner_s, slot].set(flat[tok_of],
                                                     mode="drop")
        send_eid = jnp.full((tp, c_send + 1), e_loc, jnp.int32
                            ).at[owner_s, slot].set(eid_of, mode="drop")

        # ---- bulk transfer: one all_to_all instead of scattered traffic --
        recv_tok = jax.lax.all_to_all(send_tok[:, :c_send], "model", 0, 0,
                                      tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid[:, :c_send], "model", 0, 0,
                                      tiled=False)
        rt = recv_tok.reshape(tp * c_send, D)
        re = recv_eid.reshape(tp * c_send)

        # ---- local expert compute (everything device-local) --------------
        w_g, w_u, w_d = pl["w_gate"], pl["w_up"], pl["w_down"]
        valid = (re < e_loc)[:, None]
        if e_loc == 1:
            h = jax.nn.silu(rt @ w_g[0]) * (rt @ w_u[0])
            out_tok = jnp.where(valid, h @ w_d[0], 0.0).astype(xl.dtype)
        else:
            # small local expert count: contract through the one-hot —
            # (rows, e_loc) x (e_loc, D, F) — without materializing
            # per-token weight gathers
            onehot = jax.nn.one_hot(re, e_loc, dtype=rt.dtype)
            h = jax.nn.silu(jnp.einsum("nd,ne,edf->nf", rt, onehot, w_g)) \
                * jnp.einsum("nd,ne,edf->nf", rt, onehot, w_u)
            out_tok = jnp.einsum("nf,ne,efd->nd", h, onehot, w_d
                                 ).astype(xl.dtype)
            out_tok = jnp.where(valid, out_tok, 0)

        # ---- reverse bulk transfer + writeback in arrival order ----------
        back = jax.lax.all_to_all(out_tok.reshape(tp, c_send, D),
                                  "model", 0, 0, tiled=False)
        back = jnp.pad(back, ((0, 0), (0, 1), (0, 0)))  # re-add drop slot
        y_sorted = back[owner_s, slot]                 # (n, D)
        y = jnp.zeros((n, D), xl.dtype).at[order].set(y_sorted)
        y = y * top_p.reshape(-1)[:, None].astype(xl.dtype)
        y = y.reshape(t_loc, m.top_k, D).sum(1)        # my token slice

        # restore the model-replicated activation layout
        y_full = jax.lax.all_gather(y, "model", axis=0, tiled=True)
        return y_full.reshape(Bl, Sl, D), aux

    return body(p, x)
