"""Unified LM assembly for all assigned architectures.

One ``LM`` class executes every family (dense/SSM/MoE/hybrid/encoder/VLM)
by walking the config's layer pattern. Layers are stacked and executed with
``lax.scan`` over pattern periods — one period of HLO regardless of depth,
which keeps 88-layer dry-run compiles fast — with ``jax.checkpoint`` remat
inside the scan for training.

Entry points (the shape cells map onto these):
  ``loss``        → train_4k        (fwd+CE; train_step wraps with grad/opt)
  ``prefill``     → prefill_32k     (full forward, returns serve cache)
  ``decode_step`` → decode_32k / long_500k (one token, cache update)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks, layers
from repro.models.blocks import AttnCache, MambaCache
from repro.models.params import (abstract_params, init_params, mamba_dims,
                                 param_specs)
from repro.models.sharding import Rules, make_rules, shard


@dataclasses.dataclass
class LM:
    cfg: ArchConfig
    rules: Rules
    mesh: Any = None
    moe_strategy: str = "tp"

    # ---------------- params ------------------------------------------------
    def init(self, key):
        return init_params(self.cfg, key)

    def abstract_params(self):
        return abstract_params(self.cfg)

    def param_specs(self):
        return param_specs(self.cfg, self.rules)

    # ---------------- input embedding --------------------------------------
    @staticmethod
    def _capture_frontend(op: str, frames) -> None:
        """Report an audio/vision frontend's (B, S, F) embedding stream as
        sequential bulk reads — one page per frame/patch, one port per
        sequence. Purely observational (the data plane is the matmul
        below); skipped under tracing like every capture hook."""
        from repro.core import capture as capture_mod
        cap = capture_mod.active_capture()
        if cap is None:
            return
        if not capture_mod.is_concrete(frames):
            cap.n_skipped_traced += 1
            return
        import numpy as np
        B, S, F = frames.shape
        page_bytes = int(F) * int(jnp.dtype(frames.dtype).itemsize)
        cap.record(op, f"{op}:{B * S}x{page_bytes}", B * S, page_bytes,
                   np.arange(B * S, dtype=np.int64), rw=0,
                   pe_id=np.repeat(np.arange(B, dtype=np.int64), S))

    def _embed_inputs(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x (B,S,D), loss_mask (B,S))."""
        cfg = self.cfg
        if cfg.modality == "audio":
            frames = batch["frames"]
            self._capture_frontend("audio_frames", frames)
            x = frames @ params["connector"]["w"]
            x = layers.rms_norm(x, params["connector"]["ln"])
            mask = jnp.ones(x.shape[:2], jnp.float32)
        elif cfg.modality == "vision_text":
            self._capture_frontend("vision_patches", batch["vision_embeds"])
            vis = batch["vision_embeds"] @ params["connector"]["w"]
            vis = layers.rms_norm(vis, params["connector"]["ln"])
            txt = layers.mc_embed(params["embed"]["table"], batch["tokens"],
                                  cfg.mc)
            x = jnp.concatenate([vis.astype(txt.dtype), txt], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(vis.shape[:2], jnp.float32),
                 jnp.ones(txt.shape[:2], jnp.float32)], axis=1)
        else:
            x = layers.mc_embed(params["embed"]["table"], batch["tokens"],
                                cfg.mc)
            mask = jnp.ones(x.shape[:2], jnp.float32)
        if "loss_mask" in batch:
            pad = mask.shape[1] - batch["loss_mask"].shape[1]
            lm_mask = jnp.pad(batch["loss_mask"].astype(jnp.float32),
                              ((0, 0), (pad, 0)))
            mask = mask * lm_mask
        x = shard(x, self.rules, "batch", "seq", "embed", mesh=self.mesh)
        return x, mask

    def embedding_grad_update(self, params, tokens: jnp.ndarray,
                              grad_rows: jnp.ndarray, lr: float = 1.0):
        """Apply a sparse embedding update through the controller write path.

        ``grad_rows`` holds one gradient row per token occurrence (the
        backward of ``mc_embed``); rows for repeated tokens accumulate —
        the controller's scheduler stable-sorts the WRITE batch by row and
        coalesces duplicates before touching HBM (``mc_scatter``,
        mode="add"). Value-identical to
        ``table.at[tokens].add(-lr * grad_rows)``. Returns params with the
        updated table; every other leaf is shared, not copied.
        """
        table = params["embed"]["table"]
        new_table = layers.mc_scatter(
            table, tokens, (-lr * grad_rows).astype(table.dtype),
            self.cfg.mc, mode="add")
        return {**params, "embed": {**params["embed"], "table": new_table}}

    def _full_labels(self, batch, S: int) -> jnp.ndarray:
        labels = batch["labels"]
        pad = S - labels.shape[1]
        if pad:
            labels = jnp.pad(labels, ((0, 0), (pad, 0)))   # vision prefix
        return labels

    def _moe_groups(self, x) -> int:
        """Scheduler instances for MoE dispatch = data-parallel shards of
        the token batch (per-controller bounded batches, paper §II). Falls
        back to 1 (global scheduler) off-mesh or when batch doesn't
        divide."""
        if self.mesh is None:
            return 1
        axes = self.rules.batch
        if axes is None:
            return 1
        axes = (axes,) if isinstance(axes, str) else axes
        g = 1
        for a in axes:
            g *= self.mesh.shape[a]
        B = x.shape[0]
        return g if g > 0 and B % g == 0 else 1

    # ---------------- block walker ------------------------------------------
    def _run_block(self, bp, x, layer_pos: int, positions,
                   mode: str, cache=None, cur_len=None):
        """One (mixer, ffn) sub-block with residuals.

        Returns (x, aux_losses, new_cache)."""
        cfg, rules, mesh = self.cfg, self.rules, self.mesh
        aux = {"load_balance": jnp.zeros((), jnp.float32),
               "router_z": jnp.zeros((), jnp.float32)}
        new_cache = {}
        if "attn" in bp:
            if mode == "decode":
                out, kv = blocks.attn_decode(bp["attn"], x, cache["attn"],
                                             cur_len, cfg, rules, mesh)
            else:
                out, kv = blocks.attn_forward(bp["attn"], x, cfg, rules,
                                              mesh, positions)
            x = x + out
            new_cache["attn"] = kv
        elif "mamba" in bp:
            if mode == "decode":
                out, mc = blocks.mamba_decode(bp["mamba"], x, cache["mamba"],
                                              cfg, rules, mesh)
            else:
                out, mc = blocks.mamba_forward(bp["mamba"], x, cfg, rules,
                                               mesh)
            x = x + out
            new_cache["mamba"] = mc
        if "mlp" in bp:
            if mode == "decode":
                x = x + blocks.mlp_forward(bp["mlp"], x[:, None, :],
                                           self.rules, mesh)[:, 0]
            else:
                x = x + blocks.mlp_forward(bp["mlp"], x, self.rules, mesh)
        elif "moe" in bp:
            xin = x[:, None, :] if mode == "decode" else x
            if self.moe_strategy == "ep":
                from repro.models.moe_ep import moe_ffn_ep
                out, moe_aux = moe_ffn_ep(bp["moe"], xin, cfg, mesh,
                                          no_drop=(mode == "decode"))
            else:
                out, moe_aux = blocks.moe_ffn(
                    bp["moe"], xin, cfg, self.rules, mesh,
                    no_drop=(mode == "decode"), dispatch=cfg.moe_dispatch,
                    num_groups=self._moe_groups(xin))
            x = x + (out[:, 0] if mode == "decode" else out)
            aux = moe_aux
        return x, aux, new_cache

    def _scan_layers(self, params, x, positions, mode: str,
                     cache=None, cur_len=None):
        """Scan the stacked layer groups. Returns (x, aux, caches)."""
        cfg = self.cfg
        period = cfg.scan_period

        def group_fn(x, xs):
            gp, gcache = xs
            auxes, ncaches = [], {}
            for pos in range(period):
                c = None if gcache is None else gcache.get(f"pos{pos}")
                x, aux, nc = self._run_block(
                    gp[f"pos{pos}"], x, pos, positions, mode,
                    cache=c, cur_len=cur_len)
                auxes.append(aux)
                if mode != "train":       # train never materializes caches
                    ncaches[f"pos{pos}"] = nc
            aux = jax.tree.map(lambda *a: sum(a), *auxes)
            return x, (aux, ncaches)

        fn = group_fn
        if cfg.remat and mode == "train":
            policy = (jax.checkpoint_policies.dots_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            fn = jax.checkpoint(group_fn, policy=policy)

        xs = (params["layers"], cache)
        if cfg.scan_layers:
            x, (aux, caches) = jax.lax.scan(fn, x, xs)
            aux = jax.tree.map(jnp.sum, aux)
            return x, aux, caches
        # Unrolled path (dry-run cost extrapolation / tiny models): walk the
        # stacked groups in Python, then restack outputs like scan would.
        groups = jax.tree.leaves(params["layers"])[0].shape[0]
        auxes, caches_list = [], []
        for g in range(groups):
            xs_g = jax.tree.map(lambda t: t[g], xs)
            x, (aux, ncache) = fn(x, xs_g)
            auxes.append(aux)
            caches_list.append(ncache)
        aux = jax.tree.map(lambda *a: jnp.sum(jnp.stack(a)), *auxes)
        caches = jax.tree.map(lambda *c: jnp.stack(c), *caches_list) \
            if caches_list and jax.tree.leaves(caches_list[0]) else {}
        return x, aux, caches

    # ---------------- public entry points -----------------------------------
    def _backbone(self, params, batch):
        """Embed → layers → final norm. Returns (hidden, aux, mask)."""
        x, mask = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux, _ = self._scan_layers(params, x, positions, "train")
        return layers.rms_norm(x, params["final_norm"]), aux, mask

    def _forward_full(self, params, batch):
        x, aux, mask = self._backbone(params, batch)
        logits = x @ params["lm_head"]
        logits = shard(logits, self.rules, "batch", "seq", "vocab",
                       mesh=self.mesh)
        return logits, aux, mask

    def forward(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        logits, aux, _ = self._forward_full(params, batch)
        return logits, aux

    def _ce_terms(self, logits, labels, mask):
        """(Σ masked CE, Σ masked logz², Σ mask) in fp32, padding masked."""
        cfg = self.cfg
        lg = logits.astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            col = jnp.arange(cfg.padded_vocab)
            lg = jnp.where(col < cfg.vocab_size, lg, -1e30)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        return (((logz - gold) * mask).sum(),
                ((logz * mask) ** 2).sum(), mask.sum())

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        x, aux, x_mask = self._backbone(params, batch)
        B, S, _ = x.shape
        labels = self._full_labels(batch, S)

        if cfg.loss_chunks:
            # Chunked CE: per-chunk logits live only inside a checkpointed
            # region (recomputed in backward) — the full (B,S,V) tensor
            # never reaches HBM. Python-unrolled so HLO cost accounting
            # stays exact (scan bodies are billed once by XLA).
            n = cfg.loss_chunks
            C = -(-S // n)

            def chunk_terms(xc, lc, mc):
                logits = xc @ params["lm_head"]
                return self._ce_terms(logits, lc, mc)

            chunk_fn = jax.checkpoint(chunk_terms)
            ce_sum = z_sum = m_sum = 0.0
            for i in range(n):
                sl = slice(i * C, min((i + 1) * C, S))
                c, z, m = chunk_fn(x[:, sl], labels[:, sl], x_mask[:, sl])
                ce_sum, z_sum, m_sum = ce_sum + c, z_sum + z, m_sum + m
        else:
            logits = x @ params["lm_head"]
            logits = shard(logits, self.rules, "batch", "seq", "vocab",
                           mesh=self.mesh)
            ce_sum, z_sum, m_sum = self._ce_terms(logits, labels, x_mask)

        denom = jnp.maximum(m_sum, 1.0)
        loss = ce_sum / denom
        z_loss = 1e-4 * z_sum / denom
        total = loss + z_loss
        if cfg.moe is not None or cfg.family == "hybrid":
            total = total + 1e-2 * aux["load_balance"] + aux["router_z"]
        metrics = {"ce_loss": loss, "z_loss": z_loss, **aux}
        return total, metrics

    # ---------------- serving -----------------------------------------------
    def _cache_len(self, max_len: int) -> int:
        w = self.cfg.attn_window
        return min(w, max_len) if w is not None else max_len

    def init_cache(self, batch_size: int, max_len: int, abstract=False):
        """Zero (or abstract) serve cache matching the layer pattern."""
        cfg = self.cfg
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        C = self._cache_len(max_len)
        groups = cfg.num_layers // cfg.scan_period
        dt = jnp.dtype(cfg.param_dtype)

        def make(shape, dtype):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dtype)
            return jnp.zeros(shape, dtype)

        cache = {}
        for pos in range(cfg.scan_period):
            mixer, _ = cfg.layer_kinds(pos)
            if mixer == "attn":
                if cfg.kv_cache_dtype == "int8":
                    cache[f"pos{pos}"] = {"attn": blocks.QuantAttnCache(
                        k=make((groups, batch_size, C, kv, hd), jnp.int8),
                        v=make((groups, batch_size, C, kv, hd), jnp.int8),
                        k_scale=make((groups, batch_size, C, kv),
                                     jnp.float32),
                        v_scale=make((groups, batch_size, C, kv),
                                     jnp.float32))}
                    continue
                cache[f"pos{pos}"] = {"attn": AttnCache(
                    k=make((groups, batch_size, C, kv, hd), dt),
                    v=make((groups, batch_size, C, kv, hd), dt))}
            else:
                d_in, H, P, N = mamba_dims(cfg)
                cache[f"pos{pos}"] = {"mamba": MambaCache(
                    conv_x=make((groups, batch_size, 3, d_in), dt),
                    conv_b=make((groups, batch_size, 3, N), dt),
                    conv_c=make((groups, batch_size, 3, N), dt),
                    ssm=make((groups, batch_size, H, P, N), jnp.float32))}
        return cache

    def cache_specs(self):
        """PartitionSpecs congruent with init_cache output."""
        r = self.rules
        cfg = self.cfg
        specs = {}
        for pos in range(cfg.scan_period):
            mixer, _ = cfg.layer_kinds(pos)
            if mixer == "attn":
                kv_spec = r.spec("layers", "batch", "kv_seq", None, None)
                if cfg.kv_cache_dtype == "int8":
                    specs[f"pos{pos}"] = {"attn": blocks.QuantAttnCache(
                        k=kv_spec, v=kv_spec,
                        k_scale=r.spec("layers", "batch", "kv_seq", None),
                        v_scale=r.spec("layers", "batch", "kv_seq", None))}
                    continue
                specs[f"pos{pos}"] = {"attn": AttnCache(k=kv_spec,
                                                        v=kv_spec)}
            else:
                specs[f"pos{pos}"] = {"mamba": MambaCache(
                    conv_x=r.spec("layers", "batch", None, "heads"),
                    conv_b=r.spec("layers", "batch", None, None),
                    conv_c=r.spec("layers", "batch", None, None),
                    ssm=r.spec("layers", "batch", "heads", None, None))}
        return specs

    def prefill(self, params, batch, max_len: int):
        """Full-context forward; returns (last_logits, cache, cur_len)."""
        cfg = self.cfg
        x, _ = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, _, raw_caches = self._scan_layers(params, x, positions, "prefill")

        # convert per-layer prefill KV into serve layout (ring for SWA)
        def convert(sub):
            out = {}
            for k, v in sub.items():
                if "attn" in v:
                    out[k] = {"attn": blocks.attn_prefill_cache(
                        v["attn"], cfg, S, max_len)}
                else:
                    out[k] = v
            return out

        cache = convert(raw_caches)
        xn = layers.rms_norm(x[:, -1], params["final_norm"])
        logits = (xn @ params["lm_head"])[:, :cfg.vocab_size]
        return logits, cache, jnp.asarray(S, jnp.int32)

    def decode_step(self, params, token: jnp.ndarray, cache,
                    cur_len: jnp.ndarray):
        """One serve step: embed token (B,), walk layers, update cache."""
        cfg = self.cfg
        # The 1-D decode token stream is controller traffic too: one
        # scheduler batch through mc_embed, not a raw bypassing take.
        x = layers.mc_embed(params["embed"]["table"], token, cfg.mc)
        x, _, new_cache = self._scan_layers(params, x, None, "decode",
                                            cache=cache, cur_len=cur_len)
        xn = layers.rms_norm(x, params["final_norm"])
        logits = xn @ params["lm_head"]
        logits = shard(logits, self.rules, "batch", "vocab", mesh=self.mesh)
        return logits[:, :cfg.vocab_size], new_cache


def build_lm(cfg: ArchConfig, mesh=None, *, global_batch: int = 0,
             moe_strategy: str = "tp") -> LM:
    if moe_strategy == "ep":
        if mesh is None or cfg.moe is None:
            raise ValueError("moe_strategy='ep' needs a mesh and an MoE "
                             "architecture")
        tp = mesh.shape["model"]
        if cfg.moe.num_experts % tp or cfg.moe.num_shared_experts:
            raise ValueError(
                f"EP dispatch needs num_experts % {tp} == 0 and no shared "
                f"experts (got {cfg.moe.num_experts}e/"
                f"{cfg.moe.num_shared_experts}shared); use 'tp'")
    rules = make_rules(mesh, global_batch=global_batch,
                       moe_strategy=moe_strategy,
                       num_kv_heads=cfg.num_kv_heads,
                       num_heads=cfg.num_heads)
    return LM(cfg=cfg, rules=rules, mesh=mesh, moe_strategy=moe_strategy)
