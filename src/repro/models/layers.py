"""Shared NN layers: norms, RoPE, memory-efficient attention, embeddings.

Attention comes in two forms:

* ``flash_attention`` — train/prefill path. Double-blocked online-softmax
  attention (q-blocks outer scan, kv-blocks inner scan) so the score matrix
  never materializes; this is the XLA expression of the paper's DMA engine
  streaming KV through VMEM-sized staging buffers. Causal, bidirectional
  and sliding-window masks supported. The Pallas twin lives in
  ``repro.kernels.flash_attention``.
* ``decode_attention`` — one-token serve path against a (possibly
  ring-buffered) KV cache; works with the cache sequence dim sharded across
  the mesh (flash-decoding style distributed softmax — XLA inserts the
  small all-reduces for max/sum).

Embedding traffic routes through the memory controller in both
directions: lookups via ``mc_embed`` (token ids stable-sorted per sequence
before the table gather) and table updates via ``mc_scatter`` (the
embedding-gradient WRITE stream, batch-sorted and coalesced per row);
``mc_kv_append`` is the decode-step KV page write on the DMA bulk path.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capture as capture_mod
from repro.core.config import MemoryControllerConfig


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------------
# Memory-efficient attention (XLA path)
# ---------------------------------------------------------------------------

def _mask_value(dtype):
    return jnp.asarray(-0.7 * jnp.finfo(jnp.float32).max, jnp.float32)


def flash_attention(
    q: jnp.ndarray,               # (B, S, H, hd)
    k: jnp.ndarray,               # (B, S, KV, hd)
    v: jnp.ndarray,               # (B, S, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention; O(S·block) memory instead of O(S²)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV                   # GQA group size
    scale = hd ** -0.5

    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    # pad S to multiples
    Sq = -(-S // q_block) * q_block
    Sk = -(-S // kv_block) * kv_block
    qp = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))

    # (B, KV, G, S, hd) grouped layout
    qg = qp.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
    kg = kp.transpose(0, 2, 1, 3)  # (B, KV, Sk, hd)
    vg = vp.transpose(0, 2, 1, 3)

    nq, nk = Sq // q_block, Sk // kv_block
    neg = _mask_value(q.dtype)

    def q_step(_, qi):
        qi0 = qi * q_block
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi0, q_block, axis=3)
        q_pos = qi0 + jnp.arange(q_block)

        def kv_step(carry, ki):
            o, m, l = carry
            ki0 = ki * kv_block
            k_blk = jax.lax.dynamic_slice_in_dim(kg, ki0, kv_block, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(vg, ki0, kv_block, axis=2)
            k_pos = ki0 + jnp.arange(kv_block)

            s = jnp.einsum("bkgqd,bkcd->bkgqc", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = k_pos[None, :] <= (q_pos[:, None] if causal
                                      else jnp.full_like(q_pos[:, None],
                                                         Sk - 1))
            if window is not None:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
            mask &= (k_pos < S)[None, :]
            s = jnp.where(mask[None, None, None], s, neg)

            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqc,bkcd->bkgqd", p, v_blk,
                            preferred_element_type=jnp.float32)
            o_new = o * corr[..., None] + pv
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_step, (o0, m0, l0), jnp.arange(nk))
        out_blk = o / jnp.maximum(l[..., None], 1e-37)
        return None, out_blk.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))
    # blocks: (nq, B, KV, G, q_block, hd) → (B, S, H, hd)
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, Sq, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out[:, :S]


def decode_attention(
    q: jnp.ndarray,               # (B, H, hd) — one new token per sequence
    cache_k: jnp.ndarray,         # (B, Sc, KV, hd)
    cache_v: jnp.ndarray,
    valid_mask: jnp.ndarray,      # (B, Sc) bool — which cache slots attend
) -> jnp.ndarray:
    B, H, hd = q.shape
    KV = cache_k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid_mask[:, None, None, :], s, _mask_value(q.dtype))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, cache_v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Controller-routed embedding
# ---------------------------------------------------------------------------

def _embed_region(table: jnp.ndarray) -> tuple:
    """(region_name, n_rows, row_bytes) of an embedding table — shared by
    ``mc_embed`` (READ) and ``mc_scatter`` (WRITE) so both directions of
    embedding traffic land on the same captured rows."""
    n_rows = int(table.shape[0])
    row_bytes = int(table.shape[-1]) * int(np.dtype(table.dtype).itemsize)
    return f"embed:{n_rows}x{row_bytes}", n_rows, row_bytes


def _capture_embed(op: str, table, tokens, rw: int) -> None:
    cap = capture_mod.active_capture()
    if cap is None:
        return
    name, n_rows, row_bytes = _embed_region(table)
    shape = tuple(tokens.shape)
    if len(shape) >= 2:
        # one port per sequence (leading dims flattened): the multi-PE
        # front end sees each sequence's token stream on its own port
        lead = int(np.prod(shape[:-1]))
        pe = np.repeat(np.arange(lead, dtype=np.int64), shape[-1])
    else:
        pe = 0          # single-sequence / decode stream — one port
    cap.record(op, name, n_rows, row_bytes, tokens, rw=rw, pe_id=pe)


def mc_embed(table: jnp.ndarray, tokens: jnp.ndarray,
             mc: MemoryControllerConfig) -> jnp.ndarray:
    """Embedding gather through the memory controller's scheduler.

    Requests are stable-sorted *per sequence* (axis -1) — each sequence is
    one scheduler batch, matching the paper's bounded batch size. 1-D (and
    scalar) token streams — the decode-step path — are one sequence, so
    the whole stream forms a single scheduler batch instead of bypassing
    the controller. Value-identical to ``table[tokens]``.
    """
    _capture_embed("embed_gather", table, tokens, rw=0)
    if not mc.scheduler.enabled:
        return jnp.take(table, tokens, axis=0)
    if tokens.ndim < 2:
        flat = tokens.reshape(-1)
        perm = jnp.argsort(flat, stable=True)
        gathered = jnp.take(table, jnp.take(flat, perm, axis=0), axis=0)
        inv = jnp.argsort(perm, stable=True)
        out = jnp.take(gathered, inv, axis=0)
        return out.reshape(*tokens.shape, table.shape[-1])
    perm = jnp.argsort(tokens, axis=-1, stable=True)
    sorted_tok = jnp.take_along_axis(tokens, perm, axis=-1)
    gathered = jnp.take(table, sorted_tok, axis=0)
    inv = jnp.argsort(perm, axis=-1, stable=True)
    return jnp.take_along_axis(gathered, inv[..., None], axis=-2)


def mc_scatter(table: jnp.ndarray, tokens: jnp.ndarray,
               values: jnp.ndarray, mc: MemoryControllerConfig,
               *, mode: str = "add") -> jnp.ndarray:
    """Embedding write through the memory controller's scheduler.

    The write-side twin of :func:`mc_embed`: the backward of an embedding
    lookup is an irregular scatter of per-token rows into the table
    (gradient accumulation, ``mode="add"``), the same WRITE stream the
    controller batch-sorts by row. Value-identical to
    ``table.at[tokens].add(values)`` / last-writer-wins ``set``.
    """
    from repro.core.controller import MemoryController
    _capture_embed("embed_scatter", table, tokens, rw=1)
    return MemoryController(mc).scatter(table, tokens, values, mode=mode)


def mc_kv_append(buf: jnp.ndarray, new: jnp.ndarray, slot,
                 mc: MemoryControllerConfig, axis: int = 1) -> jnp.ndarray:
    """One decode-step KV append — the controller's bulk-write request
    class.

    A cache row is a contiguous page, so the append is classified as a
    bulk/streaming write (cache-bypassing), not an irregular scatter;
    its DRAM cost is what ``benchmarks/fig7_write_workloads.py`` models.
    The data-plane transport is the default dynamic-update for every
    engine setting; ``mc`` marks the request class, which the capture
    hook reports as ``kv_append`` bulk-write records (op label suffixed
    ``_dma`` when the config's DMA engine owns the stream) — never
    affecting stored values.
    """
    cap = capture_mod.active_capture()
    if cap is not None:
        pages = int(buf.shape[axis])
        n_new = int(new.shape[axis])
        page_bytes = (int(np.prod(new.shape)) // max(1, n_new)
                      * int(np.dtype(new.dtype).itemsize))
        op = "kv_append_dma" if mc.dma.enabled else "kv_append"
        cap.record_slice(op, f"kv:{pages}x{page_bytes}", pages, page_bytes,
                         slot, n_new, rw=1)
    return jax.lax.dynamic_update_slice_in_dim(buf, new, slot, axis)
