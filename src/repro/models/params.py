"""Parameter declarations: one tree drives init, sharding specs, and
abstract (ShapeDtypeStruct) instantiation for the dry-run.

Every parameter is declared once as a ``ParamDecl`` (shape + logical axes +
initializer). ``init_params`` materializes it, ``param_specs`` maps logical
axes through the active ``Rules``, and ``abstract_params`` produces
allocation-free stand-ins — guaranteed tree-congruent because they traverse
the same declarations.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.sharding import Rules


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | ssm_a | dt_bias
    fan_in: Optional[int] = None  # scale 1/sqrt(fan_in); default shape[0]


def _d(shape, logical, init="normal", fan_in=None):
    return ParamDecl(tuple(shape), tuple(logical), init, fan_in)


def _attn_decls(cfg: ArchConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "ln": _d((d,), (None,), "ones"),
        "wq": _d((d, h * hd), ("w_fsdp", "w_tp")),
        "wk": _d((d, kv * hd), ("w_fsdp", "w_tp")),
        "wv": _d((d, kv * hd), ("w_fsdp", "w_tp")),
        "wo": _d((h * hd, d), ("w_tp", "w_fsdp")),
    }


def _mlp_decls(cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "ln": _d((d,), (None,), "ones"),
        "w_gate": _d((d, f), ("w_fsdp", "w_tp")),
        "w_up": _d((d, f), ("w_fsdp", "w_tp")),
        "w_down": _d((f, d), ("w_tp", "w_fsdp")),
    }


def _moe_decls(cfg: ArchConfig):
    m = cfg.moe
    d = cfg.d_model
    decls = {
        "ln": _d((d,), (None,), "ones"),
        "router": _d((d, m.num_experts), ("w_fsdp", None)),
        "w_gate": _d((m.num_experts, d, m.d_expert),
                     ("expert", "expert_in", "expert_out")),
        "w_up": _d((m.num_experts, d, m.d_expert),
                   ("expert", "expert_in", "expert_out")),
        "w_down": _d((m.num_experts, m.d_expert, d),
                     ("expert", "expert_out", "expert_in")),
    }
    if m.num_shared_experts:
        fs = m.num_shared_experts * m.shared_d_expert
        decls.update({
            "shared_gate": _d((d, fs), ("w_fsdp", "w_tp")),
            "shared_up": _d((d, fs), ("w_fsdp", "w_tp")),
            "shared_down": _d((fs, d), ("w_tp", "w_fsdp")),
        })
    return decls


def mamba_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    return d_in, nheads, s.head_dim, s.d_state


def _mamba_decls(cfg: ArchConfig):
    d = cfg.d_model
    d_in, nh, _, n = mamba_dims(cfg)
    return {
        "ln": _d((d,), (None,), "ones"),
        "w_zx": _d((d, 2 * d_in), ("w_fsdp", "w_tp")),
        "w_bc": _d((d, 2 * n), ("w_fsdp", None)),
        "w_dt": _d((d, nh), ("w_fsdp", "w_tp")),
        "dt_bias": _d((nh,), ("w_tp",), "dt_bias"),
        "a_log": _d((nh,), ("w_tp",), "ssm_a"),
        "d_skip": _d((nh,), ("w_tp",), "ones"),
        "conv_x": _d((4, d_in), (None, "w_tp"), "normal", 4),
        "conv_b": _d((4, n), (None, None), "normal", 4),
        "conv_c": _d((4, n), (None, None), "normal", 4),
        "gated_ln": _d((d_in,), ("w_tp",), "ones"),
        "wo": _d((d_in, d), ("w_tp", "w_fsdp")),
    }


def block_decls(cfg: ArchConfig, layer_in_period: int):
    """Declarations for one (mixer, ffn) sub-block at a period position."""
    mixer, ffn = cfg.layer_kinds(layer_in_period)
    decls = {}
    if mixer == "attn":
        decls["attn"] = _attn_decls(cfg)
    elif mixer == "mamba":
        decls["mamba"] = _mamba_decls(cfg)
    if ffn == "mlp":
        decls["mlp"] = _mlp_decls(cfg)
    elif ffn == "moe":
        decls["moe"] = _moe_decls(cfg)
    return decls


def model_decls(cfg: ArchConfig):
    """Full declaration tree. Per-layer decls get a leading stacked 'layers'
    axis (num_groups = num_layers / scan period) for lax.scan."""
    period = cfg.scan_period
    assert cfg.num_layers % period == 0
    groups = cfg.num_layers // period

    def stack(decl: ParamDecl) -> ParamDecl:
        # Pin fan-in to the *unstacked* input dim so the scan axis never
        # changes init scale.
        fan_in = decl.fan_in
        if decl.init == "normal" and fan_in is None:
            fan_in = decl.shape[-2] if len(decl.shape) >= 2 else decl.shape[-1]
        return ParamDecl((groups,) + decl.shape, ("layers",) + decl.logical,
                         decl.init, fan_in)

    layers = {}
    for pos in range(period):
        layers[f"pos{pos}"] = jax.tree.map(
            stack, block_decls(cfg, pos),
            is_leaf=lambda x: isinstance(x, ParamDecl))

    tree = {
        "embed": {"table": _d((cfg.padded_vocab, cfg.d_model),
                              (None, "w_tp"), "normal", cfg.d_model)},
        "layers": layers,
        "final_norm": _d((cfg.d_model,), (None,), "ones"),
        "lm_head": _d((cfg.d_model, cfg.padded_vocab),
                      ("w_fsdp", "w_vocab_tp")),
    }
    if cfg.modality in ("audio", "vision_text"):
        tree["connector"] = {
            "w": _d((cfg.frontend_dim, cfg.d_model), ("w_fsdp", None)),
            "ln": _d((cfg.d_model,), (None,), "ones"),
        }
    return tree


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------

def _is_decl(x):
    return isinstance(x, ParamDecl)


def _init_leaf(decl: ParamDecl, key, dtype):
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dtype)
    if decl.init == "ssm_a":
        # A in [1, 16], stored as log (mamba2 default init)
        u = jax.random.uniform(key, decl.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)      # keep fp32 (sensitive)
    if decl.init == "dt_bias":
        # inverse-softplus of dt ~ LogUniform[1e-3, 1e-1]
        dt = jnp.exp(jax.random.uniform(key, decl.shape, jnp.float32,
                                        math.log(1e-3), math.log(1e-1)))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32)
    fan_in = decl.fan_in or (decl.shape[-2] if len(decl.shape) >= 2
                             else decl.shape[-1])
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, decl.shape, jnp.float32)
            * scale).astype(dtype)


def init_params(cfg: ArchConfig, key):
    decls = model_decls(cfg)
    leaves, treedef = jax.tree.flatten(decls, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree.unflatten(
        treedef, [_init_leaf(d, k, dtype) for d, k in zip(leaves, keys)])


def abstract_params(cfg: ArchConfig):
    dtype = jnp.dtype(cfg.param_dtype)

    def to_abstract(d: ParamDecl):
        dt = jnp.float32 if d.init in ("ssm_a", "dt_bias") else dtype
        return jax.ShapeDtypeStruct(d.shape, dt)

    return jax.tree.map(to_abstract, model_decls(cfg), is_leaf=_is_decl)


def param_specs(cfg: ArchConfig, rules: Rules):
    return jax.tree.map(lambda d: rules.spec(*d.logical), model_decls(cfg),
                        is_leaf=_is_decl)


def param_count_tree(cfg: ArchConfig) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(
        model_decls(cfg), is_leaf=_is_decl))
