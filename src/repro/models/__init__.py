"""Model zoo: one LM assembly executing every assigned architecture family."""

from repro.models.lm import LM, build_lm

__all__ = ["LM", "build_lm"]
