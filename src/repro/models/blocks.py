"""Transformer/Mamba/MoE block forwards (train, prefill and decode paths).

The MoE dispatch is a literal instance of the paper's memory scheduler:
token→expert assignments are the request stream, the expert id is the "DRAM
row", capacity buffers are the DMA staging buffers, and the dispatch
reorders requests so all traffic to one expert is serviced as a bulk
transfer. See ``moe_ffn``.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import capture as capture_mod
from repro.models import layers
from repro.models.params import mamba_dims
from repro.models.sharding import Rules, shard


class AttnCache(NamedTuple):
    k: jnp.ndarray          # (B, C, KV, hd) — C = max_len or SWA window
    v: jnp.ndarray


class QuantAttnCache(NamedTuple):
    """int8 KV cache with per-(position, head) scales (kv_cache_dtype)."""

    k: jnp.ndarray          # (B, C, KV, hd) int8
    v: jnp.ndarray          # (B, C, KV, hd) int8
    k_scale: jnp.ndarray    # (B, C, KV) f32
    v_scale: jnp.ndarray    # (B, C, KV) f32


def quantize_kv(x: jnp.ndarray):
    """Symmetric per-(.., head) int8 over the head_dim axis."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


class MambaCache(NamedTuple):
    conv_x: jnp.ndarray     # (B, 3, d_in) last conv taps
    conv_b: jnp.ndarray     # (B, 3, N)
    conv_c: jnp.ndarray     # (B, 3, N)
    ssm: jnp.ndarray        # (B, H, P, N) recurrent state


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------

def attn_forward(p, x, cfg: ArchConfig, rules: Rules, mesh,
                 positions: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                  Optional[AttnCache]]:
    """Full-sequence attention (train / prefill). Returns (out, kv)."""
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xn = layers.rms_norm(x, p["ln"])
    q = (xn @ p["wq"]).reshape(B, S, h, hd)
    k = (xn @ p["wk"]).reshape(B, S, kv, hd)
    v = (xn @ p["wv"]).reshape(B, S, kv, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    q = shard(q, rules, "batch", "seq", "heads", None, mesh=mesh)
    k = shard(k, rules, "batch", "seq", "kv_heads", None, mesh=mesh)
    v = shard(v, rules, "batch", "seq", "kv_heads", None, mesh=mesh)
    out = layers.flash_attention(q, k, v, causal=cfg.causal,
                                 window=cfg.attn_window,
                                 q_block=cfg.attn_q_block,
                                 kv_block=cfg.attn_kv_block)
    out = out.reshape(B, S, h * hd) @ p["wo"]
    return shard(out, rules, "batch", "seq", "embed", mesh=mesh), \
        AttnCache(k=k, v=v)


def attn_prefill_cache(kv: AttnCache, cfg: ArchConfig, seq_len: int,
                       max_len: int):
    """Convert prefill K/V into the serve cache layout (ring for SWA,
    int8 quantization when configured).

    Handles an optional leading stacked-layers axis (seq axis is -3).
    """
    w = cfg.attn_window

    def pad_seq(x, target, axis=-3):
        pads = [(0, 0)] * x.ndim
        pads[axis % x.ndim] = (0, target - seq_len)
        return jnp.pad(x, pads)

    if w is None or seq_len < w:
        pad = max_len if w is None else w
        k, v = pad_seq(kv.k, pad), pad_seq(kv.v, pad)
    else:
        # ring buffer holding the last `w` tokens, slot = position % w
        sl = (Ellipsis, slice(-w, None), slice(None), slice(None))
        shift = seq_len % w
        k = jnp.roll(kv.k[sl], shift, axis=-3)
        v = jnp.roll(kv.v[sl], shift, axis=-3)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return QuantAttnCache(kq, vq, ks, vs)
    return AttnCache(k, v)


def attn_decode(p, x, cache, cur_len: jnp.ndarray,
                cfg: ArchConfig, rules: Rules, mesh):
    """One-token attention against the cache; returns (out, new_cache).

    ``cur_len`` is the number of tokens already in the cache; the new token
    occupies position ``cur_len``. Accepts either a plain ``AttnCache`` or
    a ``QuantAttnCache`` (int8 storage, dequantized at read — half the
    HBM traffic per step).
    """
    B, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    C = cache.k.shape[1]
    w = cfg.attn_window
    xn = layers.rms_norm(x, p["ln"])
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    q = layers.rope((xn @ p["wq"]).reshape(B, 1, h, hd), pos, cfg.rope_theta)
    k = layers.rope((xn @ p["wk"]).reshape(B, 1, kv, hd), pos, cfg.rope_theta)
    v = (xn @ p["wv"]).reshape(B, 1, kv, hd)

    quant = isinstance(cache, QuantAttnCache)
    slot = cur_len % C if w is not None else cur_len

    def dus(buf, new, axis=1):
        # KV append = the controller's bulk-write request class (fig7w).
        return layers.mc_kv_append(buf, new, slot, cfg.mc, axis=axis)

    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = QuantAttnCache(
            k=dus(cache.k, kq), v=dus(cache.v, vq),
            k_scale=dus(cache.k_scale, ks), v_scale=dus(cache.v_scale, vs))
        full_k = dequantize_kv(new_cache.k, new_cache.k_scale, x.dtype)
        full_v = dequantize_kv(new_cache.v, new_cache.v_scale, x.dtype)
    else:
        new_k = shard(dus(cache.k, k), rules, "batch", "kv_seq", None,
                      None, mesh=mesh)
        new_v = shard(dus(cache.v, v), rules, "batch", "kv_seq", None,
                      None, mesh=mesh)
        new_cache = AttnCache(new_k, new_v)
        full_k, full_v = new_k, new_v

    n_valid = jnp.minimum(cur_len + 1, C)
    valid = jnp.broadcast_to(jnp.arange(C) < n_valid, (B, C))
    out = layers.decode_attention(q[:, 0], full_k, full_v, valid)
    out = out.reshape(B, h * hd) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# Dense / shared MLP
# ---------------------------------------------------------------------------

def mlp_forward(p, x, rules: Rules, mesh):
    xn = layers.rms_norm(x, p["ln"])
    h = jax.nn.silu(xn @ p["w_gate"]) * (xn @ p["w_up"])
    h = shard(h, rules, "batch", "seq", "heads", mesh=mesh)
    return shard(h @ p["w_down"], rules, "batch", "seq", "embed", mesh=mesh)


# ---------------------------------------------------------------------------
# MoE — the memory-controller scheduler at cluster scale
# ---------------------------------------------------------------------------

def capture_moe_dispatch(top_e, n_tokens: int, d_model: int,
                         itemsize: int) -> None:
    """Report a routed MoE layer's traffic into the active TraceCapture.

    The genuine multi-port view of expert dispatch (paper Fig. 2 /
    Nguyen et al.): **the expert id is the port** (``pe_id`` = expert —
    experts are the PEs contending for the channels), the request row is
    the *token's* activation row in the dispatch buffer region, READ on
    dispatch and WRITE on combine. ``top_e`` is ``(T, k)``; a traced
    value (jit/shard_map) skips the record, counted by the recorder.
    """
    cap = capture_mod.active_capture()
    if cap is None:
        return
    te = capture_mod.concrete(top_e)
    if te is None:
        cap.n_skipped_traced += 1
        return
    te = te.astype(np.int64)
    T, k = te.shape
    row_bytes = int(d_model) * int(itemsize)
    name = f"moe_tokens:{int(n_tokens)}x{row_bytes}"
    tok = np.repeat(np.arange(T, dtype=np.int64), k)
    pe = te.reshape(-1)
    cap.record("moe_dispatch", name, int(n_tokens), row_bytes, tok,
               rw=0, pe_id=pe)
    cap.record("moe_combine", name, int(n_tokens), row_bytes, tok,
               rw=1, pe_id=pe)


def moe_ffn(p, x, cfg: ArchConfig, rules: Rules, mesh, *,
            no_drop: bool = False, dispatch: str = "sort",
            num_groups: int = 1):
    """Token-choice top-k MoE with capacity buffers.

    Scheduler mapping (paper Fig. 2):
      requests   = (token, expert) assignments,
      row index  = expert id (the device/HBM region owning that expert),
      batch      = one *group's* assignment set (see below),
      reorder    = stable sort by row id; capacity slot = offset in the
                   expert's run (``dispatch="sort"``) — vs the naive
                   GShard one-hot prefix scan (``dispatch="cumsum"``),
      bulk xfer  = the buffer einsum against expert weights,
      writeback  = combine weighted by router prob, arrival order restored.

    ``num_groups`` partitions tokens into independent scheduler instances
    (GShard local groups), matching the paper's *bounded, per-controller*
    batches: each data shard sorts and scatters only its own requests, so
    dispatch is collective-free. Capacity is per-group; group-local drops
    are the standard GShard semantics. ``num_groups=1`` is the global
    scheduler (single-controller semantics, used on CPU/tests).

    Returns (out, aux_losses dict).
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = num_groups if T % max(1, num_groups) == 0 else 1
    TG = T // G
    xn = layers.rms_norm(x, p["ln"])
    flat = xn.reshape(T, D)

    logits = (flat @ p["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)           # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    capture_moe_dispatch(top_e, T, D, jnp.dtype(x.dtype).itemsize)

    # --- load-balance + router-z auxiliary losses (Switch/ST-MoE) ---
    me = probs.mean(0)                                     # (E,)
    ce = jnp.zeros((m.num_experts,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0) / (T * m.top_k)
    aux = {
        "load_balance": m.num_experts * jnp.sum(me * ce),
        "router_z": m.router_z_coef * jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2),
    }

    # --- scheduler: place each assignment into its expert's capacity slot ---
    if no_drop:
        # Serving path: per-group capacity TG is a strict upper bound (a
        # token selects an expert at most once), so no request is ever
        # dropped and decode matches the cache-free forward exactly.
        capacity = TG
    else:
        capacity = int(math.ceil(TG * m.top_k / m.num_experts
                                 * m.capacity_factor))
        if capacity >= 64:       # round for even layout
            capacity = -(-capacity // 128) * 128
        capacity = min(capacity, TG)
    na = TG * m.top_k                            # assignments per group
    e_grp = top_e.reshape(G, na)                 # (G, n) row ids
    if dispatch == "sort":
        # Stable sort by row id per group; slot = offset in the expert's
        # contiguous run. Stability preserves arrival order within an
        # expert (same-address consistency), so slots equal the
        # sequential-arrival (cumsum) semantics without the O(n·E)
        # prefix scan.
        order = jnp.argsort(e_grp, axis=-1, stable=True)
        e_sorted = jnp.take_along_axis(e_grp, order, axis=-1)
        run_start = jax.vmap(
            lambda es: jnp.searchsorted(es, jnp.arange(m.num_experts)))(
            e_sorted)                            # (G, E)
        pos_sorted = (jnp.arange(na)[None, :]
                      - jnp.take_along_axis(run_start, e_sorted, axis=-1)
                      ).astype(jnp.int32)
        pos_in_e = jnp.zeros((G, na), jnp.int32)
        pos_in_e = jax.vmap(lambda z, o, v: z.at[o].set(v))(
            pos_in_e, order, pos_sorted)
    else:                         # "cumsum": GShard-style naive dispatch
        onehot = jax.nn.one_hot(e_grp, m.num_experts, dtype=jnp.int32)
        pos_in_e = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1
    keep = pos_in_e < capacity
    slot = jnp.where(keep, pos_in_e, capacity)             # drop slot = C

    # dispatch: (G, E, C+1, D) buffers; the +1 slot swallows drops. The
    # group dim is a scatter *batch* dim sharded over data, so each shard
    # scatters only its own requests — no cross-device traffic, and no
    # GSPMD operand replication (a global capacity-sharded scatter
    # measured ~100 GiB/device of temps on qwen2 train).
    flat_g = flat.reshape(G, TG, D)
    tok_idx = jnp.repeat(jnp.arange(TG), m.top_k)
    upd = jnp.take(flat_g, tok_idx, axis=1)                # (G, n, D)
    buf = jnp.zeros((G, m.num_experts, capacity + 1, D), x.dtype)
    buf = shard(buf, rules, "expert_capacity", "expert", None, "heads",
                mesh=mesh)
    buf = jax.vmap(lambda b, e, s, u: b.at[e, s].set(u, mode="drop"))(
        buf, e_grp, slot, upd)
    buf = shard(buf[:, :, :capacity], rules, "expert_capacity", "expert",
                None, "embed", mesh=mesh)

    # bulk transfer: batched expert FFN (SwiGLU); groups stay data-sharded
    hmid = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    hmid = shard(hmid, rules, "expert_capacity", "expert", None, "heads",
                 mesh=mesh)
    eout = jnp.einsum("gecf,efd->gecd", hmid, p["w_down"])
    eout = jnp.pad(eout, ((0, 0), (0, 0), (0, 1), (0, 0)))  # drop slot
    eout = shard(eout, rules, "expert_capacity", "expert", None, "heads",
                 mesh=mesh)

    # writeback: gather each assignment's result, weight, combine per token
    y = jax.vmap(lambda eo, e, s: eo[e, s])(eout, e_grp, slot)
    y = y * top_p.reshape(G, na)[..., None].astype(x.dtype)
    y = y.reshape(G, TG, m.top_k, D).sum(2).reshape(T, D)

    if m.num_shared_experts:
        y = y + (jax.nn.silu(flat @ p["shared_gate"])
                 * (flat @ p["shared_up"])) @ p["shared_down"]

    out = y.reshape(B, S, D)
    return shard(out, rules, "batch", "seq", "embed", mesh=mesh), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

def _causal_conv(u, w, cache=None):
    """Depthwise causal conv, kernel 4. u: (B, S, C), w: (4, C).

    With ``cache`` (B, 3, C) the first taps come from previous context
    (decode path handles S=1)."""
    if cache is None:
        pad = jnp.zeros((u.shape[0], 3, u.shape[2]), u.dtype)
    else:
        pad = cache.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)               # (B, S+3, C)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(4))
    new_cache = full[:, -3:]
    return jax.nn.silu(out), new_cache


def _mamba_project(p, x, cfg: ArchConfig):
    d_in, nh, hp, n = mamba_dims(cfg)
    xn = layers.rms_norm(x, p["ln"])
    zx = xn @ p["w_zx"]
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bc = xn @ p["w_bc"]
    b, c = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus((xn @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                   # (B, S, H)
    return z, xin, b, c, dt


def mamba_forward(p, x, cfg: ArchConfig, rules: Rules, mesh
                  ) -> Tuple[jnp.ndarray, MambaCache]:
    """Chunked SSD forward (Mamba-2, arXiv:2405.21060 §6).

    Intra-chunk terms are computed with dense (quadratic-in-chunk) matmuls —
    MXU-friendly — while inter-chunk terms flow through a scan carrying the
    (B, H, P, N) state. Returns final state as decode cache.
    """
    B, S, D = x.shape
    d_in, H, P, N = mamba_dims(cfg)
    L = min(cfg.ssm.chunk, S)

    z, xin, b, c, dt = _mamba_project(p, x, cfg)
    xin, conv_x = _causal_conv(xin, p["conv_x"])
    b, conv_b = _causal_conv(b, p["conv_b"])
    c, conv_c = _causal_conv(c, p["conv_c"])
    a = -jnp.exp(p["a_log"])                               # (H,) negative

    # Pad to a chunk multiple. Padded positions get dt=0, which makes them
    # exactly transparent: zero state contribution, unchanged decay.
    Sp = -(-S // L) * L
    if Sp != S:
        pad3 = lambda t: jnp.pad(t, ((0, 0), (0, Sp - S), (0, 0)))
        xin, b, c = pad3(xin), pad3(b), pad3(c)
        dt = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
        valid = (jnp.arange(Sp) < S).astype(dt.dtype)
        dt = dt * valid[None, :, None]
    nc = Sp // L

    xh = xin.reshape(B, nc, L, H, P).astype(jnp.float32)
    dtc = dt.reshape(B, nc, L, H)
    bc_ = b.reshape(B, nc, L, N).astype(jnp.float32)
    cc_ = c.reshape(B, nc, L, N).astype(jnp.float32)

    def chunk_step(h_prev, inputs):
        xc, dt_c, b_c, c_c = inputs                        # (B,L,H,P) etc.
        da = dt_c * a                                      # (B,L,H)
        cum = jnp.cumsum(da, axis=1)                       # (B,L,H)
        # intra-chunk: M[l,m,h] = exp(cum_l - cum_m) * (c_l·b_m) * dt_m, l>=m
        scores = jnp.einsum("bln,bmn->blm", c_c, b_c)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        mask = jnp.tril(jnp.ones((L, L), bool))
        mmat = jnp.where(mask[None, :, :, None],
                         scores[..., None] * decay
                         * dt_c[:, None, :, :], 0.0)       # (B,L,M,H)
        y = jnp.einsum("blmh,bmhp->blhp", mmat, xc)
        # inter-chunk: contribution of carried state
        y += jnp.exp(cum)[..., None] * jnp.einsum(
            "bln,bhpn->blhp", c_c, h_prev)
        # state update for next chunk
        tail = jnp.exp(cum[:, -1:, :] - cum)               # (B,L,H)
        s_chunk = jnp.einsum("blh,bln,blhp->bhpn",
                             tail * dt_c, b_c, xc)
        h_new = h_prev * jnp.exp(cum[:, -1])[:, :, None, None] + s_chunk
        return h_new, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (xh.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          bc_.transpose(1, 0, 2, 3), cc_.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, P)[:, :S]
    y = y + xh.reshape(B, Sp, H, P)[:, :S] * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_in)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rms_norm(y.astype(x.dtype), p["gated_ln"])
    out = y @ p["wo"]
    cache = MambaCache(conv_x=conv_x, conv_b=conv_b, conv_c=conv_c,
                       ssm=h_final)
    return shard(out, rules, "batch", "seq", "embed", mesh=mesh), cache


def mamba_decode(p, x, cache: MambaCache, cfg: ArchConfig, rules: Rules,
                 mesh) -> Tuple[jnp.ndarray, MambaCache]:
    """O(1) recurrent step. x: (B, D)."""
    B, D = x.shape
    d_in, H, P, N = mamba_dims(cfg)
    cap = capture_mod.active_capture()
    if cap is not None and capture_mod.is_concrete(x):
        # SSM family signature: every decode step rewrites the whole
        # (H, P, N) recurrent state — a wide sequential page-write burst
        # per sequence (port = sequence), nothing like KV's single-slot
        # append. Static shapes, so gate on x being concrete to avoid
        # recording during jit tracing.
        page_bytes = P * N * 4                      # f32 state rows
        cap.record("ssm_state_update", f"ssm:{H}x{page_bytes}", H,
                   page_bytes, np.tile(np.arange(H, dtype=np.int64), B),
                   rw=1, pe_id=np.repeat(np.arange(B, dtype=np.int64), H))
    z, xin, b, c, dt = _mamba_project(p, x[:, None, :], cfg)
    xin, conv_x = _causal_conv(xin, p["conv_x"], cache.conv_x)
    b, conv_b = _causal_conv(b, p["conv_b"], cache.conv_b)
    c, conv_c = _causal_conv(c, p["conv_c"], cache.conv_c)

    xh = xin[:, 0].reshape(B, H, P).astype(jnp.float32)
    dt1 = dt[:, 0]                                         # (B, H)
    b1 = b[:, 0].astype(jnp.float32)                       # (B, N)
    c1 = c[:, 0].astype(jnp.float32)
    a = -jnp.exp(p["a_log"])

    da = jnp.exp(dt1 * a)                                  # (B, H)
    h_new = (cache.ssm * da[:, :, None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dt1, xh, b1))
    y = jnp.einsum("bhpn,bn->bhp", h_new, c1)
    y = y + xh * p["d_skip"][None, :, None]
    y = (y.reshape(B, d_in)
         * jax.nn.silu(z[:, 0].astype(jnp.float32)))
    y = layers.rms_norm(y.astype(x.dtype), p["gated_ln"])
    out = y @ p["wo"]
    return out, MambaCache(conv_x, conv_b, conv_c, h_new)
