"""Version-compatibility shims for the pinned container toolchain.

The repo targets the current jax API; the container pins an older
release where some entry points live elsewhere or take different
keyword names. Shims here keep call sites written against the new API
(the same role ``tests/_hypothesis_fallback.py`` plays for hypothesis).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec

P = getattr(jax, "P", PartitionSpec)


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None):
    """``jax.shard_map`` across versions: the new top-level API takes
    ``check_vma``; the 0.4.x experimental version spells it
    ``check_rep`` (same meaning: static replication checking)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
