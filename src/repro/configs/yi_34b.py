"""yi-34b — llama-architecture dense transformer with GQA.

[dense] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[arXiv:2403.04652; hf]
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    source="arXiv:2403.04652",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16)
