"""jamba-v0.1-52b — hybrid Mamba + attention (1:7) with MoE every 2nd layer.

[hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2 [arXiv:2403.19887; hf]

Layer pattern (period 8, matching the published 1:7 attn:mamba interleave):
mixer = attention at l % 8 == 4, Mamba elsewhere; FFN = MoE on odd layers,
dense SwiGLU on even layers.
"""

import dataclasses

from repro.configs.base import ArchConfig, MoESpec, SSMSpec

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    attn_every=8,
    attn_offset=4,
    moe_every=2,
    moe=MoESpec(num_experts=16, top_k=2, d_expert=14336),
    ssm=SSMSpec(d_state=16, expand=2, head_dim=64, chunk=256),
    source="arXiv:2403.19887",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    moe=MoESpec(num_experts=4, top_k=2, d_expert=128),
    ssm=SSMSpec(d_state=8, expand=2, head_dim=16, chunk=16))
