"""hubert-xlarge — encoder-only audio transformer (wav2vec2 architecture).

[audio] 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified]

The convolutional waveform frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, S, frontend_dim)
and the model starts at the connector projection. Encoder-only ⇒ no decode
shapes; trained with masked-frame cluster prediction (HuBERT objective) on
the 504-way cluster vocabulary.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    causal=False,            # bidirectional encoder
    modality="audio",
    frontend_dim=512,        # conv feature extractor output size (stub)
    source="arXiv:2106.07447",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=64, head_dim=16, frontend_dim=32)
