"""Architecture registry: ``get_arch(name)`` / ``--arch <id>`` resolution.

Each assigned architecture lives in its own module defining ``CONFIG``
(the exact assigned configuration) and ``SMOKE_CONFIG`` (a reduced
same-family configuration for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "mamba2_2p7b",
    "yi_34b",
    "granite_34b",
    "h2o_danube_1p8b",
    "internlm2_20b",
    "hubert_xlarge",
    "jamba_v0p1_52b",
    "qwen2_moe_a2p7b",
    "mixtral_8x7b",
    "internvl2_76b",
]

# accept dashed/official ids too
_ALIASES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "yi-34b": "yi_34b",
    "granite-34b": "granite_34b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "internlm2-20b": "internlm2_20b",
    "hubert-xlarge": "hubert_xlarge",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "internvl2-76b": "internvl2_76b",
}


def canonical(name: str) -> str:
    name = name.strip()
    return _ALIASES.get(name, name)


def get_arch(name: str, smoke: bool = False):
    cname = canonical(name)
    if cname not in ARCH_IDS:
        raise KeyError(f"unknown arch '{name}'; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{cname}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def all_archs(smoke: bool = False):
    return {a: get_arch(a, smoke) for a in ARCH_IDS}
