"""internlm2-20b — dense GQA transformer.

[dense] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544
[arXiv:2403.17297; hf]
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    head_dim=128,
    source="arXiv:2403.17297",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16)
