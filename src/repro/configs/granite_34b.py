"""granite-34b — llama-architecture code model with MQA (kv=1).

[dense] 88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf]
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,          # multi-query attention
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    source="arXiv:2405.04324",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=256, head_dim=16)
