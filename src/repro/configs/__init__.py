"""Architecture & shape configs (one module per assigned arch)."""

from repro.configs.base import (ArchConfig, MoESpec, SSMSpec, ShapeConfig,
                                SHAPES, supported_shapes)
from repro.configs.registry import ARCH_IDS, all_archs, canonical, get_arch

__all__ = ["ArchConfig", "MoESpec", "SSMSpec", "ShapeConfig", "SHAPES",
           "supported_shapes", "ARCH_IDS", "all_archs", "canonical",
           "get_arch"]
