"""Architecture & shape configuration schema.

Every assigned architecture is a frozen ``ArchConfig``; every workload
shape is a ``ShapeConfig``. ``(arch, shape)`` cells drive the smoke tests,
the multi-pod dry-run and the roofline table. The memory controller is a
first-class member of the config — enabling/disabling engines re-specializes
the compiled program like the paper's synthesis parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.config import MemoryControllerConfig


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    num_shared_experts: int = 0    # qwen2-moe: always-on shared experts
    shared_d_expert: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256               # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | moe | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention
    causal: bool = True
    attn_window: Optional[int] = None      # sliding-window size (SWA archs)
    rope_theta: float = 10_000.0
    # family extensions
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    attn_every: Optional[int] = None       # hybrid: attn at layer l%attn_every==attn_offset
    attn_offset: int = 4
    moe_every: Optional[int] = None        # hybrid: MoE at l%moe_every==1
    # modality frontend stubs (audio frames / vision patches)
    modality: str = "text"                 # text | audio | vision_text
    frontend_dim: Optional[int] = None     # stub embedding feature size
    num_vision_tokens: int = 0             # vision_text: prefix length
    # numerics / memory controller
    param_dtype: str = "bfloat16"
    mc: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig)
    use_pallas: bool = False               # TPU kernels (interpret-tested)
    remat: bool = True
    # "nothing" recomputes the whole layer in backward (min memory, max
    # recompute: 3 weight-gather passes); "dots" saves matmul outputs
    # (more live memory, one fewer recompute pass). §Perf lever.
    remat_policy: str = "nothing"
    # lax.scan over layer groups (compact HLO). The dry-run's cost
    # extrapolation compiles small unrolled variants because XLA cost
    # analysis counts while bodies once regardless of trip count.
    scan_layers: bool = True
    # Chunked cross-entropy (beyond-paper optimization, §Perf): compute the
    # LM head + softmax in `loss_chunks` sequence chunks with rematerialized
    # logits, so the (B,S,V) logits tensor never exists in HBM. None = the
    # naive baseline loss.
    loss_chunks: int | None = None
    # MoE dispatch scheduler: "sort" = the paper's batch-reorder scheduler
    # (stable sort by expert/row id, positions from run offsets);
    # "cumsum" = naive GShard-style one-hot prefix scan (the baseline the
    # scheduler is compared against in §Perf).
    moe_dispatch: str = "sort"
    # Flash-attention (XLA path) block shapes — the DMA-engine staging
    # sizes. Larger kv blocks rewrite the online-softmax accumulators
    # fewer times (§Perf memory lever); smaller blocks cap live memory.
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    # Serving KV-cache storage: "param" follows param_dtype; "int8" stores
    # quantized K/V with per-(position, head) scales — halves decode cache
    # reads/footprint at ~1e-2 relative attention error (tested).
    kv_cache_dtype: str = "param"
    # citation tag for the assignment table
    source: str = ""

    def __post_init__(self) -> None:
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.family != "ssm" and self.num_heads % self.num_kv_heads:
            raise ValueError("num_heads must be a multiple of num_kv_heads")
        if self.family in ("moe",) and self.moe is None:
            raise ValueError("moe family needs an MoESpec")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError("ssm/hybrid family needs an SSMSpec")

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding: embeddings/LM head are allocated
        at the next multiple of 256 so the vocab dim shards evenly on any
        TP axis up to 256; loss masks the padding columns."""
        return -(-self.vocab_size // 256) * 256

    # --- derived sizes (used by roofline MODEL_FLOPS and memory checks) ----
    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, kv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * hd * (h + 2 * kv) + h * hd * d
        mlp = 3 * d * f                       # SwiGLU
        per_layer = []
        for layer in range(self.num_layers):
            kind_mixer, kind_ffn = self.layer_kinds(layer)
            p = 2 * d                          # 2 RMSNorm weights
            if kind_mixer == "attn":
                p += attn
            elif kind_mixer == "mamba":
                s = self.ssm
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                p += d * (2 * d_in + 2 * s.d_state + nheads)  # in_proj(z,x,B,C,dt)
                p += d_in * d                  # out_proj
                p += 2 * nheads                # A_log, D
                p += d_in                      # gated-norm weight
            if kind_ffn == "mlp":
                p += mlp
            elif kind_ffn == "moe":
                m = self.moe
                p += d * m.num_experts         # router
                p += m.num_experts * 3 * d * m.d_expert
                p += m.num_shared_experts * 3 * d * m.shared_d_expert
            per_layer.append(p)
        embed = v * d
        head = v * d                           # untied LM head
        final_norm = d
        extra = 0
        if self.modality in ("audio", "vision_text"):
            extra += (self.frontend_dim or d) * d  # connector projection
        return embed + head + final_norm + sum(per_layer) + extra

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_expert_cost = m.num_experts * 3 * self.d_model * m.d_expert
        active_expert_cost = m.top_k * 3 * self.d_model * m.d_expert
        n_moe_layers = sum(
            1 for l in range(self.num_layers)
            if self.layer_kinds(l)[1] == "moe")
        return (self.param_count()
                - n_moe_layers * (dense_expert_cost - active_expert_cost))

    def layer_kinds(self, layer: int) -> Tuple[str, str]:
        """(mixer, ffn) kinds for a layer index."""
        if self.family == "ssm":
            return "mamba", "none"            # mamba2 blocks have no FFN
        if self.family == "hybrid":
            mixer = ("attn" if layer % self.attn_every == self.attn_offset
                     else "mamba")
            ffn = "moe" if (self.moe_every and layer % self.moe_every == 1) \
                else "mlp"
            return mixer, ffn
        ffn = "moe" if self.moe is not None else "mlp"
        return "attn", ffn

    @property
    def scan_period(self) -> int:
        """Layers per scanned group (hybrid archs scan over their pattern
        period; homogeneous stacks scan layer-by-layer)."""
        if self.family == "hybrid":
            import math
            return abs(self.attn_every * self.moe_every) // math.gcd(
                self.attn_every, self.moe_every)
        return 1


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def supported_shapes(arch: ArchConfig) -> list:
    """Which shape cells are runnable for an arch (skips per assignment:
    encoder-only has no decode; long_500k needs sub-quadratic attention)."""
    names = ["train_4k", "prefill_32k"]
    if arch.family != "encoder":
        names.append("decode_32k")
        sub_quadratic = (
            arch.family in ("ssm", "hybrid") or arch.attn_window is not None)
        if sub_quadratic:
            names.append("long_500k")
    return names
