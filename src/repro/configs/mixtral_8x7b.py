"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention.

[moe] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8e top-2, SWA [arXiv:2401.04088; hf]
"""

import dataclasses

from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    attn_window=4096,        # SWA ⇒ sub-quadratic, runs long_500k
    moe=MoESpec(num_experts=8, top_k=2, d_expert=14336),
    source="arXiv:2401.04088",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, attn_window=8,
    moe=MoESpec(num_experts=4, top_k=2, d_expert=128))
