"""qwen2-moe-a2.7b — fine-grained MoE: 60 routed experts top-4 + 4 shared.

[moe] 24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE 60e top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

The 4 shared (always-on) experts are the cache-engine analogue: their
weights are the hot working set every token reuses, while the 60 routed
experts are scheduled bulk traffic.
"""

import dataclasses

from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,               # routed-expert hidden size
    vocab_size=151936,
    head_dim=128,
    moe=MoESpec(num_experts=60, top_k=4, d_expert=1408,
                num_shared_experts=4, shared_d_expert=1408),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=32, vocab_size=256, head_dim=16,
    moe=MoESpec(num_experts=8, top_k=4, d_expert=32,
                num_shared_experts=2, shared_d_expert=32))
