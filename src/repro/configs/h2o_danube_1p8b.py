"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[dense] 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000
[arXiv:2401.16818; hf]
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    head_dim=80,
    attn_window=4096,        # mistral-style SWA ⇒ sub-quadratic, runs long_500k
    source="arXiv:2401.16818",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, attn_window=8)
