"""internvl2-76b — VLM: InternViT frontend + llama-arch 70B-class backbone.

[vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified]

Per the assignment, only the transformer BACKBONE is modeled; the InternViT
frontend is a STUB — ``input_specs()`` provides precomputed patch embeddings
(B, num_vision_tokens, frontend_dim) which the connector MLP projects into
the token stream ahead of the text tokens. Loss is masked to text positions.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    modality="vision_text",
    frontend_dim=3200,       # InternViT-6B output width (stubbed)
    num_vision_tokens=256,
    source="arXiv:2404.16821",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, frontend_dim=48,
    num_vision_tokens=8)
