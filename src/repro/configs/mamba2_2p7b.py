"""mamba2-2.7b — SSD (state-space duality), attention-free.

[ssm] 64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]
"""

import dataclasses

from repro.configs.base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=80,            # d_inner / ssm head_dim = 5120/64
    num_kv_heads=80,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    ssm=SSMSpec(d_state=128, expand=2, head_dim=64, chunk=256),
    source="arXiv:2405.21060",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    vocab_size=256,
    head_dim=32,
    ssm=SSMSpec(d_state=16, expand=2, head_dim=32, chunk=16),
)
