"""Public sorted-scatter op: schedule (sort) → coalesce → scatter.

``sorted_scatter(table, idx, vals)`` is value-identical to the sequential
write stream ``for i: table[idx[i]] = vals[i]`` (``mode="set"``, last
writer wins) or ``table[idx[i]] += vals[i]`` (``mode="add"``, gradient
accumulation). The request stream is stable-sorted by row id (the
scheduler's WRITE batch reorder), duplicate-row writes are coalesced —
``add`` folds each run into a single row update via a within-run prefix
sum, ``set`` relies on VMEM overwrite inside the kernel — and the Pallas
scatter streams one HBM burst per distinct row.

No unsort step is needed on the write path: writes return no payload, so
arrival order only matters *per address*, which the stable sort preserves
(the weak-consistency rule extended to writes).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.scatter_util import masked_row_set
from repro.kernels.bitonic_sort import ops as bitonic_ops
from repro.kernels.sorted_scatter.coalesce import coalesce_add_runs
from repro.kernels.sorted_scatter.kernel import scatter_rows


def sorted_scatter(table: jnp.ndarray, indices: jnp.ndarray,
                   values: jnp.ndarray, *, mode: str = "set",
                   use_bitonic: bool = False,
                   interpret: bool = True,
                   backend: str = "pallas") -> jnp.ndarray:
    """One sort-and-coalesce pipeline for both data planes: the Pallas
    kernel (``backend="pallas"``) and the XLA fallback the controller
    uses off-TPU (``backend="xla"``, last-of-run rows via masked
    scatter). Keeping a single copy is what guarantees the two paths
    cannot drift in batch semantics."""
    if mode not in ("set", "add"):
        raise ValueError(f"mode must be 'set' or 'add', got {mode!r}")
    idx = indices.reshape(-1)
    vals = values.reshape(idx.shape[0], table.shape[-1])
    if use_bitonic:
        _, perm = bitonic_ops.sort_with_indices(idx, interpret=interpret)
    else:
        perm = jnp.argsort(idx, stable=True)
    sidx = jnp.take(idx, perm, axis=0)
    svals = jnp.take(vals, perm, axis=0)
    if mode == "add":
        # The last slot of each equal-index run — the only one whose VMEM
        # block is flushed — holds table[row] + Σ(run values).
        svals = coalesce_add_runs(table, sidx, svals)
    if backend == "pallas":
        return scatter_rows(table, sidx, svals, interpret=interpret)
    n = sidx.shape[0]
    is_last = jnp.concatenate(
        [sidx[1:] != sidx[:-1], jnp.ones((1,), bool)]) if n else \
        jnp.zeros((0,), bool)
    return masked_row_set(table, sidx, svals, is_last)
