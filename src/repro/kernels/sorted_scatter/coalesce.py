"""Run-coalescing for sorted write batches (shared by the Pallas op and
the controller's XLA fallback — one copy of the subtle reduction math).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def coalesce_add_runs(table: jnp.ndarray, sidx: jnp.ndarray,
                      svals: jnp.ndarray) -> jnp.ndarray:
    """Fold each equal-index run of a *sorted* write batch for ``add``.

    Returns per-slot values ``table[row] + Σ(run values)``, so flushing
    any one slot of a run — in particular the final one the VMEM
    coalescing keeps — accumulates exactly like the in-order stream.
    Sums are taken *per run* (segment sum keyed on the run-start index)
    in at least float32 — float64 tables accumulate in float64, so the
    naive-``add`` identity holds at the table's own precision — with no
    global prefix accumulation, so a short run's sum stays accurate even
    in million-row batches.
    """
    acc_dtype = jnp.promote_types(jnp.float32, table.dtype)
    starts = jnp.searchsorted(sidx, sidx, side="left")
    totals = jax.ops.segment_sum(svals.astype(acc_dtype), starts,
                                 num_segments=sidx.shape[0])
    run_sum = jnp.take(totals, starts, axis=0)
    # The base-row add also happens in the accumulator dtype — rounding
    # to the table dtype exactly once, same as the unscheduled reference.
    return (jnp.take(table, sidx, axis=0).astype(acc_dtype)
            + run_sum).astype(table.dtype)
