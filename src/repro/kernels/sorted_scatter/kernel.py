"""Sorted-scatter — the scheduler's write-side locality payoff, in Pallas.

Mirror image of ``sorted_gather``: the FPGA scheduler reorders a WRITE
batch so same-row writes reach DRAM back-to-back. The TPU analogue: feed
*sorted* row indices to a scalar-prefetch scatter whose *output* BlockSpec
index map selects ``table[idx[i]]``. When consecutive grid steps map to the
same output block the Pallas pipeline emitter defers the VMEM→HBM copy-out
until the block changes — duplicate-row writes are **coalesced in VMEM**
and only the final value of a run is flushed, one HBM burst per distinct
row. That is simultaneously the row-buffer-hit economics of the paper and
its weak-consistency rule: within a sorted run the last writer (in arrival
order, preserved by the stable sort) wins.

The table is passed through ``input_output_aliases`` so rows never written
keep their original contents — the kernel is an in-place row update, not a
rebuild of the table.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_row_kernel(idx_ref, val_ref, table_ref, out_ref):
    # idx_ref (scalar prefetch) already steered the output pipeline to row
    # idx[i]; table_ref is only present for the HBM aliasing — the body is a
    # VMEM overwrite, so a run of equal indices coalesces before copy-out.
    del idx_ref, table_ref
    out_ref[...] = val_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_rows(table: jnp.ndarray, sorted_idx: jnp.ndarray,
                 values: jnp.ndarray, *, interpret: bool = True):
    """Write ``values[i]`` to ``table[sorted_idx[i]]``, last writer wins.

    Callers must pass indices sorted (stably) by row to get the VMEM
    coalescing and HBM locality; *correctness* additionally requires equal
    indices to be adjacent, which sorting guarantees — with non-adjacent
    duplicates an earlier flushed block could clobber a later one.
    """
    n = sorted_idx.shape[0]
    d = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),           # values
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={2: 0},   # table buffer is updated in place
        interpret=interpret,
    )(sorted_idx.astype(jnp.int32), values, table)
