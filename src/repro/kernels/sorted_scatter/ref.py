"""Oracles for sorted_scatter: sequential write-stream semantics."""

import jax.numpy as jnp
import numpy as np


def scatter_ref(table: jnp.ndarray, indices: jnp.ndarray,
                values: jnp.ndarray, mode: str = "set") -> jnp.ndarray:
    """In-order write stream (the naive un-scheduled controller): writes
    land one at a time, so duplicates resolve to the last arrival for
    ``set`` and accumulate for ``add`` — in promoted (≥f32) precision
    with a single final round, the same reference the controller's
    toggle-identity contract is defined against."""
    if mode == "add":
        idx = indices.reshape(-1)
        vals = values.reshape(idx.shape[0], table.shape[-1])
        acc = jnp.promote_types(jnp.float32, table.dtype)
        return table.astype(acc).at[idx].add(
            vals.astype(acc)).astype(table.dtype)
    out = np.array(table)
    idx = np.asarray(indices).reshape(-1)
    vals = np.asarray(values).reshape(idx.shape[0], -1)
    for i, row in enumerate(idx):
        out[row] = vals[i]
    return jnp.asarray(out, table.dtype)
