"""Bitonic sorting network — the scheduler's reordering engine (paper Fig. 2).

TPU adaptation of the FPGA sorting fabric: the FPGA unrolls the network
*spatially* (one comparator per wire pair); the TPU time-multiplexes the
``log2(N)(log2(N)+1)/2`` stages onto the 8x128 VPU lanes, each stage being a
single vectorized compare-exchange over the whole batch held in VMEM. The
stage count of Eq. 1 is preserved exactly; only the per-stage constant
changes (one VPU pass instead of one FPGA cycle).

Layout trick: a compare-exchange at stride ``2^j`` is a reshape to
``(n / 2^(j+1), 2, 2^j)`` followed by elementwise min/max between the two
middle-axis halves — no gathers, so every stage is pure VPU work.

Stability (the consistency-model requirement that same-address requests
keep arrival order) is obtained by comparing ``(key, arrival_id)``
lexicographically; ids are unique, so the network implements a total order
and the result equals a stable sort by key.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compare_exchange(keys, ids, vals, j_exp: int, k_exp: int):
    """One network stage: stride 2^j_exp within direction blocks of 2^k_exp."""
    n = keys.shape[-1]
    j = 1 << j_exp
    shape = (n // (2 * j), 2, j)

    def split(x):
        x = x.reshape(shape)
        return x[:, 0, :], x[:, 1, :]

    ka, kb = split(keys)
    ia, ib = split(ids)
    va, vb = split(vals)

    # Direction of the sub-block each pair lives in: element index of the
    # pair's first slot is c*2j + t; its K-block is (c*2j) >> k_exp.
    c = jax.lax.broadcasted_iota(jnp.int32, (shape[0], 1), 0)
    ascending = ((c * 2 * j) >> k_exp) % 2 == 0

    gt = (ka > kb) | ((ka == kb) & (ia > ib))   # composite (key, id) order
    swap = jnp.where(ascending, gt, ~gt)

    def merge(a, b):
        lo = jnp.where(swap, b, a)
        hi = jnp.where(swap, a, b)
        return jnp.stack([lo, hi], axis=1).reshape(n)

    return merge(ka, kb), merge(ia, ib), merge(va, vb)


def sort_network(keys, ids, vals):
    """Run the full bitonic network on 1-D int32 arrays (n a power of two)."""
    n = keys.shape[-1]
    assert n & (n - 1) == 0, "bitonic network needs a power-of-two batch"
    m = n.bit_length() - 1
    for k_exp in range(1, m + 1):
        for j_exp in range(k_exp - 1, -1, -1):
            keys, ids, vals = _compare_exchange(keys, ids, vals, j_exp, k_exp)
    return keys, ids, vals


def _sort_kernel(keys_ref, vals_ref, out_keys_ref, out_perm_ref,
                 out_vals_ref):
    """Sort one scheduler batch (a grid row) resident in VMEM."""
    keys = keys_ref[0, :]
    vals = vals_ref[0, :]
    n = keys.shape[-1]
    ids = jax.lax.iota(jnp.int32, n)
    skeys, sids, svals = sort_network(keys, ids, vals)
    out_keys_ref[0, :] = skeys
    out_perm_ref[0, :] = sids
    out_vals_ref[0, :] = svals


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_batched(keys: jnp.ndarray, vals: jnp.ndarray,
                         *, interpret: bool = True):
    """Sort each row of ``keys (G, N)`` with payload ``vals``; returns
    (sorted_keys, perm, sorted_vals). N must be a power of two; each grid
    step sorts one batch entirely in VMEM (the scheduler's double-buffered
    queue fits VMEM for every Table-I batch size)."""
    g, n = keys.shape
    grid = (g,)
    blk = lambda: pl.BlockSpec((1, n), lambda i: (i, 0))
    return pl.pallas_call(
        _sort_kernel,
        grid=grid,
        in_specs=[blk(), blk()],
        out_specs=(blk(), blk(), blk()),
        out_shape=(
            jax.ShapeDtypeStruct((g, n), keys.dtype),
            jax.ShapeDtypeStruct((g, n), jnp.int32),
            jax.ShapeDtypeStruct((g, n), vals.dtype),
        ),
        interpret=interpret,
    )(keys, vals)
