"""Pure-jnp oracle for the bitonic sort kernel: a stable key sort."""

import jax.numpy as jnp


def sort_with_indices_ref(keys: jnp.ndarray, vals: jnp.ndarray):
    """Row-wise stable sort; returns (sorted_keys, perm, sorted_vals)."""
    perm = jnp.argsort(keys, axis=-1, stable=True)
    sorted_keys = jnp.take_along_axis(keys, perm, axis=-1)
    sorted_vals = jnp.take_along_axis(vals, perm, axis=-1)
    return sorted_keys, perm.astype(jnp.int32), sorted_vals
