"""Jitted public wrapper for the bitonic-sort scheduler kernel.

Handles non-power-of-two batch sizes by padding with a +inf sentinel key
(INT32_MAX), which sorts to the tail and is sliced off — matching the FPGA
scheduler's behaviour of issuing a partially filled batch at timeout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitonic_sort.kernel import bitonic_sort_batched

_SENTINEL = jnp.iinfo(jnp.int32).max


def _next_pow2(n: int) -> int:
    return 1 << max(1, (n - 1).bit_length())


def sort_with_indices(keys: jnp.ndarray, vals: jnp.ndarray | None = None,
                      *, interpret: bool = True):
    """Stable-sort ``keys`` (1-D or (G, N)) via the Pallas network.

    Returns (sorted_keys, perm) when ``vals`` is None else
    (sorted_keys, perm, sorted_vals). ``perm`` indexes arrival order —
    apply it to payloads, invert it to unsort responses.
    """
    squeeze = keys.ndim == 1
    k2 = keys[None, :] if squeeze else keys
    v2 = (jnp.zeros_like(k2) if vals is None
          else (vals[None, :] if squeeze else vals))
    g, n = k2.shape
    n_pad = _next_pow2(n)
    if n_pad != n:
        k2 = jnp.pad(k2, ((0, 0), (0, n_pad - n)),
                     constant_values=_SENTINEL)
        v2 = jnp.pad(v2, ((0, 0), (0, n_pad - n)))
    skeys, perm, svals = bitonic_sort_batched(k2.astype(jnp.int32),
                                              v2, interpret=interpret)
    skeys, perm, svals = skeys[:, :n], perm[:, :n], svals[:, :n]
    if squeeze:
        skeys, perm, svals = skeys[0], perm[0], svals[0]
    if vals is None:
        return skeys, perm
    return skeys, perm, svals
