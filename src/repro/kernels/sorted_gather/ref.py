"""Pure-jnp oracle for sorted_gather: plain row gather."""

import jax.numpy as jnp


def gather_ref(table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, indices, axis=0)
