"""Sorted-gather — the scheduler's locality payoff, in Pallas.

The FPGA scheduler reorders a batch so same-row requests reach DRAM
back-to-back and hit the open row buffer. The TPU analogue: feed *sorted*
row indices to a scalar-prefetch gather whose BlockSpec index map selects
``table[idx[i]]``. The Pallas pipeline emitter skips the HBM→VMEM copy when
consecutive grid steps map to the same block — so after sorting, duplicate
rows cost **zero additional HBM traffic**, exactly the row-buffer-hit
economics of the paper (and why the wrapper sorts first).

Block shape: one table row per grid step, padded to the (8, 128)-lane
layout by the compiler; rows are contiguous HBM bursts, so the sorted
stream is also quasi-sequential for the HBM controller.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_row_kernel(idx_ref, table_ref, out_ref):
    # idx_ref is the scalar-prefetch operand; the index map already steered
    # the pipeline to the right table row, so the body is a VMEM move.
    del idx_ref
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("rows_per_step", "interpret"))
def gather_rows(table: jnp.ndarray, sorted_idx: jnp.ndarray,
                *, rows_per_step: int = 1, interpret: bool = True):
    """Gather ``table[sorted_idx]``; callers must pass sorted indices to get
    the dedup/locality behaviour (unsorted input is still correct)."""
    n = sorted_idx.shape[0]
    d = table.shape[1]
    assert rows_per_step == 1, "one row per grid step (revisit-dedup unit)"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(sorted_idx.astype(jnp.int32), table)
