"""Public sorted-gather op: schedule (sort) → gather → unsort.

``sorted_gather(table, idx)`` is value-identical to ``table[idx]``. The
request stream is stable-sorted by row id (the scheduler), the Pallas
gather streams rows with HBM locality + revisit dedup, and the inverse
permutation restores arrival order (the Fig. 2 read-pointer writeback).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitonic_sort import ops as bitonic_ops
from repro.kernels.sorted_gather.kernel import gather_rows


def sorted_gather(table: jnp.ndarray, indices: jnp.ndarray,
                  *, use_bitonic: bool = False,
                  interpret: bool = True) -> jnp.ndarray:
    idx = indices.reshape(-1)
    if use_bitonic:
        _, perm = bitonic_ops.sort_with_indices(idx, interpret=interpret)
    else:
        perm = jnp.argsort(idx, stable=True)
    sorted_idx = jnp.take(idx, perm, axis=0)
    gathered = gather_rows(table, sorted_idx, interpret=interpret)
    inv_perm = jnp.argsort(perm, stable=True)
    out = jnp.take(gathered, inv_perm, axis=0)
    return out.reshape(*indices.shape, table.shape[-1])
