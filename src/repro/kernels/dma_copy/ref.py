"""Oracle for dma_copy: the identity copy."""

import jax.numpy as jnp


def dma_copy_ref(src: jnp.ndarray) -> jnp.ndarray:
    return jnp.array(src, copy=True)
