"""DMA engine data plane — multi-channel double-buffered bulk copy.

Implements the paper's DMA engine (§IV-B) with TPU async copies: the
``num_parallel_dma`` FPGA buffers become ``channels`` VMEM staging slots,
each with inbound/outbound DMA semaphores. The kernel keeps up to
``channels`` inbound HBM→VMEM copies in flight while draining completed
slots back out — bulk transfers overlap exactly like parallel FPGA DMAs,
and ``max_transaction_bytes`` maps to the chunk (block) size.

Structure per chunk ``c`` on channel ``ch = c % channels``:
  wait outbound[ch] (slot free) → start inbound c → ... (channels in
  flight) ... → wait inbound[ch] → start outbound c.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dma_copy_kernel(in_ref, out_ref, scratch, in_sems, out_sems,
                     *, channels: int):
    num_chunks = in_ref.shape[0]

    def inbound(c):
        ch = c % channels
        return pltpu.make_async_copy(in_ref.at[c], scratch.at[ch],
                                     in_sems.at[ch])

    def outbound(c):
        ch = c % channels
        return pltpu.make_async_copy(scratch.at[ch], out_ref.at[c],
                                     out_sems.at[ch])

    # Prologue: fill every channel with an in-flight inbound transfer.
    for ch in range(min(channels, num_chunks)):
        inbound(ch).start()

    def body(c, _):
        # Land chunk c, ship it out, and immediately refill the channel
        # with chunk c+channels (if any).
        inbound(c).wait()
        outbound(c).start()
        nxt = c + channels

        @pl.when(nxt < num_chunks)
        def _():
            # Slot reuse hazard: the outbound of chunk c must complete
            # before its scratch slot is overwritten by chunk c+channels.
            outbound(c).wait()
            inbound(nxt).start()

        return 0

    jax.lax.fori_loop(0, num_chunks, body, 0)

    # Epilogue: drain the tail outbound transfers that were never waited
    # on by a refill.
    tail = max(0, num_chunks - channels)
    for c in range(tail, num_chunks):
        outbound(c).wait()


@functools.partial(jax.jit, static_argnames=("channels", "interpret"))
def dma_copy_chunked(src: jnp.ndarray, *, channels: int = 4,
                     interpret: bool = True) -> jnp.ndarray:
    """Copy ``src (num_chunks, chunk_elems)`` through the staging pipeline."""
    num_chunks, chunk = src.shape
    return pl.pallas_call(
        functools.partial(_dma_copy_kernel, channels=channels),
        in_specs=[pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.MemorySpace.ANY),
        out_shape=jax.ShapeDtypeStruct((num_chunks, chunk), src.dtype),
        scratch_shapes=[
            pltpu.VMEM((channels, chunk), src.dtype),
            pltpu.SemaphoreType.DMA((channels,)),
            pltpu.SemaphoreType.DMA((channels,)),
        ],
        interpret=interpret,
    )(src)
