"""Public DMA-engine op: shape-agnostic bulk copy through staging buffers.

Chunks the flat payload into ``max_transaction_bytes`` transactions (the
DMA Request Mapper), pads the tail transaction, and runs the multi-channel
kernel. Value-identical to a copy of ``src``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import DMAConfig
from repro.kernels.dma_copy.kernel import dma_copy_chunked


def dma_copy(src: jnp.ndarray, *, config: DMAConfig | None = None,
             interpret: bool = True) -> jnp.ndarray:
    config = config or DMAConfig()
    flat = src.reshape(-1)
    elem = flat.dtype.itemsize
    chunk_elems = max(128, config.max_transaction_bytes // elem)
    n = flat.shape[0]
    num_chunks = max(1, -(-n // chunk_elems))
    pad = num_chunks * chunk_elems - n
    staged = jnp.pad(flat, (0, pad)).reshape(num_chunks, chunk_elems)
    out = dma_copy_chunked(staged, channels=config.num_parallel_dma,
                           interpret=interpret)
    return out.reshape(-1)[:n].reshape(src.shape)
