"""Pallas TPU kernels for the memory-controller hot paths.

Each kernel directory carries ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jitted public wrapper) and ``ref.py`` (pure-jnp oracle used by
the allclose test sweeps):

* ``bitonic_sort``  — the scheduler's reordering network (paper Fig. 2)
* ``sorted_gather`` — locality gather; Pallas revisit-skip = row-buffer hit
* ``cache_lookup``  — set-associative tag/LRU pipelines (paper Fig. 3/4)
* ``dma_copy``      — multi-channel double-buffered bulk engine (paper §IV-B)
* ``flash_attention`` — chunked attention; the DMA engine applied to KV streaming

Kernels target TPU (VMEM tiling, async copies); this container validates
them in ``interpret=True`` mode. Model code dispatches to XLA-path
equivalents for the CPU dry-run (``use_pallas`` config flag).
"""
