"""Pure-jnp oracle: dense masked attention in the model's (B,S,H,hd)
layout."""

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,S,H,hd); k,v: (B,S,KV,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k).astype(jnp.float32)
    s = s / np.sqrt(hd)
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v)
    return o.reshape(B, S, H, hd).astype(q.dtype)
