"""Flash attention — the DMA engine applied to KV-cache streaming.

The paper's DMA engine stages bulk transfers through parallel on-chip
buffers so the PE never waits on DRAM; here K/V blocks stream HBM→VMEM
through the Pallas pipeline (auto double-buffered) while the online-softmax
accumulators live entirely in VMEM scratch — the accumulator traffic that
dominates the XLA-path memory term (§Perf refuted-hypothesis log) simply
does not exist on this path.

Block-causal skip: fully-masked KV blocks are skipped with ``pl.when``
(compute) and their fetches deduped by clamping the block index map to the
last useful block (the Pallas pipeline skips refetching an unchanged
block) — the ragged-causal FLOP saving the dense XLA path cannot express.

Layout: one (batch, head) pair per grid row; GQA folds kv_head = head // G
into the K/V index maps, so grouped queries share the same streamed block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
                  q_block: int, kv_block: int, nk: int, causal: bool,
                  window, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q_start = qi * q_block
    k_start = ki * kv_block
    # block is live unless causality/window excludes it entirely
    live = jnp.bool_(True)
    if causal:
        live = k_start <= q_start + q_block - 1
    if window is not None:
        live = jnp.logical_and(live,
                               k_start + kv_block > q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        mask = jnp.ones((q_block, kv_block), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_i[:, 0]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_i[:, 0] = l_i[:, 0] * corr + p.sum(-1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_i[:, 0] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc[...] /
                    jnp.maximum(l_i[:, 0], 1e-37)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "group", "causal", "window", "q_block", "kv_block", "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,           # (BH, S, hd) — flattened (batch, head)
    k: jnp.ndarray,           # (BKV, S, hd)
    v: jnp.ndarray,
    *,
    group: int,               # q heads per kv head (GQA)
    causal: bool = True,
    window=None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
):
    BH, S, hd = q.shape
    scale = hd ** -0.5
    nq = S // q_block
    nk = S // kv_block
    assert S % q_block == 0 and S % kv_block == 0

    def kv_index(bh, qi, ki):
        # clamp skipped (fully-masked) blocks to the last live one: the
        # pipeline dedups the repeated fetch (row-buffer-hit economics)
        if causal:
            last_live = ((qi + 1) * q_block - 1) // kv_block
            ki = jnp.minimum(ki, last_live)
        return (bh // group, ki, 0)

    grid = (BH, nq, nk)
    kernel = functools.partial(
        _flash_kernel, q_block=q_block, kv_block=kv_block, nk=nk,
        causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_block, hd), kv_index),
            pl.BlockSpec((1, kv_block, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, hd), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
