"""Public flash-attention op in the model layout (B, S, H, hd)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal=True, window=None,
                    q_block=128, kv_block=128, interpret=True):
    """GQA flash attention; value-matches ``ref.attention_ref``."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    out = flash_attention_pallas(qf, kf, vf, group=G, causal=causal,
                                 window=window, q_block=q_block,
                                 kv_block=kv_block, interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
