"""Oracle for the cache-probe kernel: the functional cache engine.

``repro.core.cache_engine.lookup`` (the lax.scan LRU reference) is replayed
beat-for-beat; the touched way is recovered as the way whose age equals the
new clock stamp. Tests compare the kernel's metadata trajectory against
this, and independently against the pure-python ``hit_rate_oracle``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.cache_engine import CacheState, lookup


def cache_probe_ref(line_ids, tags, valid, age, clock):
    """Replay the kernel's contract through the core cache engine.

    Returns (hits, ways, tags', valid', age', clock') matching
    ``kernel.cache_probe``.
    """
    state = CacheState(tags=tags, valid=valid != 0, age=age,
                       data=jnp.zeros((*tags.shape, 1), jnp.float32),
                       clock=clock.reshape(()),
                       dirty=jnp.zeros(tags.shape, bool))
    hits, ways = [], []
    for lid in line_ids:
        state, hit, _ = lookup(state, lid, jnp.zeros((1,), jnp.float32))
        set_idx = int(lid) % tags.shape[0]
        way = int(jnp.argmax(state.age[set_idx] == state.clock))
        hits.append(int(hit))
        ways.append(way)
    return (jnp.asarray(hits, jnp.int32), jnp.asarray(ways, jnp.int32),
            state.tags, state.valid.astype(jnp.int32), state.age,
            state.clock.reshape(1))
