"""Cache-engine tag/LRU pipeline as a Pallas kernel (paper §IV-A, Fig. 3/4).

The FPGA cache engine runs a 4-stage PE pipeline (tag read → compare → LRU
decision → data access) and a 3-stage MEM fill pipeline sharing Tag RAM,
Data RAM and LRU state; shared-RAM hazards force one beat at a time. The
TPU kernel keeps the whole tag store + LRU age matrix in VMEM and walks the
request batch with a ``fori_loop`` — the sequential loop *is* the shared-RAM
stall semantics — while each beat's tag compare and LRU scan are vectorized
across the ways (VPU lanes), like the FPGA comparing all ways in parallel.

The kernel owns metadata only (tags/valid/age → hit?, way). The data path
(serving hit lines from the VMEM-resident Data RAM, filling victims from
HBM) is composed around it in ``ops.py`` — mirroring the paper's split
between the tag pipelines and the Data RAM port.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cache_probe_kernel(line_ids_ref, tags_ref, valid_ref, age_ref,
                        clock_ref, hits_ref, ways_ref, out_tags_ref,
                        out_valid_ref, out_age_ref, out_clock_ref):
    num_sets, _ = tags_ref.shape
    n = line_ids_ref.shape[0]

    # Copy-in the shared state (Tag RAM / valid bits / LRU ages).
    out_tags_ref[...] = tags_ref[...]
    out_valid_ref[...] = valid_ref[...]
    out_age_ref[...] = age_ref[...]

    def beat(i, clock):
        line = line_ids_ref[i]
        set_idx = line % num_sets
        tag = line // num_sets

        way_tags = out_tags_ref[set_idx, :]
        way_valid = out_valid_ref[set_idx, :]
        way_age = out_age_ref[set_idx, :]

        match = (way_valid != 0) & (way_tags == tag)      # parallel compare
        hit = jnp.any(match)
        hit_way = jnp.argmax(match)
        victim = jnp.argmin(way_age)                       # LRU (invalid=-1)
        way = jnp.where(hit, hit_way, victim).astype(jnp.int32)

        hits_ref[i] = hit.astype(jnp.int32)
        ways_ref[i] = way
        out_tags_ref[set_idx, way] = tag
        out_valid_ref[set_idx, way] = jnp.int32(1)
        out_age_ref[set_idx, way] = clock + 1   # stamp after advancing
        return clock + 1

    out_clock_ref[0] = jax.lax.fori_loop(0, n, beat, clock_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def cache_probe(line_ids: jnp.ndarray, tags: jnp.ndarray,
                valid: jnp.ndarray, age: jnp.ndarray, clock: jnp.ndarray,
                *, interpret: bool = True):
    """Run a request batch through the tag/LRU pipeline.

    Returns (hits (N,), way (N,), tags', valid', age', clock'). State
    arrays are VMEM-resident — even the largest Table III config (32K
    lines) is <1 MiB of metadata.
    """
    n = line_ids.shape[0]
    sets, ways = tags.shape
    any_spec = pl.BlockSpec(memory_space=pl.MemorySpace.ANY)
    return pl.pallas_call(
        _cache_probe_kernel,
        in_specs=[any_spec] * 5,
        out_specs=(any_spec,) * 6,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),          # hits
            jax.ShapeDtypeStruct((n,), jnp.int32),          # ways
            jax.ShapeDtypeStruct((sets, ways), jnp.int32),  # tags'
            jax.ShapeDtypeStruct((sets, ways), jnp.int32),  # valid'
            jax.ShapeDtypeStruct((sets, ways), jnp.int32),  # age'
            jax.ShapeDtypeStruct((1,), jnp.int32),          # clock'
        ),
        interpret=interpret,
    )(line_ids.astype(jnp.int32), tags, valid, age,
      clock.reshape(1).astype(jnp.int32))
