"""Public cached-gather op composing the tag/LRU kernel with the data path.

``cache_service(table, line_ids, state)``: probe all requests through the
cache pipeline, serve hits from the Data RAM, fill misses from ``table``
(the HBM side), and return data in arrival order + updated state — value
semantics identical to ``table[line_ids]``, property-tested.

Read-only service: like ``cache_engine.lookup`` it has no write-back
port, so states carrying dirty lines must be flushed before entering
(mixed read/write traces belong to ``cache_engine.simulate_trace_rw``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.cache_engine import CacheState
from repro.kernels.cache_lookup.kernel import cache_probe


def cache_service(table: jnp.ndarray, line_ids: jnp.ndarray,
                  state: CacheState, *, interpret: bool = True):
    """Returns (lines (N, d), hits (N,), new_state)."""
    hits, ways, tags, valid, age, clock = cache_probe(
        line_ids, state.tags, state.valid.astype(jnp.int32),
        state.age, state.clock, interpret=interpret)

    num_sets = state.tags.shape[0]
    set_idx = line_ids % num_sets

    # Data path. The kernel fixed the (set, way) placement of every beat;
    # replay the Data RAM in vectorized form: a beat's line is served from
    # cache iff it hit, where the cached value is whatever the most recent
    # fill of that (set, way) wrote — which, for a hit, is always the same
    # line id (tags matched), so the value equals table[line]. The fills
    # themselves come from HBM. Value-identity lets the Data RAM update be
    # expressed as a scatter of table rows.
    from_mem = jnp.take(table, line_ids, axis=0)
    lines = from_mem  # value-identical serve (hits avoid HBM on real HW)
    new_data = state.data.at[set_idx, ways].set(from_mem)

    # Read-only service: fills install clean lines; a hit keeps the way's
    # dirty bit (its Data RAM content is untouched).
    new_dirty = state.dirty.at[set_idx, ways].set(
        state.dirty[set_idx, ways] & (hits != 0))
    new_state = CacheState(tags=tags, valid=valid != 0, age=age,
                           data=new_data, clock=clock.reshape(()),
                           dirty=new_dirty)
    return lines, hits != 0, new_state
