"""``python -m repro.trace`` — trace one run, export Perfetto JSON +
cycle attribution.

The observability front door (ARCHITECTURE §11): run a workload through
``MemoryController.simulate`` with a
:class:`~repro.core.telemetry.TraceRecorder` attached, then

* write the Chrome-trace-event / Perfetto JSON
  (``repro.launch.tracing``) — open it at https://ui.perfetto.dev;
* write the :class:`~repro.core.telemetry.CycleAttribution` rollup
  (component totals, per-tenant, top-K hot rows) as JSON;
* print the human-readable attribution summary.

The positional argument is either

* a **golden case name** from ``tests/core/golden_cases.py``
  (``serving_hog_victim_weighted``, ``faults_ecc_storm``,
  ``paper_eval_gcn``, ...) — resolved against the repo checkout, so the
  CLI traces exactly the workload the regression suite pins; or
* a **JSON config path** describing a synthetic workload::

      {"workload": "poisson",         // or "hog_victim"
       "n": 3000, "seed": 3, "rate": 0.05,
       "num_pes": 1, "arb": "round_robin", "weights": null,
       "policy": "frfcfs", "window": 16, "starvation_cap": 16,
       "t_rfc": 420, "t_refi": 9363}

Examples::

    python -m repro.trace serving_hog_victim_weighted --validate
    python -m repro.trace my_workload.json --out t.json --attr a.json
"""

from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys

import numpy as np


def _find_golden_cases():
    """Locate ``tests/core/golden_cases.py`` (repo checkout or cwd) and
    import it as a standalone module; ``None`` when not found."""
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(here))
    for root in (os.getcwd(), repo):
        path = os.path.join(root, "tests", "core", "golden_cases.py")
        if os.path.exists(path):
            spec = importlib.util.spec_from_file_location(
                "repro_golden_cases", path)
            mod = importlib.util.module_from_spec(spec)
            spec.loader.exec_module(mod)
            return mod
    return None


def _run_golden(name: str, recorder):
    from repro.core.controller import MemoryController
    gc = _find_golden_cases()
    if gc is None:
        raise SystemExit("golden_cases.py not found — run from the repo "
                         "checkout or pass a JSON config path")
    if name in gc.SERVING_CASES:
        config, workload, arb_policy, weights = gc.SERVING_CASES[name]
        rows, rw, pe, arr = workload()
        return MemoryController(config).simulate(
            pe, rows, rw, gc.ROW_BYTES, arbiter_policy=arb_policy,
            weights=weights, arrival_cycle=arr, trace=recorder)
    if name in gc.CASES:
        config, trace_fn, multiport = gc.CASES[name]
        rows, rw = trace_fn()
        pe = None
        if multiport:
            pe = np.random.default_rng(2).integers(
                0, config.num_pes, rows.shape[0])
        return MemoryController(config).simulate(
            pe, rows, rw, gc.ROW_BYTES, trace=recorder)
    known = sorted(list(gc.CASES) + list(gc.SERVING_CASES))
    raise SystemExit(f"unknown golden case {name!r}; known: "
                     + ", ".join(known))


def _run_config(path: str, recorder):
    from repro.core.config import (DRAMSchedConfig,
                                   MemoryControllerConfig,
                                   SchedulerConfig, CacheConfig)
    from repro.core.controller import MemoryController
    from repro.data import synthetic

    with open(path) as fh:
        cfg = json.load(fh)
    n = int(cfg.get("n", 3000))
    rng = np.random.default_rng(int(cfg.get("seed", 0)))
    workload = cfg.get("workload", "poisson")
    if workload == "hog_victim":
        rows, rw, pe, arr = synthetic.hog_victim_workload(
            rng, n_victim=n // 5, n_hog=n - n // 5,
            victim_rate=float(cfg.get("rate", 0.05)) / 5,
            hog_rate=float(cfg.get("rate", 0.05)))
        num_pes = max(2, int(cfg.get("num_pes", 2)))
    elif workload == "poisson":
        rows = (np.floor(np.minimum(np.clip(rng.random(n), 1e-12, 1.0)
                                    ** -5.0, 2.0 ** 62)).astype(np.int64)
                - 1) % 8192
        rw = (rng.random(n) < 0.1).astype(np.int32)
        arr = synthetic.poisson_arrivals(rng, n,
                                         float(cfg.get("rate", 0.05)))
        num_pes = int(cfg.get("num_pes", 1))
        pe = rng.integers(0, num_pes, n) if num_pes > 1 else None
    else:
        raise SystemExit(f"unknown workload {workload!r} "
                         "(poisson | hog_victim)")
    mc_config = MemoryControllerConfig(
        num_pes=num_pes,
        scheduler=SchedulerConfig(enabled=False),
        cache=CacheConfig(enabled=False),
        dram_sched=DRAMSchedConfig(
            policy=cfg.get("policy", "frfcfs"),
            reorder_window=int(cfg.get("window", 16)),
            starvation_cap=int(cfg.get("starvation_cap", 16)),
            t_rfc=int(cfg.get("t_rfc", 0)),
            t_refi=int(cfg.get("t_refi", 0))))
    weights = cfg.get("weights")
    return MemoryController(mc_config).simulate(
        pe, rows, rw, 4096,
        arbiter_policy=cfg.get("arb", "round_robin"),
        weights=None if weights is None else tuple(weights),
        arrival_cycle=arr, trace=recorder)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="Trace one run; export Perfetto JSON + cycle "
                    "attribution.")
    ap.add_argument("case", help="golden case name or JSON config path")
    ap.add_argument("--out", default=None,
                    help="Perfetto trace output path "
                         "(default <case>.trace.json)")
    ap.add_argument("--attr", default=None,
                    help="attribution JSON output path "
                         "(default <case>.attr.json)")
    ap.add_argument("--validate", action="store_true",
                    help="re-validate the exported JSON against the "
                         "trace-event schema and print the counts")
    ap.add_argument("--top-k", type=int, default=10,
                    help="hot rows to report (default 10)")
    ap.add_argument("--max-slices", type=int, default=None,
                    help="cap per-request sojourn slices in the export")
    args = ap.parse_args(argv)

    from repro.core.telemetry import CycleAttribution, TraceRecorder
    from repro.launch import tracing

    recorder = TraceRecorder()
    if args.case.endswith(".json") or os.path.sep in args.case:
        result = _run_config(args.case, recorder)
        stem = os.path.splitext(os.path.basename(args.case))[0]
    else:
        result = _run_golden(args.case, recorder)
        stem = args.case

    out = args.out or f"{stem}.trace.json"
    attr_path = args.attr or f"{stem}.attr.json"
    counts = tracing.write_chrome_trace(
        out, recorder, max_request_slices=args.max_slices)
    att = CycleAttribution.from_pipeline(result, recorder)
    tracing.write_attribution(attr_path, att, top_k=args.top_k)

    print(f"trace: {out} ({counts['X']} slices, {counts['C']} counter "
          f"samples, {recorder.n_events} recorded events)")
    print(f"attribution: {attr_path}")
    if args.validate:
        with open(out) as fh:
            counts = tracing.validate_chrome_trace(json.load(fh))
        print(f"validated: {counts}")
    print()
    print(att.summary_text(top_k=args.top_k))
    return 0


if __name__ == "__main__":
    sys.exit(main())
