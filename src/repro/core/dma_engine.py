"""DMA engine — parallel bulk transfers (paper §IV-B).

The FPGA DMA engine owns N buffers, each servicing one in-flight bulk
transfer; FLITs of a transfer accumulate in a buffer until the transfer is
complete, then the external access is issued. On TPU the analogue is a
double-buffered async-copy pipeline: ``num_parallel_dma`` concurrent
HBM→VMEM copies of ``max_transaction_bytes`` each, overlapping transfer with
consumption. This module plans transfers (control plane) and executes them
(data plane: Pallas ``dma_copy`` kernel on TPU, dynamic-slice loop oracle
elsewhere).

The engine's purpose in the framework mirrors the paper's three advantages:
bulk requests reduce controller input traffic, streaming data bypasses the
cache (no pollution), and wide sequential bursts saturate HBM bandwidth
(Fig. 8's 20x case).
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import DMAConfig
from repro.core.timing import DRAMTimings, DDR4_2400


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    """One bulk transfer split into channel-assigned transactions."""

    channel: np.ndarray      # (num_txn,) channel id
    offset: np.ndarray       # (num_txn,) byte offset
    size: np.ndarray         # (num_txn,) byte size
    total_bytes: int

    @property
    def num_transactions(self) -> int:
        return int(self.offset.shape[0])


def plan_transfer(total_bytes: int, config: DMAConfig) -> TransferPlan:
    """Split ``total_bytes`` into <=max_transaction chunks round-robined
    over the parallel DMA channels (the DMA Request Mapper's job)."""
    if total_bytes <= 0:
        raise ValueError("transfer must move at least one byte")
    txn = config.max_transaction_bytes
    offsets = np.arange(0, total_bytes, txn, dtype=np.int64)
    sizes = np.minimum(txn, total_bytes - offsets).astype(np.int64)
    channels = (np.arange(offsets.shape[0]) % config.num_parallel_dma
                ).astype(np.int32)
    return TransferPlan(channel=channels, offset=offsets, size=sizes,
                        total_bytes=total_bytes)


def modeled_transfer_cycles(
    plan: TransferPlan,
    config: DMAConfig,
    timings: DRAMTimings = DDR4_2400,
) -> float:
    """Modeled FPGA cycles for a planned transfer (feeds Fig. 5/8 benches).

    Each transaction streams sequentially (one row activation plus
    row-buffer-hit bursts); channels overlap ideally up to the DRAM's
    single-device bandwidth, which we honor by only overlapping the
    activation latency, not the burst streaming.
    """
    bursts = np.ceil(plan.size / timings.burst_bytes)
    act = (timings.t_rcd + timings.t_cl) * timings.clock_ratio
    stream = bursts * timings.t_burst * timings.clock_ratio
    per_channel_act = np.zeros(config.num_parallel_dma)
    for ch, _ in zip(plan.channel, plan.size):
        per_channel_act[ch] += act
    return float(per_channel_act.max() + stream.sum())


def bulk_copy(
    src: jnp.ndarray,
    *,
    config: DMAConfig,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Bulk-read ``src`` through the DMA staging path.

    Data plane of the engine: on TPU this runs the double-buffered Pallas
    ``dma_copy`` kernel; the oracle path streams ``max_transaction``-sized
    slices (same access pattern, XLA-executed). Returns a fresh copy of
    ``src`` — the value-level identity is what makes the engine droppable
    into any model (enable/disable is purely a performance decision).
    """
    if use_pallas:
        from repro.kernels.dma_copy import ops as dma_ops
        return dma_ops.dma_copy(src, config=config)

    flat = src.reshape(-1)
    elem_bytes = flat.dtype.itemsize
    txn_elems = max(1, config.max_transaction_bytes // elem_bytes)
    n = flat.shape[0]
    num_txn = -(-n // txn_elems)
    pad = num_txn * txn_elems - n
    padded = jnp.pad(flat, (0, pad))

    def copy_txn(carry, i):
        chunk = jax.lax.dynamic_slice(padded, (i * txn_elems,), (txn_elems,))
        return carry, chunk

    _, chunks = jax.lax.scan(copy_txn, 0, jnp.arange(num_txn))
    return chunks.reshape(-1)[:n].reshape(src.shape)


def bulk_write(
    dst: jnp.ndarray,
    src: jnp.ndarray,
    *,
    config: DMAConfig,
    offset_elems: int = 0,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Bulk-write ``src`` into ``dst`` (flat offset) through the DMA path.

    Write-side twin of :func:`bulk_copy`: the transfer is staged in
    ``max_transaction``-sized chunks per channel buffer, then streamed to
    the destination as wide sequential bursts that bypass the cache (no
    pollution, paper §IV-B). Value-identical to
    ``dst.flat[offset:offset+src.size] = src`` — returning the updated
    array — so the engine can be toggled without changing results.
    """
    if use_pallas:
        from repro.kernels.dma_copy import ops as dma_ops
        src = dma_ops.dma_copy(src, config=config)   # staged read side

    dst_flat = dst.reshape(-1)
    src_flat = src.reshape(-1).astype(dst.dtype)
    elem_bytes = dst_flat.dtype.itemsize
    txn_elems = max(1, config.max_transaction_bytes // elem_bytes)
    n = src_flat.shape[0]
    if offset_elems < 0 or offset_elems + n > dst_flat.shape[0]:
        raise ValueError("bulk_write region out of destination bounds")

    full = n // txn_elems

    def write_txn(buf, i):
        start = i * txn_elems
        chunk = jax.lax.dynamic_slice(src_flat, (start,), (txn_elems,))
        return jax.lax.dynamic_update_slice(
            buf, chunk, (offset_elems + start,)), None

    out = dst_flat
    if full:
        out, _ = jax.lax.scan(write_txn, out, jnp.arange(full))
    tail = n - full * txn_elems
    if tail:                                   # ragged last transaction
        out = jax.lax.dynamic_update_slice(
            out, src_flat[full * txn_elems:],
            (offset_elems + full * txn_elems,))
    return out.reshape(dst.shape)


def channel_vmem_bytes(config: DMAConfig) -> int:
    """VMEM claimed by the engine (double-buffered staging per channel) —
    the TPU analogue of Fig. 5's URAM series."""
    return 2 * config.num_parallel_dma * config.buffer_bytes
