"""Set-parallel trace engine — the cache simulator at array speed.

The sequential cache engine (``cache_engine.simulate_trace(_rw)``) scans a
trace one beat at a time: a million-request trace is a million
``lax.scan`` steps, each touching a handful of lanes. This module exploits
the one algorithmic fact that makes the LRU cache *exactly* parallel:

**Set partition.** With ``set = line % num_sets`` every request touches
only the state rows of its own set, every victim write-back lands on a
line of the *same* set (``victim_line = tag * num_sets + set``), and every
fill/write-through access of the backing table hits a row of the same set
(``row % num_sets == set``). The trace, the cache state *and* the backing
table therefore partition cleanly by set index: simulating the per-set
subtraces independently — in any interleaving — produces bit-identical
final state, hit flags, served lines and table contents to the strict
one-beat-at-a-time scan.

Two passes:

1. **Tag pipeline** (``_tag_round``): the trace is grouped by set (stable
   argsort — arrival order preserved within each set) and driven through
   a ``lax.scan`` whose carry is only the control state
   (``tags/valid/age/dirty`` — no Data RAM, no table), with all per-beat
   inputs pre-arranged as contiguous ``(chunk, lanes)`` scan inputs so a
   step is pure vector arithmetic (no random gathers). Because real
   traces are skewed (a Zipf-hot line concentrates one set), subtraces
   are processed in ``chunk``-beat *rounds*, each round advancing only
   the lanes that still have work: total padded work is
   ``Σ_s ceil(count_s / chunk) · chunk ≤ N + num_sets · chunk`` no matter
   how skewed the trace. Once at most ``FINISH_LANES`` lanes survive the
   rounds switch to a geometric staircase (depths from ``TAIL_CHUNKS``):
   one shallow round retires the short lanes, then the few serial
   hot-set chains run deep narrow scans — all beats stay in-kernel, with
   no per-beat python tail. LRU ages stay bit-identical by stamping each
   beat with its *global* arrival position (``clock0 + i + 1``).

2. **Data reconstruction**: served lines, the final Data RAM and the
   final backing table are recovered from the tag-pipeline outputs with
   O(N log N) vectorized passes instead of being threaded through the
   scan. The key invariant (maintained by every producer in this module
   and by the FPGA design itself) is *clean-line coherence*: a valid
   clean way's data always equals the backing-table row it caches, so
   the value any read observes is simply the **last write to its line**
   before it — a real trace write, the pre-trace content of an
   initially-dirty way ("virtual write"), or, failing those, the
   original table row. Victim flushes and write-through stores are then
   per-line "latest event wins" scatters onto the table.

Lane counts and chunk lengths are rounded to powers of two so repeated
calls with similar trace shapes reuse the same compiled kernel.

The same set-partition argument powers two numpy siblings in
``cache_engine``: ``hit_rate_oracle`` (hit mask only) and
``filter_trace_rw`` (hit mask + keep set + victim write-backs — the
staged pipeline's CacheFilter stage, ARCHITECTURE §7), both lockstep
per-set walks validated against their dict-walk oracles.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


#: auto-dispatch guard: below this trace length the sequential scan's
#: compile/compute cost is already trivial and set-parallel launch
#: overhead is not worth paying.
MIN_PARALLEL_TRACE = 256


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def partition_by_set(line_ids: np.ndarray, num_sets: int):
    """Group a trace by cache set, preserving arrival order within sets.

    Returns ``(perm, starts, counts)``: ``perm`` stable-sorts the trace by
    set index, so set ``s`` owns sorted positions
    ``starts[s] : starts[s] + counts[s]`` (in arrival order).
    """
    set_idx = line_ids % num_sets
    # num_sets ≤ 32768 (Table I ceiling) → uint16 stable sort is radix,
    # ~4x faster than comparison sorting the int64 keys.
    perm = np.argsort(set_idx.astype(np.uint16), kind="stable")
    counts = np.bincount(set_idx, minlength=num_sets)
    starts = np.zeros(num_sets, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    return perm, starts, counts


# ---------------------------------------------------------------------------
# Pass 1 — tag pipeline (control state only)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("write_back",))
def _tag_round(tags, valid, age, dirty, clock0, lane_ids,
               tag_x, live_x, w_x, stamp_x, write_back):
    """One chunk of beats for the lanes in ``lane_ids``.

    ``lane_ids`` may be padded with the out-of-range id ``num_sets``:
    gathers clamp to a harmless row, the all-False ``live_x`` column makes
    every beat a no-op, and the write-back scatter drops the row (JAX
    out-of-bounds scatter semantics), so padding lanes never touch state.
    """
    num_sets, ways = tags.shape
    safe = jnp.clip(lane_ids, 0, num_sets - 1)

    way_iota = jnp.arange(ways, dtype=jnp.int32)[None, :]

    def step(carry, xs):
        # One-hot selects/updates throughout: XLA:CPU lowers per-lane
        # gather/scatter (x[rows, way], .at[rows, way].set) to scalar
        # loops, so the way dimension (≤16) is handled with elementwise
        # masks instead — the whole step is SIMD.
        tg, vd, ag, dt = carry
        tag, live, is_w, stamp = xs
        match = vd & (tg == tag[:, None])
        hit = jnp.any(match, axis=1)
        way = jnp.where(hit, jnp.argmax(match, axis=1),
                        jnp.argmin(ag, axis=1)).astype(jnp.int32)
        oh = way_iota == way[:, None]
        vic_tag = jnp.sum(jnp.where(oh, tg, 0), axis=1)
        way_valid = jnp.any(vd & oh, axis=1)
        way_dirty = jnp.any(dt & oh, axis=1)
        evict = (~hit) & way_valid & way_dirty & live
        keep_dirty = hit & way_dirty & ~is_w
        new_dirty = (is_w | keep_dirty) if write_back else keep_dirty
        stamp = clock0 + stamp
        upd = oh & live[:, None]
        tg = jnp.where(upd, tag[:, None], tg)
        vd = vd | upd
        ag = jnp.where(upd, stamp[:, None], ag)
        dt = jnp.where(upd, new_dirty[:, None], dt)
        return (tg, vd, ag, dt), (hit & live, way.astype(jnp.int8), evict,
                                  vic_tag)

    carry0 = (tags[safe], valid[safe], age[safe], dirty[safe])
    (tg2, vd2, ag2, dt2), ys = jax.lax.scan(
        step, carry0, (tag_x, live_x, w_x, stamp_x))
    sc = jnp.where(lane_ids < num_sets, safe, num_sets)
    return (tags.at[sc].set(tg2), valid.at[sc].set(vd2),
            age.at[sc].set(ag2), dirty.at[sc].set(dt2)), ys


#: switch the chunked rounds to the geometric tail staircase once at most
#: this many lanes still have work: short lanes die in one shallow round,
#: then the surviving hot-set chains run deep compacted scans (the
#: retired python finisher walked these beats on host copies instead —
#: a compacted ``_tag_round`` does a serial chain at ~1µs/beat with no
#: full-state host round trip).
FINISH_LANES = 64
#: tail round depths — a small fixed menu so the (chunk, lanes) shape
#: universe (and the jit compile cache) stays bounded.
TAIL_CHUNKS = (256, 1024, 4096, 16384, 65536)


def _run_tag_pipeline(state, lids: np.ndarray, rw: np.ndarray | None, *,
                      write_back: bool):
    """Drive the whole trace through chunked rounds of the tag pipeline.

    Returns the final control state plus arrival-order outcome vectors:
    ``hit``, ``way``, ``evict`` (dirty-victim eviction at this beat) and
    ``vic_tag`` (tag of the way replaced at this beat).
    """
    n = lids.shape[0]
    num_sets = int(state.tags.shape[0])
    ways = int(state.tags.shape[1])
    perm, starts, counts = partition_by_set(lids, num_sets)
    tag_s = (lids[perm] // num_sets).astype(np.int32)
    rw_s = (rw[perm] != 0) if rw is not None else np.zeros(n, bool)
    stamp_s = (perm + 1).astype(np.int32)
    chunk = _next_pow2(max(16, min(-(-n // num_sets), 65536)))
    max_count = int(counts.max())

    tags, valid, age, dirty = (state.tags, state.valid, state.age,
                               state.dirty)
    hit_a = np.zeros(n, bool)
    way_a = np.zeros(n, np.int32)
    evict_a = np.zeros(n, bool)
    victag_a = np.zeros(n, np.int64)
    rounds = []          # (ys device arrays, live-lane count, host idx/mask)
    done = 0
    while done < max_count:
        live = np.flatnonzero(counts > done).astype(np.int32)
        if live.shape[0] <= FINISH_LANES:
            # Geometric tail staircase: pick the smallest menu depth that
            # retires the shortest surviving lane — short lanes die in one
            # shallow round, then the hot-set chains run deep compacted
            # scans (~1µs/step at one lane) with no per-beat python.
            rem = counts[live] - done
            want = min(int(rem.max()), max(int(rem.min()), chunk))
            chunk_r = TAIL_CHUNKS[-1]
            for c in TAIL_CHUNKS:
                if c >= want:
                    chunk_r = c
                    break
        else:
            chunk_r = chunk
        offs = np.arange(chunk_r)
        k_pad = _next_pow2(max(1, live.shape[0]))
        lane_ids = np.full(k_pad, num_sets, np.int32)
        lane_ids[:live.shape[0]] = live
        # (chunk, k) layouts built directly — contiguous scan rows, no
        # transpose; dead slots hold garbage that live_x masks off.
        idx = np.clip(starts[live][None, :] + (done + offs)[:, None],
                      0, n - 1)
        mask = np.zeros((chunk_r, k_pad), bool)
        mask[:, :live.shape[0]] = (done + offs)[:, None] \
            < counts[live][None, :]
        pad = ((0, 0), (0, k_pad - live.shape[0]))
        tag_x = np.pad(tag_s[idx], pad)
        w_x = np.pad(rw_s[idx], pad)
        stamp_x = np.pad(stamp_s[idx], pad)

        (tags, valid, age, dirty), ys = _tag_round(
            tags, valid, age, dirty, state.clock, jnp.asarray(lane_ids),
            jnp.asarray(tag_x), jnp.asarray(mask), jnp.asarray(w_x),
            jnp.asarray(stamp_x), write_back)
        rounds.append((ys, live.shape[0], idx, mask))
        done += chunk_r
    # Unsort once at the end (the transfers drain the async dispatch
    # queue; sorted position -> arrival slot via the set-sort perm).
    for ys, k, idx, mask in rounds:
        m = mask[:, :k]
        dst = perm[idx[:, :k][m]]
        hit_a[dst] = np.asarray(ys[0])[:, :k][m]
        way_a[dst] = np.asarray(ys[1])[:, :k][m]
        evict_a[dst] = np.asarray(ys[2])[:, :k][m]
        victag_a[dst] = np.asarray(ys[3])[:, :k][m]
    set_idx = (lids % num_sets).astype(np.int64)
    return (tags, valid, age, dirty), hit_a, way_a, evict_a, victag_a, \
        set_idx


# ---------------------------------------------------------------------------
# Pass 2 — vectorized value reconstruction
# ---------------------------------------------------------------------------

def _resolve_last_writes(line_arr, val_arr):
    """Per-line forward fill over *position-ordered* entries.

    ``line_arr[k]`` is entry k's line; ``val_arr[k]`` is its value when it
    is a write record and -1 when it is a query. Entries must already be
    in position order (the callers build them in arrival order, virtual
    writes first). Returns, per entry, the value of the latest record on
    the same line at or before it (-1 if none).

    A stable sort on the line key alone groups lines while preserving
    position order (radix when lines fit uint16); the per-line fill is
    then one global running max of record row-indices after lifting each
    line's rows by a disjoint offset.
    """
    m = line_arr.shape[0]
    if m == 0:
        return np.empty(0, np.int64)
    if 0 <= int(line_arr.min()) and int(line_arr.max()) < (1 << 16):
        order = np.argsort(line_arr.astype(np.uint16), kind="stable")
    else:
        order = np.argsort(line_arr, kind="stable")
    line_o, val_o = line_arr[order], val_arr[order]
    gid = np.zeros(m, np.int64)
    gid[1:] = np.cumsum(line_o[1:] != line_o[:-1])
    ridx = np.where(val_o >= 0, np.arange(m), -1)
    ffill = np.maximum.accumulate(ridx + gid * (m + 1)) - gid * (m + 1)
    res = np.where(ffill >= 0, val_o[np.maximum(ffill, 0)], -1)
    out = np.empty(m, np.int64)
    out[order] = res
    return out


def _scatter_last(dst_np, idx, vals_np):
    """``dst[idx] = vals`` where the *latest* duplicate wins (arrival
    order = array order) — numpy fancy assignment resolves duplicates
    last-wins. Mutates and returns ``dst_np``."""
    dst_np[idx] = vals_np.astype(dst_np.dtype, copy=False)
    return dst_np


def _virtual_writes(state, num_sets, dirty_only: bool):
    """Pre-trace line values resident in the cache, as (line, flat-way)
    pairs. ``dirty_only``: clean ways mirror the table (the coherence
    invariant), so only dirty ways carry values the table does not."""
    valid = np.asarray(state.valid)
    mask = valid & np.asarray(state.dirty) if dirty_only else valid
    sets, ways = mask.shape
    s_grid = np.repeat(np.arange(sets, dtype=np.int64), ways)
    flat = np.flatnonzero(mask.reshape(-1))
    lines = np.asarray(state.tags).reshape(-1).astype(np.int64)[flat] \
        * num_sets + s_grid[flat]
    return lines, flat


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def simulate_trace_parallel(state, line_ids, table):
    """Set-parallel equivalent of ``cache_engine.simulate_trace_seq``.

    Bit-identical final state / hits / lines; up to ``num_sets``-way
    parallelism. Requires concrete ``line_ids`` and a dirty-free starting
    state (the read path has no write-back port — the same contract as
    ``cache_engine.lookup``; the auto dispatcher checks and falls back).
    """
    from repro.core.cache_engine import CacheState

    lids = np.asarray(line_ids, dtype=np.int64)
    n = int(lids.shape[0])
    elems = state.data.shape[-1]
    if n == 0:
        return (state, jnp.zeros((0,), bool),
                jnp.zeros((0, elems), state.data.dtype))
    num_sets = int(state.tags.shape[0])
    ways = int(state.tags.shape[1])

    (tags, valid, age, dirty), hit_a, way_a, _, _, set_idx = \
        _run_tag_pipeline(state, lids, None, write_back=False)

    # Clean coherent state ⇒ every hit serves exactly the table row, and
    # every miss fills from it: lines == table[lids] wholesale.
    table_np = np.asarray(table)
    lines_np = table_np[np.clip(lids, 0, table_np.shape[0] - 1)]
    data_np = _scatter_last(
        np.asarray(state.data).reshape(num_sets * ways, elems).copy(),
        set_idx * ways + way_a, lines_np)
    final = CacheState(tags=tags, valid=valid, age=age,
                       data=jnp.asarray(data_np).reshape(num_sets, ways,
                                                         elems),
                       clock=state.clock + jnp.int32(n), dirty=dirty)
    return final, jnp.asarray(hit_a), jnp.asarray(
        lines_np.astype(np.asarray(state.data).dtype, copy=False))


def simulate_trace_rw_parallel(state, line_ids, rw, write_lines, table, *,
                               write_back: bool):
    """Set-parallel equivalent of ``cache_engine.simulate_trace_rw_seq``.

    Pass 1 resolves hits/ways/evictions; pass 2 reconstructs values: the
    line a read observes is the latest same-line write before it (trace
    write, or the pre-trace content of an initially dirty way, else the
    original table row — clean ways mirror the table by the coherence
    invariant), victim flushes carry the same resolved value, and the
    final table applies flush/write-through events latest-wins per line.

    Requires concrete ``line_ids``/``rw`` with every id in
    ``[0, table_rows)`` and matching table/data/payload dtypes — the auto
    dispatcher in ``cache_engine`` checks all of this and falls back.
    """
    from repro.core.cache_engine import CacheState

    lids = np.asarray(line_ids, dtype=np.int64)
    n = int(lids.shape[0])
    elems = state.data.shape[-1]
    if n == 0:
        return (state, table, jnp.zeros((0,), bool),
                jnp.zeros((0, elems), state.data.dtype))
    num_sets = int(state.tags.shape[0])
    ways = int(state.tags.shape[1])
    rw_np = np.asarray(rw, np.int32)
    is_w = rw_np != 0

    (tags, valid, age, dirty), hit_a, way_a, evict_a, victag_a, set_idx = \
        _run_tag_pipeline(state, lids, rw_np, write_back=write_back)

    # --- value resolution (host-side; pure copies, bit-exact) ------------
    # Value space: trace write payloads [0, n) ++ pre-trace way contents
    # [n, n + sets*ways).
    wl_np = np.asarray(write_lines).reshape(n, elems)
    data0_np = np.asarray(state.data).reshape(num_sets * ways, elems)
    table_np = np.asarray(table)

    virt_lines, virt_flat = _virtual_writes(state, num_sets,
                                            dirty_only=True)
    w_pos = np.flatnonzero(is_w)
    r_pos = np.flatnonzero(~is_w)
    e_pos = np.flatnonzero(evict_a)
    vic_line = victag_a[e_pos] * num_sets + set_idx[e_pos]

    # Build the entry list already in position order: virtual writes
    # first (pre-trace), then one entry per beat — a write is a record
    # (its own payload index), a read is a query — with each dirty
    # eviction's flush query slotted right beside its beat. Same-position
    # entries are always on different lines, so their relative order is
    # immaterial.
    nv = virt_lines.shape[0]
    slot = np.arange(n, dtype=np.int64) + nv
    slot[1:] += np.cumsum(evict_a[:-1])
    ev_slot = slot[e_pos] + 1
    m = nv + n + e_pos.shape[0]
    line_arr = np.empty(m, np.int64)
    val_arr = np.full(m, -1, np.int64)
    line_arr[:nv] = virt_lines
    val_arr[:nv] = n + virt_flat
    line_arr[slot] = lids
    val_arr[slot[w_pos]] = w_pos
    line_arr[ev_slot] = vic_line
    lw_all = _resolve_last_writes(line_arr, val_arr)
    lw_read = lw_all[slot[r_pos]]
    lw_evict = lw_all[ev_slot]

    def resolve(lw_idx):
        """Gather values for resolved last-write indices (≥ 0)."""
        out = np.empty((lw_idx.shape[0], elems), wl_np.dtype)
        real = lw_idx < n
        out[real] = wl_np[lw_idx[real]]
        out[~real] = data0_np[lw_idx[~real] - n]
        return out

    # Reads: latest write else the original table row. Writes: payload.
    lines_np = np.empty((n, elems), wl_np.dtype)
    lines_np[w_pos] = wl_np[w_pos]
    found = lw_read >= 0
    lines_np[r_pos[found]] = resolve(lw_read[found])
    lines_np[r_pos[~found]] = table_np[lids[r_pos[~found]]]

    # Final Data RAM: the last beat to touch each way leaves its line.
    data_np = _scatter_last(data0_np.copy(), set_idx * ways + way_a,
                            lines_np)

    # Final table: victim flushes (a dirty way was written — lw exists)
    # plus, under write-through, every trace write; latest event per line
    # wins.
    flush_vals = resolve(np.maximum(lw_evict, 0))
    if write_back:
        ev_line, ev_pos = vic_line, e_pos
        ev_vals = flush_vals
    else:
        ev_line = np.concatenate([vic_line, lids[w_pos]])
        ev_pos = np.concatenate([e_pos, w_pos])
        ev_vals = np.concatenate([flush_vals, wl_np[w_pos]], axis=0)
    new_table = table
    if ev_line.size:
        # Clip like access_rw does. Trace-installed victims are in-bounds
        # by the dispatcher's checks; this only fires on forced-parallel
        # calls with out-of-range resident dirty lines (where auto would
        # have fallen back to the sequential path).
        ev_line = np.clip(ev_line, 0, table_np.shape[0] - 1)
        order = np.lexsort((ev_pos, ev_line))
        last = np.ones(order.shape[0], bool)
        last[:-1] = ev_line[order][1:] != ev_line[order][:-1]
        win = order[last]
        table_out = table_np.copy()
        table_out[ev_line[win]] = ev_vals[win].astype(table_np.dtype,
                                                      copy=False)
        new_table = jnp.asarray(table_out)

    final = CacheState(
        tags=tags, valid=valid, age=age,
        data=jnp.asarray(data_np).reshape(num_sets, ways, elems),
        clock=state.clock + jnp.int32(n), dirty=dirty)
    return final, new_table, jnp.asarray(hit_a), jnp.asarray(lines_np)


def _clean_ways_coherent(state, table) -> bool:
    """The coherence precondition of the value-reconstruction pass: every
    valid *clean* way's data must mirror the table row it caches (and the
    cached line must exist in this table). True for any state/table pair
    produced against the same table lineage by this module; a state
    warmed against a *different* table fails and must take the
    sequential path. NaNs compare unequal, which conservatively falls
    back."""
    num_sets, ways = state.tags.shape
    valid = np.asarray(state.valid)
    clean = valid & ~np.asarray(state.dirty)
    if not clean.any():
        return True
    tags = np.asarray(state.tags).astype(np.int64)
    lines = tags * num_sets + np.arange(num_sets, dtype=np.int64)[:, None]
    if int(lines[clean].max()) >= table.shape[0] \
            or int(lines[clean].min()) < 0:
        return False
    rows = np.asarray(table)[np.clip(lines, 0, table.shape[0] - 1)]
    mismatch = (rows != np.asarray(state.data)).any(axis=-1)
    return not bool((mismatch & clean).any())


def auto_parallel_ok(state, line_ids, *, rw=None, write_lines=None,
                     table=None, rw_path: bool = False) -> bool:
    """Dispatcher predicate: can this call take the set-parallel path
    with bit-identical results? Concrete inputs, big enough to matter,
    not single-set degenerate, and the per-path preconditions — read:
    dirty-free state; rw: in-bounds trace *and* resident-dirty line ids
    + uniform dtypes; both: clean resident ways coherent with the passed
    table (:func:`_clean_ways_coherent`)."""
    if not (_is_concrete(line_ids) and _is_concrete(state.tags)):
        return False
    lids = np.asarray(line_ids)
    n = lids.shape[0]
    if n < MIN_PARALLEL_TRACE:
        return False
    num_sets = int(state.tags.shape[0])
    if not rw_path:
        if table is not None and not _is_concrete(table):
            return False
        if table is not None and table.dtype != state.data.dtype:
            return False
        if bool(np.asarray(state.dirty).any()):
            return False
        # Negative ids wrap python-style through the sequential path's
        # jnp gather; the parallel path clamps — keep them sequential.
        if int(np.asarray(lids, np.int64).min(initial=0)) < 0:
            return False
        return table is None or _clean_ways_coherent(state, table)
    if not (_is_concrete(rw) and _is_concrete(write_lines)
            and _is_concrete(table)):
        return False
    if not (table.dtype == state.data.dtype == write_lines.dtype):
        return False
    lids64 = np.asarray(lids, np.int64)
    if not bool(lids64.min() >= 0 and lids64.max() < table.shape[0]):
        return False
    # Resident dirty lines flush during the trace — their targets must be
    # real table rows (the sequential path would clip; we fall back).
    virt_lines, _ = _virtual_writes(state, num_sets, dirty_only=True)
    if virt_lines.size and (int(virt_lines.min()) < 0
                            or int(virt_lines.max()) >= table.shape[0]):
        return False
    return _clean_ways_coherent(state, table)


# ---------------------------------------------------------------------------
# Out-of-order DRAM command scheduling — the chunked fast path
# ---------------------------------------------------------------------------

def simulate_dram_sched_fast(addrs, timings, sched, rw=None, *, trace=None):
    """Fast path of :func:`repro.core.timing.simulate_dram_sched` —
    bit-identical to ``simulate_dram_sched_seq`` (property-tested over
    policy x window x cap x refresh x rw x timings).

    The oracle's window walk has one exploitable invariant, the same one
    the windowed baseline simulator uses: **open-row state changes only
    when a miss is serviced**. Between miss services, FR-FCFS issues the
    pending row-hits oldest-first — which is exactly the frontier scan
    order — so the walk decomposes into scan runs (hits issue in arrival
    order against frozen bank state, misses defer) punctuated by miss
    services and their drains (deferred requests the newly opened row
    converts into hits).

    Dispatch:

    * ``fifo``/``frfcfs`` run :func:`_sched_fast_nocap` — a segmented
      scan over per-(bank,row) drain buckets: every drain is an O(1)
      bucket pop instead of an O(window) pending scan, the miss-heavy
      regime runs a branch-light tight loop with no per-event python
      round trips, and hit-heavy phases escalate to chunked array scans
      that issue whole row-hit runs in single vector ops.
    * ``frfcfs_cap`` keeps the starvation-budget event walk
      (:func:`_sched_fast_cap`): forced picks interleave state changes
      mid-drain, which couples the drain order to the bypass counters.

    ``trace`` keeps both hot paths untouched: the timing run completes
    first, then :func:`repro.core.telemetry.replay_sched_events`
    reconstructs the oracle's event stream from ``service_order``.
    """
    from repro.core.timing import _sched_result

    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.size
    if n == 0:
        return _sched_result(0, 0, 0, 0, 0, 0, sched.t_rfc, timings, [])
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    rw_arr = None if rw is None else np.asarray(rw, np.int32).ravel()
    if sched.policy != "frfcfs_cap":
        key_span = int(rows.max()) + 2 if n else 2
        if key_span < (1 << 61) // max(int(timings.num_banks), 1):
            res = _sched_fast_nocap(n, rows, banks, timings, sched, rw_arr)
            if trace is not None:
                from repro.core import telemetry
                telemetry.replay_sched_events(addrs, timings, sched,
                                              rw_arr, res, trace)
            return res
    return _sched_fast_cap(addrs, n, rows, banks, timings, sched, rw_arr,
                           trace=trace)


def _sched_fast_nocap(n, rows, banks, timings, sched, rw_arr):
    """Bucketed segmented scan for ``fifo``/``frfcfs`` (no starvation
    cap) — bit-identical to the oracle's window walk.

    Without a cap the pick rule is static: oldest row-ready hit, else
    oldest miss. Three structural facts make the walk cheap:

    * deferred requests drain **only** when a miss opens exactly their
      (bank, row) — so the pending window is kept as per-(bank, row)
      *buckets* and a drain is one dict pop over exactly the converted
      requests (the oracle's O(window) rescan per event disappears);
    * the oldest pending miss is popped through an append-only arrival
      list with a lazy-deletion head (drained entries are flagged and
      skipped), so window-full events are O(1);
    * bank state changes only at miss services, so while the frontier
      streams row hits the state is frozen and whole runs classify in
      one vector compare against packed (bank, row) keys — the tight
      loop escalates to chunked array scans after a long hit streak and
      falls back when the stream turns miss-heavy.

    Refresh is absorbed exactly as the oracle does: checked before every
    pick (scan hit, miss service, and each drained hit), closing every
    bank and re-anchoring the next boundary; a refresh that lands mid
    drain re-queues the unserved bucket tail.
    """
    from repro.core.timing import _sched_result

    w = sched.effective_window
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    t_wtr, t_rtw = timings.t_wtr, timings.t_rtw
    cost_hit = timings.t_cl + timings.t_burst
    cost_first = timings.t_rcd + timings.t_cl + timings.t_burst
    cost_conf = (timings.t_rp + timings.t_rcd + timings.t_cl
                 + timings.t_burst)
    nb = timings.num_banks

    key_span = int(rows.max()) + 2
    keys = banks * key_span + rows          # packed (bank, row) identity
    keys_l = keys.tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    cur = [-1] * nb                 # open packed key per bank, -1 closed
    buckets: dict[int, list[int]] = {}      # packed key -> deferred idxs
    order: list[int] = []           # deferred arrival order (append-only)
    head = 0                        # lazy-deletion read head into order
    drained = bytearray(n)          # 1 = served by a drain, skip on pop
    ndef = 0                        # live deferred count
    out_l: list[int] = []
    f = 0
    cycle = 0
    next_ref = t_refi if t_refi else float("inf")
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    streak = 0                      # consecutive tight-loop scan hits
    STREAK = 192                    # escalate to array scans past this
    grow = max(64, 4 * w)

    while True:
        if f >= n and ndef == 0:
            break
        # refresh precedes the next pick (one always follows: not done)
        while cycle >= next_ref:
            cycle += t_rfc
            n_ref += 1
            cur = [-1] * nb
            next_ref += t_refi
        # ---- scan phase: serve frontier hits, defer misses ----------
        while f < n and ndef < w:
            if cycle >= next_ref:
                cycle += t_rfc
                n_ref += 1
                cur = [-1] * nb
                next_ref += t_refi
                streak = 0
                continue
            if streak >= STREAK:
                # -- array burst: bank state is frozen while the
                # frontier streams hits, so whole runs classify in one
                # vector compare; the run is truncated by the room-th
                # miss or the next refresh boundary, exactly like the
                # tight loop it replaces
                kb = np.asarray(cur, np.int64)
                while f < n and ndef < w:
                    room = w - ndef
                    chunk = min(max(32, 4 * room, grow), n - f)
                    sl = slice(f, f + chunk)
                    hm = kb[banks[sl]] == keys[sl]
                    miss_rel = np.flatnonzero(~hm)
                    if miss_rel.size >= room:
                        take = int(miss_rel[room - 1]) + 1
                        miss_rel = miss_rel[:room]
                    else:
                        take = chunk
                    hit_rel = np.flatnonzero(hm[:take])
                    tcosts = None
                    if rw_arr is not None and hit_rel.size:
                        dirs = rw_arr[f + hit_rel]
                        prev = np.concatenate(([last_dir], dirs[:-1]))
                        tcosts = np.where(
                            (prev == 1) & (dirs == 0), t_wtr,
                            np.where((prev == 0) & (dirs == 1),
                                     t_rtw, 0)).astype(np.int64)
                    if t_refi and hit_rel.size:
                        costs = (np.full(hit_rel.size, cost_hit, np.int64)
                                 if tcosts is None else cost_hit + tcosts)
                        pre = cycle + np.concatenate(
                            ([0], np.cumsum(costs[:-1])))
                        cross = np.flatnonzero(pre >= next_ref)
                        if cross.size:           # cross[0] >= 1: see top
                            kcut = int(cross[0])
                            take = int(hit_rel[kcut])
                            hit_rel = hit_rel[:kcut]
                            miss_rel = miss_rel[miss_rel < take]
                            if tcosts is not None:
                                tcosts = tcosts[:kcut]
                    k = hit_rel.size
                    if k:
                        n_hit += k
                        if tcosts is None:
                            cycle += k * cost_hit
                        else:
                            tsum = int(tcosts.sum())
                            turn += tsum
                            cycle += k * cost_hit + tsum
                            last_dir = int(rw_arr[f + hit_rel[-1]])
                        out_l.extend((f + hit_rel).tolist())
                    if miss_rel.size:
                        for m in (f + miss_rel).tolist():
                            kk = keys_l[m]
                            lst = buckets.get(kk)
                            if lst is None:
                                buckets[kk] = [m]
                            else:
                                lst.append(m)
                            order.append(m)
                        ndef += miss_rel.size
                    f += take
                    if take < chunk or cycle >= next_ref:
                        break
                    grow = min(chunk * 2, 1 << 20)
                streak = 0
                grow = max(64, 4 * w)
                continue
            k = keys_l[f]
            if cur[k // key_span] == k:
                c = cost_hit
                if rw_l is not None:
                    d = rw_l[f]
                    if d != last_dir:
                        if last_dir == 1:
                            c += t_wtr
                            turn += t_wtr
                        elif last_dir == 0:
                            c += t_rtw
                            turn += t_rtw
                        last_dir = d
                n_hit += 1
                cycle += c
                out_l.append(f)
                streak += 1
            else:
                lst = buckets.get(k)
                if lst is None:
                    buckets[k] = [f]
                else:
                    lst.append(f)
                order.append(f)
                ndef += 1
                streak = 0
            f += 1
        if ndef == 0:
            continue
        if cycle >= next_ref:
            continue
        # ---- event: pop the oldest deferred miss --------------------
        while drained[order[head]]:
            head += 1
        d = order[head]
        head += 1
        ndef -= 1
        k = keys_l[d]
        if cur[k // key_span] == -1:
            n_first += 1
            c = cost_first
        else:
            n_conflict += 1
            c = cost_conf
        cur[k // key_span] = k
        if rw_l is not None:
            dd = rw_l[d]
            if dd != last_dir:
                if last_dir == 1:
                    c += t_wtr
                    turn += t_wtr
                elif last_dir == 0:
                    c += t_rtw
                    turn += t_rtw
                last_dir = dd
        cycle += c
        out_l.append(d)
        streak = 0
        # ---- drain: the bucket holds exactly the converted hits -----
        lst = buckets.pop(k)        # lst[0] is d (oldest overall)
        for i in range(1, len(lst)):
            if cycle >= next_ref:
                buckets[k] = lst[i:]        # refresh mid-drain: re-queue
                break
            x = lst[i]
            c = cost_hit
            if rw_l is not None:
                dd = rw_l[x]
                if dd != last_dir:
                    if last_dir == 1:
                        c += t_wtr
                        turn += t_wtr
                    elif last_dir == 0:
                        c += t_rtw
                        turn += t_rtw
                    last_dir = dd
            n_hit += 1
            cycle += c
            out_l.append(x)
            drained[x] = 1
            ndef -= 1
    return _sched_result(n_first, n_hit, n_conflict, n, turn, n_ref,
                         t_rfc, timings, np.asarray(out_l, np.int64))


def _sched_fast_cap(addrs, n, rows, banks, timings, sched, rw_arr, *,
                    trace=None):
    """Starvation-budget event walk (``frfcfs_cap``, and the fallback
    for degenerate packed-key ranges): a vectorized frontier scan with
    one python event per serviced miss or forced pick. Forced picks
    interleave state changes mid-drain, which couples the drain order
    to the bypass counters — the reason this path keeps the explicit
    pending list the bucketed no-cap walk retires."""
    from repro.core.timing import _sched_result

    w = sched.effective_window
    use_cap = sched.policy == "frfcfs_cap"
    cap = sched.starvation_cap
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    t_wtr, t_rtw = timings.t_wtr, timings.t_rtw
    cost_hit = timings.t_cl + timings.t_burst
    cost_first = timings.t_rcd + timings.t_cl + timings.t_burst
    cost_conf = (timings.t_rp + timings.t_rcd + timings.t_cl
                 + timings.t_burst)

    open_arr = np.zeros(timings.num_banks, np.int64)
    opened = np.zeros(timings.num_banks, bool)
    # python mirrors of the per-request decode and bank state: the
    # miss-heavy regime steps request-at-a-time below, and list reads
    # are ~10x cheaper than numpy scalar indexing there
    banks_l = banks.tolist()
    rows_l = rows.tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    open_l = [0] * timings.num_banks
    opened_l = [False] * timings.num_banks
    deferred: list[int] = []    # scanned misses, arrival order
    byp: list[int] = []         # younger issues past each, parallel list
    out = np.empty(n, np.int64)
    out_n = 0
    f = 0
    cycle = 0
    next_ref = t_refi
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    grow = max(64, 4 * w)     # scan chunk; doubles through long hit runs
    MICRO = 96                # python-step budget in the miss-heavy mode

    def serve_scalar(idx: int) -> None:
        nonlocal n_hit, n_conflict, n_first, cycle, turn, last_dir, out_n
        b, r = banks_l[idx], rows_l[idx]
        if not opened_l[b]:
            n_first += 1
            c = cost_first
        elif open_l[b] == r:
            n_hit += 1
            c = cost_hit
        else:
            n_conflict += 1
            c = cost_conf
        opened_l[b] = True
        open_l[b] = r
        opened[b] = True
        open_arr[b] = r
        if rw_l is not None:
            d = rw_l[idx]
            if last_dir == 1 and d == 0:
                turn += t_wtr
                c += t_wtr
            elif last_dir == 0 and d == 1:
                turn += t_rtw
                c += t_rtw
            last_dir = d
        cycle += c
        out[out_n] = idx
        out_n += 1

    while f < n or deferred:
        if t_refi:
            while cycle >= next_ref:      # refresh precedes the issue
                cycle += t_rfc
                n_ref += 1
                opened[:] = False
                opened_l = [False] * timings.num_banks
                next_ref += t_refi
        if deferred and (len(deferred) >= w or f >= n
                         or (use_cap and byp[0] >= cap)):
            # -- event: issue the oldest pending miss, then drain the
            # deferred requests its open row converts into hits
            # (oldest-hit-first, interrupted by starvation forcing or a
            # refresh boundary exactly as the oracle's pick rule is)
            serve_scalar(deferred.pop(0))
            if use_cap:
                byp.pop(0)
                # scalar drain: starvation forcing can interleave state
                # changes (forced conflicts open new rows mid-drain)
                while deferred:
                    if t_refi and cycle >= next_ref:
                        break              # refresh re-evaluates state
                    if byp[0] >= cap:
                        i = 0              # oldest starved (byp sorted)
                    else:
                        d_arr = np.asarray(deferred, np.int64)
                        db = banks[d_arr]
                        cand = np.flatnonzero(
                            opened[db] & (open_arr[db] == rows[d_arr]))
                        if cand.size == 0:
                            break
                        i = int(cand[0])
                    serve_scalar(deferred.pop(i))
                    byp.pop(i)
                    for kk in range(i):    # older entries were bypassed
                        byp[kk] += 1
            elif deferred and len(deferred) <= 48:
                # hits never change state, so one pass over the (small)
                # window drains every conversion in age order
                cand_pos = [kk for kk, dd in enumerate(deferred)
                            if opened_l[banks_l[dd]]
                            and open_l[banks_l[dd]] == rows_l[dd]]
                if cand_pos:
                    served: list[int] = []
                    for kk in cand_pos:
                        if t_refi and cycle >= next_ref:
                            break
                        serve_scalar(deferred[kk])
                        served.append(kk)
                    if served:
                        drop = set(served)
                        deferred = [dd for kk, dd in enumerate(deferred)
                                    if kk not in drop]
            elif deferred:
                # same drain, vectorized for deep windows, cut only by
                # the refresh boundary
                d_arr = np.asarray(deferred, np.int64)
                db = banks[d_arr]
                cand = np.flatnonzero(
                    opened[db] & (open_arr[db] == rows[d_arr]))
                if cand.size:
                    idxs = d_arr[cand]
                    tcosts = None
                    if rw_arr is not None:
                        dirs = rw_arr[idxs]
                        prev = np.concatenate(([last_dir], dirs[:-1]))
                        tcosts = np.where(
                            (prev == 1) & (dirs == 0), t_wtr,
                            np.where((prev == 0) & (dirs == 1),
                                     t_rtw, 0)).astype(np.int64)
                    j = cand.size
                    if t_refi:
                        costs = (np.full(j, cost_hit, np.int64)
                                 if tcosts is None else cost_hit + tcosts)
                        pre = cycle + np.concatenate(
                            ([0], np.cumsum(costs[:-1])))
                        cross = np.flatnonzero(pre >= next_ref)
                        if cross.size:
                            j = int(cross[0])
                    if j:
                        n_hit += j
                        if tcosts is None:
                            cycle += j * cost_hit
                        else:
                            tsum = int(tcosts[:j].sum())
                            turn += tsum
                            cycle += j * cost_hit + tsum
                            last_dir = int(rw_arr[idxs[j - 1]])
                        out[out_n:out_n + j] = idxs[:j]
                        out_n += j
                        keep = np.ones(d_arr.size, bool)
                        keep[cand[:j]] = False
                        deferred = [d for d, m in zip(deferred, keep)
                                    if m]
            continue
        if f >= n:
            break
        if grow <= 32:
            # -- miss-heavy regime: python-step the frontier (the numpy
            # chunk overhead dwarfs its win on short hit runs). Exact
            # same semantics as the chunked scan below: serve hits in
            # arrival order, defer misses, stop on window-full /
            # starvation budget / refresh boundary / step budget.
            steps = 0
            while f < n and len(deferred) < w and steps < MICRO:
                if t_refi and cycle >= next_ref:
                    break
                if use_cap and byp and byp[0] >= cap:
                    break
                b, r = banks_l[f], rows_l[f]
                if opened_l[b] and open_l[b] == r:
                    c = cost_hit
                    if rw_l is not None:
                        d = rw_l[f]
                        if last_dir == 1 and d == 0:
                            turn += t_wtr
                            c += t_wtr
                        elif last_dir == 0 and d == 1:
                            turn += t_rtw
                            c += t_rtw
                        last_dir = d
                    n_hit += 1
                    cycle += c
                    out[out_n] = f
                    out_n += 1
                    if use_cap and byp:
                        byp = [x + 1 for x in byp]
                else:
                    deferred.append(f)
                    if use_cap:
                        byp.append(0)
                f += 1
                steps += 1
            if steps >= MICRO and len(deferred) < w:
                grow = 64          # long run — try the chunked scan
            continue
        # -- scan run: issue frontier hits, defer misses --------------
        room = w - len(deferred)
        chunk = min(max(32, 4 * room, grow), n - f)
        sl = slice(f, f + chunk)
        hm = opened[banks[sl]] & (open_arr[banks[sl]] == rows[sl])
        miss_rel = np.flatnonzero(~hm)
        if miss_rel.size >= room:
            take = int(miss_rel[room - 1]) + 1   # through the room-th miss
            miss_rel = miss_rel[:room]
        else:
            take = chunk
        hit_rel = np.flatnonzero(hm[:take])
        if use_cap and hit_rel.size:
            if deferred:
                # every hit here is younger than the oldest pending miss
                budget = cap - byp[0]            # >= 1: event checked above
                if hit_rel.size > budget:
                    take = int(hit_rel[budget])
                    hit_rel = hit_rel[:budget]
                    miss_rel = miss_rel[miss_rel < take]
            elif miss_rel.size:
                # only hits *after* the first new miss bypass it
                after = hit_rel[hit_rel > miss_rel[0]]
                if after.size > cap:
                    take = int(after[cap])
                    hit_rel = hit_rel[hit_rel < take]
                    miss_rel = miss_rel[miss_rel < take]
        tcosts = None
        if rw_arr is not None and hit_rel.size:
            dirs = rw_arr[f + hit_rel]
            prev = np.concatenate(([last_dir], dirs[:-1]))
            tcosts = np.where((prev == 1) & (dirs == 0), t_wtr,
                              np.where((prev == 0) & (dirs == 1),
                                       t_rtw, 0)).astype(np.int64)
        if t_refi and hit_rel.size:
            costs = (np.full(hit_rel.size, cost_hit, np.int64)
                     if tcosts is None else cost_hit + tcosts)
            pre = cycle + np.concatenate(([0], np.cumsum(costs[:-1])))
            cross = np.flatnonzero(pre >= next_ref)
            if cross.size:                       # cross[0] >= 1: see top
                kcut = int(cross[0])
                take = int(hit_rel[kcut])
                hit_rel = hit_rel[:kcut]
                miss_rel = miss_rel[miss_rel < take]
                if tcosts is not None:
                    tcosts = tcosts[:kcut]
        k = hit_rel.size
        if k:
            n_hit += k
            if tcosts is None:
                cycle += k * cost_hit
            else:
                tsum = int(tcosts.sum())
                turn += tsum
                cycle += k * cost_hit + tsum
                last_dir = int(rw_arr[f + hit_rel[-1]])
            out[out_n:out_n + k] = f + hit_rel
            out_n += k
        if use_cap:
            if k and byp:
                byp = [b + k for b in byp]
            if miss_rel.size:
                new_byp = k - np.searchsorted(hit_rel, miss_rel)
                byp.extend(int(b) for b in new_byp)
        if miss_rel.size:
            deferred.extend(int(m) for m in (f + miss_rel))
        f += take
        grow = chunk * 2 if take == chunk else 32
    res = _sched_result(n_first, n_hit, n_conflict, n, turn, n_ref,
                        t_rfc, timings, out)
    if trace is not None:
        from repro.core import telemetry
        telemetry.replay_sched_events(addrs, timings, sched, rw_arr, res,
                                      trace)
    return res


def simulate_arrivals_fast(addrs, timings, sched, rw=None, *,
                           arrival_fpga=None, pe_id=None, num_ports=None,
                           arb_policy="round_robin", weights=None,
                           trace=None):
    """Fast path of :func:`repro.core.timing.simulate_arrivals` —
    bit-identical to ``simulate_arrivals_seq`` (property-tested over
    arrival process x ports x arbiter policy x DRAM policy x window x
    cap x refresh x rw).

    Single-port streams admit in trace order, so the closed-loop
    chunked frontier scan of :func:`simulate_dram_sched_fast`
    generalizes: classify a frontier chunk against current bank state,
    issue every *arrived* hit in one array op and defer the arrived
    misses, with the run truncated by whichever binds first — the
    arrival gate (a request is admitted only once the clock reaches its
    stamp), the window filling with misses, the starvation budget, or
    the next refresh boundary — plus an idle-gap advance when the
    frontier itself is in the future. Multi-port streams couple
    admission to the arbiter's rotation state, where deferring a grant
    changes *which* port wins the slot, so they run an optimized
    event-at-a-time loop instead (python lists + anchored clock, same
    spec).

    Both paths track the clock as ``anchor + offset`` (float anchor set
    only at idle jumps, exact integer offset) exactly like the oracle,
    so batched integer cost sums land on bit-identical timestamps.

    ``trace`` keeps both hot paths untouched: the timing run completes
    first, then :func:`repro.core.telemetry.replay_arrival_events`
    reconstructs the oracle's event stream from ``grant_order`` /
    ``granted_port`` / ``service_order``.
    """
    from repro.core.timing import (ServingSimResult, _serving_trace,
                                   _serving_weights)

    addrs, n, rw_arr, arr, ports, nports = _serving_trace(
        addrs, timings, rw, arrival_fpga, pe_id, num_ports)
    _serving_weights(nports, arb_policy, weights)   # validate up front
    if n == 0:
        return ServingSimResult(total_fpga_cycles=0.0, row_hits=0,
                                row_conflicts=0, first_accesses=0)
    if nports == 1:
        if sched.policy != "frfcfs_cap":
            rows_v = timings.row_of(addrs)
            key_span = int(rows_v.max()) + 2 if n else 2
        if (sched.policy != "frfcfs_cap"
                and key_span < (1 << 61) // max(int(timings.num_banks), 1)):
            res = _arrivals_fast_single_nocap(addrs, n, timings, sched,
                                              rw_arr, arr, ServingSimResult)
        else:
            res = _arrivals_fast_single(addrs, n, timings, sched, rw_arr,
                                        arr, ServingSimResult)
    else:
        res = _arrivals_fast_multi(addrs, n, timings, sched, rw_arr, arr,
                                   ports, nports, arb_policy, weights,
                                   ServingSimResult)
    if trace is not None:
        from repro.core import telemetry
        telemetry.replay_arrival_events(
            addrs, timings, sched, rw_arr, arrival_fpga=arrival_fpga,
            pe_id=pe_id, num_ports=num_ports, result=res, trace=trace)
    return res


def _arrivals_fast_single_nocap(addrs, n, timings, sched, rw_arr, arr,
                                result_cls):
    """Arrival-gated bucketed segmented scan (single admission queue,
    ``fifo``/``frfcfs``) — the open-loop sibling of
    :func:`_sched_fast_nocap`: per-(bank, row) drain buckets and a
    lazy-deletion pending list replace the O(window) pending rescan per
    event, with the scan additionally truncated by the arrival gate (a
    request is admitted only once the clock reaches its stamp) and an
    idle-gap advance (refreshes completing inside the gap overlap with
    idleness; one in progress at the target delays the next issue to
    its end — the oracle's absorb rule). The clock is ``anchor + off``
    (float anchor set only at idle jumps, exact integer offset), so
    batched cost sums land on bit-identical timestamps."""
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    w = sched.effective_window
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    t_wtr, t_rtw = timings.t_wtr, timings.t_rtw
    cost_hit = timings.t_cl + timings.t_burst
    cost_first = timings.t_rcd + timings.t_cl + timings.t_burst
    cost_conf = (timings.t_rp + timings.t_rcd + timings.t_cl
                 + timings.t_burst)
    nb = timings.num_banks

    key_span = int(rows.max()) + 2
    keys = banks * key_span + rows
    keys_l = keys.tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    arr_l = arr.tolist()
    cur = [-1] * nb
    buckets: dict[int, list[int]] = {}
    order: list[int] = []
    head = 0
    drained = bytearray(n)
    ndef = 0
    out = np.empty(n, np.int64)
    out_n = 0
    completion = np.zeros(n, np.float64)
    service = np.zeros(n, np.int64)
    f = 0
    anchor = 0                  # float once the channel has idled
    off = 0                     # exact integer clocks since anchor
    next_ref = t_refi if t_refi else float("inf")
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    streak = 0
    STREAK = 192
    grow = max(64, 4 * w)
    idle = 0.0

    while True:
        if f >= n and ndef == 0:
            break
        if ndef == 0 and arr_l[f] > anchor + off:
            # idle-gap advance with the oracle's refresh-absorb rule
            target = arr_l[f]
            if t_refi:
                while next_ref <= target:
                    n_ref += 1
                    cur = [-1] * nb
                    end = next_ref + t_rfc
                    next_ref += t_refi
                    if end > target:
                        target = end
            idle += target - (anchor + off)
            anchor, off = target, 0
            streak = 0
        while anchor + off >= next_ref:     # refresh precedes the pick
            off += t_rfc
            n_ref += 1
            cur = [-1] * nb
            next_ref += t_refi
        # ---- scan phase: serve arrived hits, defer arrived misses ---
        while f < n and ndef < w and arr_l[f] <= anchor + off:
            if anchor + off >= next_ref:
                off += t_rfc
                n_ref += 1
                cur = [-1] * nb
                next_ref += t_refi
                streak = 0
                continue
            if streak >= STREAK:
                # -- array burst: state frozen while hits stream; runs
                # truncated by the arrival gate, the room-th miss, or
                # the refresh boundary
                kb = np.asarray(cur, np.int64)
                while f < n and ndef < w and arr_l[f] <= anchor + off:
                    room = w - ndef
                    chunk = min(max(32, 4 * room, grow), n - f)
                    sl = slice(f, f + chunk)
                    hm = kb[banks[sl]] == keys[sl]
                    hit_all = np.flatnonzero(hm)
                    costs_full = np.zeros(chunk, np.int64)
                    tc = None
                    if rw_arr is not None and hit_all.size:
                        dirs = rw_arr[f + hit_all]
                        prev = np.concatenate(([last_dir], dirs[:-1]))
                        tc = np.where(
                            (prev == 1) & (dirs == 0), t_wtr,
                            np.where((prev == 0) & (dirs == 1),
                                     t_rtw, 0)).astype(np.int64)
                        costs_full[hit_all] = cost_hit + tc
                    else:
                        costs_full[hit_all] = cost_hit
                    ends_full = off + np.cumsum(costs_full)
                    pre_full = ends_full - costs_full
                    take = chunk
                    late = np.flatnonzero(arr[sl] > anchor + pre_full)
                    if late.size:
                        take = int(late[0])
                    miss_rel = np.flatnonzero(~hm[:take])
                    if miss_rel.size >= room:
                        t2 = int(miss_rel[room - 1]) + 1
                        if t2 < take:
                            take = t2
                        miss_rel = miss_rel[:room]
                    hit_rel = hit_all[hit_all < take]
                    if t_refi and hit_rel.size:
                        cross = np.flatnonzero(
                            anchor + pre_full[hit_rel] >= next_ref)
                        if cross.size:       # cross[0] >= 1: refresh ran
                            kcut = int(cross[0])
                            take = int(hit_rel[kcut])
                            hit_rel = hit_rel[:kcut]
                            miss_rel = miss_rel[miss_rel < take]
                    k = hit_rel.size
                    if k:
                        n_hit += k
                        if tc is not None:
                            tsum = int(tc[:k].sum())  # hit_rel prefixes
                            turn += tsum
                            last_dir = int(rw_arr[f + hit_rel[-1]])
                        completion[f + hit_rel] = anchor + ends_full[hit_rel]
                        service[f + hit_rel] = costs_full[hit_rel]
                        off = int(ends_full[hit_rel[-1]])
                        out[out_n:out_n + k] = f + hit_rel
                        out_n += k
                    if miss_rel.size:
                        for m in (f + miss_rel).tolist():
                            kk = keys_l[m]
                            lst = buckets.get(kk)
                            if lst is None:
                                buckets[kk] = [m]
                            else:
                                lst.append(m)
                            order.append(m)
                        ndef += miss_rel.size
                    f += take
                    if take < chunk or anchor + off >= next_ref:
                        break
                    grow = min(chunk * 2, 1 << 20)
                streak = 0
                grow = max(64, 4 * w)
                continue
            k = keys_l[f]
            if cur[k // key_span] == k:
                c = cost_hit
                if rw_l is not None:
                    d = rw_l[f]
                    if d != last_dir:
                        if last_dir == 1:
                            c += t_wtr
                            turn += t_wtr
                        elif last_dir == 0:
                            c += t_rtw
                            turn += t_rtw
                        last_dir = d
                n_hit += 1
                off += c
                completion[f] = anchor + off
                service[f] = c
                out[out_n] = f
                out_n += 1
                streak += 1
            else:
                lst = buckets.get(k)
                if lst is None:
                    buckets[k] = [f]
                else:
                    lst.append(f)
                order.append(f)
                ndef += 1
                streak = 0
            f += 1
        if ndef == 0:
            continue
        if anchor + off >= next_ref:
            continue
        # ---- event: pop the oldest admitted miss --------------------
        while drained[order[head]]:
            head += 1
        d = order[head]
        head += 1
        ndef -= 1
        k = keys_l[d]
        if cur[k // key_span] == -1:
            n_first += 1
            c = cost_first
        else:
            n_conflict += 1
            c = cost_conf
        cur[k // key_span] = k
        if rw_l is not None:
            dd = rw_l[d]
            if dd != last_dir:
                if last_dir == 1:
                    c += t_wtr
                    turn += t_wtr
                elif last_dir == 0:
                    c += t_rtw
                    turn += t_rtw
                last_dir = dd
        off += c
        completion[d] = anchor + off
        service[d] = c
        out[out_n] = d
        out_n += 1
        streak = 0
        lst = buckets.pop(k)
        for i in range(1, len(lst)):
            if anchor + off >= next_ref:
                buckets[k] = lst[i:]
                break
            x = lst[i]
            c = cost_hit
            if rw_l is not None:
                dd = rw_l[x]
                if dd != last_dir:
                    if last_dir == 1:
                        c += t_wtr
                        turn += t_wtr
                    elif last_dir == 0:
                        c += t_rtw
                        turn += t_rtw
                    last_dir = dd
            n_hit += 1
            off += c
            completion[x] = anchor + off
            service[x] = c
            out[out_n] = x
            out_n += 1
            drained[x] = 1
            ndef -= 1
    return result_cls(
        total_fpga_cycles=(anchor + off) * timings.clock_ratio,
        row_hits=n_hit, row_conflicts=n_conflict, first_accesses=n_first,
        n_refreshes=n_ref, refresh_dram_cycles=n_ref * t_rfc,
        turnaround_dram_cycles=turn,
        service_order=out,
        completion_fpga_cycles=completion * timings.clock_ratio,
        service_dram_cycles=service,
        grant_order=np.arange(n, dtype=np.int64),
        granted_port=np.zeros(n, np.int64),
        idle_dram_cycles=idle)


def _arrivals_fast_single(addrs, n, timings, sched, rw_arr, arr, result_cls):
    """Arrival-gated chunked frontier scan (single admission queue)."""
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    w = sched.effective_window
    use_cap = sched.policy == "frfcfs_cap"
    cap = sched.starvation_cap
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    t_wtr, t_rtw = timings.t_wtr, timings.t_rtw
    cost_hit = timings.t_cl + timings.t_burst
    cost_first = timings.t_rcd + timings.t_cl + timings.t_burst
    cost_conf = (timings.t_rp + timings.t_rcd + timings.t_cl
                 + timings.t_burst)

    open_arr = np.zeros(timings.num_banks, np.int64)
    opened = np.zeros(timings.num_banks, bool)
    banks_l = banks.tolist()
    rows_l = rows.tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    arr_l = arr.tolist()
    open_l = [0] * timings.num_banks
    opened_l = [False] * timings.num_banks
    deferred: list[int] = []    # admitted misses, admission order
    byp: list[int] = []         # issues past each, parallel list
    out = np.empty(n, np.int64)
    out_n = 0
    completion = np.zeros(n, np.float64)
    service = np.zeros(n, np.int64)
    f = 0
    anchor = 0                  # float once the channel has idled
    off = 0                     # exact integer clocks since anchor
    next_ref = t_refi
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    idle = 0.0
    grow = max(64, 4 * w)

    def serve_scalar(idx: int) -> None:
        nonlocal n_hit, n_conflict, n_first, off, turn, last_dir, out_n
        b, r = banks_l[idx], rows_l[idx]
        if not opened_l[b]:
            n_first += 1
            c = cost_first
        elif open_l[b] == r:
            n_hit += 1
            c = cost_hit
        else:
            n_conflict += 1
            c = cost_conf
        opened_l[b] = True
        open_l[b] = r
        opened[b] = True
        open_arr[b] = r
        if rw_l is not None:
            d = rw_l[idx]
            if last_dir == 1 and d == 0:
                turn += t_wtr
                c += t_wtr
            elif last_dir == 0 and d == 1:
                turn += t_rtw
                c += t_rtw
            last_dir = d
        off += c
        completion[idx] = anchor + off
        service[idx] = c
        out[out_n] = idx
        out_n += 1

    while f < n or deferred:
        if not deferred and arr_l[f] > anchor + off:
            # idle-gap advance: refreshes completing inside the gap
            # overlap with idleness; one in progress at the target
            # delays the next issue to its end (oracle's absorb rule)
            target = arr_l[f]
            if t_refi:
                while next_ref <= target:
                    n_ref += 1
                    opened[:] = False
                    opened_l = [False] * timings.num_banks
                    end = next_ref + t_rfc
                    next_ref += t_refi
                    if end > target:
                        target = end
            idle += target - (anchor + off)
            anchor, off = target, 0
        if t_refi:
            while anchor + off >= next_ref:   # refresh precedes the issue
                off += t_rfc
                n_ref += 1
                opened[:] = False
                opened_l = [False] * timings.num_banks
                next_ref += t_refi
        frontier_ok = f < n and arr_l[f] <= anchor + off
        if deferred and (len(deferred) >= w or not frontier_ok
                         or (use_cap and byp[0] >= cap)):
            # -- event: issue the oldest admitted miss, then drain the
            # deferred requests its newly opened row converts into hits
            serve_scalar(deferred.pop(0))
            if use_cap:
                byp.pop(0)
                while deferred:
                    if t_refi and anchor + off >= next_ref:
                        break
                    if byp[0] >= cap:
                        i = 0
                    elif len(deferred) <= 48:
                        i = -1
                        for kk, dd in enumerate(deferred):
                            bb = banks_l[dd]
                            if opened_l[bb] and open_l[bb] == rows_l[dd]:
                                i = kk
                                break
                        if i < 0:
                            break
                    else:
                        d_arr = np.asarray(deferred, np.int64)
                        db = banks[d_arr]
                        cand = np.flatnonzero(
                            opened[db] & (open_arr[db] == rows[d_arr]))
                        if cand.size == 0:
                            break
                        i = int(cand[0])
                    serve_scalar(deferred.pop(i))
                    byp.pop(i)
                    for kk in range(i):
                        byp[kk] += 1
            elif deferred and len(deferred) <= 48:
                cand_pos = [kk for kk, dd in enumerate(deferred)
                            if opened_l[banks_l[dd]]
                            and open_l[banks_l[dd]] == rows_l[dd]]
                if cand_pos:
                    served_pos: list[int] = []
                    for kk in cand_pos:
                        if t_refi and anchor + off >= next_ref:
                            break
                        serve_scalar(deferred[kk])
                        served_pos.append(kk)
                    if served_pos:
                        drop = set(served_pos)
                        deferred = [dd for kk, dd in enumerate(deferred)
                                    if kk not in drop]
            elif deferred:
                # vectorized deep-window drain, cut by refresh only
                d_arr = np.asarray(deferred, np.int64)
                db = banks[d_arr]
                cand = np.flatnonzero(
                    opened[db] & (open_arr[db] == rows[d_arr]))
                if cand.size:
                    idxs = d_arr[cand]
                    tcosts = None
                    if rw_arr is not None:
                        dirs = rw_arr[idxs]
                        prev = np.concatenate(([last_dir], dirs[:-1]))
                        tcosts = np.where(
                            (prev == 1) & (dirs == 0), t_wtr,
                            np.where((prev == 0) & (dirs == 1),
                                     t_rtw, 0)).astype(np.int64)
                    costs = (np.full(cand.size, cost_hit, np.int64)
                             if tcosts is None else cost_hit + tcosts)
                    ends = off + np.cumsum(costs)
                    j = cand.size
                    if t_refi:
                        cross = np.flatnonzero(
                            anchor + (ends - costs) >= next_ref)
                        if cross.size:
                            j = int(cross[0])
                    if j:
                        n_hit += j
                        if tcosts is not None:
                            tsum = int(tcosts[:j].sum())
                            turn += tsum
                            last_dir = int(rw_arr[idxs[j - 1]])
                        completion[idxs[:j]] = anchor + ends[:j]
                        service[idxs[:j]] = costs[:j]
                        off = int(ends[j - 1])
                        out[out_n:out_n + j] = idxs[:j]
                        out_n += j
                        keep = np.ones(d_arr.size, bool)
                        keep[cand[:j]] = False
                        deferred = [d for d, m in zip(deferred, keep)
                                    if m]
            continue
        if f >= n:
            break
        # -- scalar lane: defer leading misses (admission advances no
        # clock, so a frontier miss is pure bookkeeping) and, while the
        # arrived backlog is short, serve hits one at a time — the scan
        # machinery only pays off once a real backlog forms. Break
        # conditions mirror the scan's truncations; the event branch
        # above guarantees byp[0] < cap and a refresh-clean clock at
        # entry, so a break always makes progress first (moved=True).
        moved = False
        while f < n and arr_l[f] <= anchor + off:
            b = banks_l[f]
            if opened_l[b] and open_l[b] == rows_l[f]:
                if len(deferred) >= w:
                    break                     # window full: event drains
                if f + 16 < n and arr_l[f + 16] <= anchor + off:
                    break                     # backlog: vectorized scan
                if use_cap and byp and byp[0] >= cap:
                    break                     # cap: event serves a miss
                if t_refi and anchor + off >= next_ref:
                    break                     # refresh precedes the issue
                serve_scalar(f)
                if use_cap:
                    for kk in range(len(byp)):
                        byp[kk] += 1
            else:
                if len(deferred) >= w:
                    break                     # window full: event drains
                deferred.append(f)
                if use_cap:
                    byp.append(0)
            f += 1
            moved = True
        if moved:
            continue
        # -- scan run: serve arrived frontier hits, defer arrived misses
        room = w - len(deferred)
        chunk = min(max(32, 4 * room, grow), n - f)
        sl = slice(f, f + chunk)
        bsl = banks[sl]
        hm = opened[bsl] & (open_arr[bsl] == rows[sl])
        hit_all = np.flatnonzero(hm)
        costs_full = np.zeros(chunk, np.int64)
        tc = None
        if rw_arr is not None and hit_all.size:
            dirs = rw_arr[f + hit_all]
            prev = np.concatenate(([last_dir], dirs[:-1]))
            tc = np.where((prev == 1) & (dirs == 0), t_wtr,
                          np.where((prev == 0) & (dirs == 1),
                                   t_rtw, 0)).astype(np.int64)
            costs_full[hit_all] = cost_hit + tc
        else:
            costs_full[hit_all] = cost_hit
        ends_full = off + np.cumsum(costs_full)
        pre_full = ends_full - costs_full
        take = chunk
        # arrival gate: position j is admitted right after the issue of
        # every earlier chunk entry — eligible iff arrived by that clock
        late = np.flatnonzero(arr[sl] > anchor + pre_full)
        if late.size:
            take = int(late[0])
        miss_rel = np.flatnonzero(~hm[:take])
        if miss_rel.size >= room:
            t2 = int(miss_rel[room - 1]) + 1     # through the room-th miss
            if t2 < take:
                take = t2
            miss_rel = miss_rel[:room]
        hit_rel = hit_all[hit_all < take]
        if use_cap and hit_rel.size:
            if deferred:
                # every hit here is younger than the oldest pending miss
                budget = cap - byp[0]            # >= 1: event checked above
                if hit_rel.size > budget:
                    take = int(hit_rel[budget])
                    hit_rel = hit_rel[:budget]
                    miss_rel = miss_rel[miss_rel < take]
            elif miss_rel.size:
                # only hits *after* the first new miss bypass it
                after = hit_rel[hit_rel > miss_rel[0]]
                if after.size > cap:
                    take = int(after[cap])
                    hit_rel = hit_rel[hit_rel < take]
                    miss_rel = miss_rel[miss_rel < take]
        if t_refi and hit_rel.size:
            cross = np.flatnonzero(anchor + pre_full[hit_rel] >= next_ref)
            if cross.size:
                kcut = int(cross[0])             # >= 1: refresh ran above
                take = int(hit_rel[kcut])
                hit_rel = hit_rel[:kcut]
                miss_rel = miss_rel[miss_rel < take]
        k = hit_rel.size
        if k:
            n_hit += k
            if tc is not None:
                tsum = int(tc[:k].sum())         # hit_rel prefixes hit_all
                turn += tsum
                last_dir = int(rw_arr[f + hit_rel[-1]])
            completion[f + hit_rel] = anchor + ends_full[hit_rel]
            service[f + hit_rel] = costs_full[hit_rel]
            off = int(ends_full[hit_rel[-1]])
            out[out_n:out_n + k] = f + hit_rel
            out_n += k
        if use_cap:
            if k and byp:
                byp = [b + k for b in byp]
            if miss_rel.size:
                new_byp = k - np.searchsorted(hit_rel, miss_rel)
                byp.extend(int(b) for b in new_byp)
        if miss_rel.size:
            deferred.extend(int(m) for m in (f + miss_rel))
        f += take
        grow = chunk * 2 if take == chunk else 64
    return result_cls(
        total_fpga_cycles=(anchor + off) * timings.clock_ratio,
        row_hits=n_hit, row_conflicts=n_conflict, first_accesses=n_first,
        n_refreshes=n_ref, refresh_dram_cycles=n_ref * t_rfc,
        turnaround_dram_cycles=turn,
        service_order=out,
        completion_fpga_cycles=completion * timings.clock_ratio,
        service_dram_cycles=service,
        grant_order=np.arange(n, dtype=np.int64),
        granted_port=np.zeros(n, np.int64),
        idle_dram_cycles=idle)


def _arrivals_fast_multi(addrs, n, timings, sched, rw_arr, arr, ports,
                         nports, arb_policy, weights, result_cls):
    """Optimized event-at-a-time serving loop for arbitrated streams.

    Admission is coupled to the arbiter's rotation state, so deferring
    a grant can change which port wins a slot — the frontier-scan
    batching of the single-port path does not apply. Same spec as the
    oracle, with python-list state (~an order of magnitude cheaper than
    dict/numpy scalar indexing in this regime)."""
    from repro.core.timing import _serving_weights

    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    w = sched.effective_window
    use_cap = sched.policy == "frfcfs_cap"
    cap = sched.starvation_cap
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    t_wtr, t_rtw = timings.t_wtr, timings.t_rtw
    t_cl, t_rcd, t_rp = timings.t_cl, timings.t_rcd, timings.t_rp
    t_burst = timings.t_burst
    credits = _serving_weights(nports, arb_policy, weights)
    priority = arb_policy == "priority"

    banks_l = banks.tolist()
    rows_l = rows.tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    arr_l = arr.tolist()
    queues = [np.flatnonzero(ports == p).tolist() for p in range(nports)]
    qlen = [len(q) for q in queues]
    heads = [0] * nports
    open_l = [0] * timings.num_banks
    opened_l = [False] * timings.num_banks
    pending: list[int] = []
    bypass: list[int] = []
    ptr, credit = 0, credits[0]
    anchor = 0
    off = 0
    next_ref = t_refi
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    idle = 0.0
    served = 0
    completion = np.zeros(n, np.float64)
    service = np.zeros(n, np.int64)
    out = np.empty(n, np.int64)
    grant_order = np.empty(n, np.int64)
    granted_port = np.empty(n, np.int64)
    granted = 0

    while served < n:
        cur = anchor + off
        while len(pending) < w:              # -- admission
            g = -1
            if priority:
                for p in range(nports):
                    h = heads[p]
                    if h < qlen[p] and arr_l[queues[p][h]] <= cur:
                        g = p
                        break
            else:
                for _ in range(nports + 1):
                    if credit > 0:
                        h = heads[ptr]
                        if h < qlen[ptr] and arr_l[queues[ptr][h]] <= cur:
                            g = ptr
                            credit -= 1
                            break
                    ptr += 1
                    if ptr == nports:
                        ptr = 0
                    credit = credits[ptr]
            if g < 0:
                break
            idx = queues[g][heads[g]]
            heads[g] += 1
            pending.append(idx)
            bypass.append(0)
            grant_order[granted] = idx
            granted_port[granted] = g
            granted += 1
        if not pending:                      # -- idle-gap advance
            target = min(arr_l[queues[p][heads[p]]] for p in range(nports)
                         if heads[p] < qlen[p])
            if t_refi:
                while next_ref <= target:
                    n_ref += 1
                    opened_l = [False] * timings.num_banks
                    end = next_ref + t_rfc
                    next_ref += t_refi
                    if end > target:
                        target = end
            idle += target - (anchor + off)
            anchor, off = target, 0
            continue
        if t_refi:
            while anchor + off >= next_ref:
                off += t_rfc
                n_ref += 1
                opened_l = [False] * timings.num_banks
                next_ref += t_refi
        pick = 0
        if w > 1:
            forced = -1
            if use_cap:
                for i, bp in enumerate(bypass):
                    if bp >= cap:
                        forced = i
                        break
            if forced >= 0:
                pick = forced
            else:
                for i, j in enumerate(pending):
                    b = banks_l[j]
                    if opened_l[b] and open_l[b] == rows_l[j]:
                        pick = i
                        break
        idx = pending.pop(pick)
        bypass.pop(pick)
        b, r = banks_l[idx], rows_l[idx]
        if not opened_l[b]:
            n_first += 1
            cost = t_rcd + t_cl
        elif open_l[b] == r:
            n_hit += 1
            cost = t_cl
        else:
            n_conflict += 1
            cost = t_rp + t_rcd + t_cl
        opened_l[b] = True
        open_l[b] = r
        cost += t_burst
        if rw_l is not None:
            d = rw_l[idx]
            if last_dir == 1 and d == 0:
                turn += t_wtr
                cost += t_wtr
            elif last_dir == 0 and d == 1:
                turn += t_rtw
                cost += t_rtw
            last_dir = d
        off += cost
        for i in range(pick):
            bypass[i] += 1
        completion[idx] = anchor + off
        service[idx] = cost
        out[served] = idx
        served += 1
    return result_cls(
        total_fpga_cycles=(anchor + off) * timings.clock_ratio,
        row_hits=n_hit, row_conflicts=n_conflict, first_accesses=n_first,
        n_refreshes=n_ref, refresh_dram_cycles=n_ref * t_rfc,
        turnaround_dram_cycles=turn,
        service_order=out,
        completion_fpga_cycles=completion * timings.clock_ratio,
        service_dram_cycles=service,
        grant_order=grant_order,
        granted_port=granted_port,
        idle_dram_cycles=idle)


def simulate_faults_fast(addrs, timings, sched, rw=None, *,
                         faults, channel=0, arrival_fpga=None,
                         pe_id=None, num_ports=None,
                         arb_policy="round_robin", weights=None,
                         trace=None):
    """Fast path of :func:`repro.core.timing.simulate_faults` —
    bit-identical to ``simulate_faults_seq`` (property-tested over
    fault rate x ECC mode x replay bound x backoff x outage x ports x
    DRAM policy x refresh).

    Same optimized event-at-a-time loop as
    :func:`_arrivals_fast_multi` (python-list state, anchored clock),
    with the RAS layer woven around the service step. The fault draws
    are where the speed comes from: every request's *first-attempt*
    uniform and weak-row flag are computed in one vectorized
    splitmix64 pass up front (the counter-based hash makes the draw a
    pure function of ``(seed, channel, index, attempt)``, so
    evaluating it early cannot perturb anything); only replay attempts
    — rare by construction — fall back to the scalar hash, which is
    the same wrapping arithmetic.

    ``trace`` keeps this hot path untouched: the timing run completes
    first, then :func:`repro.core.telemetry.replay_fault_events`
    reconstructs the oracle's event stream from the recorded
    permutations plus the replayable fault draws.
    """
    import heapq

    from repro.core import faults as F
    from repro.core.timing import (FaultSimResult, _serving_trace,
                                   _serving_weights)

    fc = faults
    addrs, n, rw_arr, arr, ports, nports = _serving_trace(
        addrs, timings, rw, arrival_fpga, pe_id, num_ports)
    credits = _serving_weights(nports, arb_policy, weights)
    if n == 0:
        return FaultSimResult(total_fpga_cycles=0.0, row_hits=0,
                              row_conflicts=0, first_accesses=0)
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    w = sched.effective_window
    use_cap = sched.policy == "frfcfs_cap"
    cap = sched.starvation_cap
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    t_wtr, t_rtw = timings.t_wtr, timings.t_rtw
    t_cl, t_rcd, t_rp = timings.t_cl, timings.t_rcd, timings.t_rp
    t_burst = timings.t_burst
    priority = arb_policy == "priority"
    secded = fc.ecc == "secded"
    due_frac = fc.due_fraction
    ecc_clocks = fc.ecc_correction_clocks
    write_crc = fc.write_crc
    max_replays = fc.max_replays
    retire_thresh = fc.row_retire_threshold
    esc_thresh = fc.refresh_escalate_threshold
    wins = fc.outage_windows_for(channel)

    weak = F.weak_rows(fc, channel, rows)
    p_req = np.minimum(
        fc.transient_ber + np.where(weak, fc.weak_row_ber, 0.0), 1.0)
    # error_prob(fc, False) with the same float expression as the spec:
    p_base = fc.transient_ber if fc.transient_ber < 1.0 else 1.0
    if p_req.max() > 0.0:
        u1 = F.error_uniforms(fc, channel, np.arange(n, dtype=np.int64), 1)
    else:
        u1 = np.zeros(n)
    u1_l = u1.tolist()
    p_l = p_req.tolist()
    weak_l = weak.tolist()
    banks_l = banks.tolist()
    rows_l = rows.tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    arr_l = arr.tolist()
    ports_l = ports.tolist()
    queues = [np.flatnonzero(ports == p).tolist() for p in range(nports)]
    qlen = [len(q) for q in queues]
    heads = [0] * nports
    open_l = [0] * timings.num_banks
    opened_l = [False] * timings.num_banks
    pending: list[int] = []
    bypass: list[int] = []
    ptr, credit = 0, credits[0]
    anchor = 0
    off = 0
    next_ref = t_refi
    t_refi_eff = t_refi
    esc_level = 0
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    idle = 0.0
    served = 0
    completion = np.zeros(n, np.float64)
    service = np.zeros(n, np.int64)
    attempts_np = np.zeros(n, np.int64)
    attempts = attempts_np.tolist()
    dropped = np.zeros(n, bool)
    grant_order = np.empty(n, np.int64)
    granted_port = np.empty(n, np.int64)
    granted = 0
    order: list[int] = []
    replay_q: list = []
    rseq = 0
    retired: dict[int, int] = {}
    err_count: dict[int, int] = {}
    st = F.FaultStats()
    retired_seq: list = []
    dropped_by_port: dict[int, int] = {}

    while served < n:
        cur = anchor + off
        while len(pending) < w:              # -- admission
            if replay_q and replay_q[0][0] <= cur:
                pending.append(heapq.heappop(replay_q)[2])
                bypass.append(0)
                continue
            g = -1
            if priority:
                for p in range(nports):
                    h = heads[p]
                    if h < qlen[p] and arr_l[queues[p][h]] <= cur:
                        g = p
                        break
            else:
                for _ in range(nports + 1):
                    if credit > 0:
                        h = heads[ptr]
                        if h < qlen[ptr] and arr_l[queues[ptr][h]] <= cur:
                            g = ptr
                            credit -= 1
                            break
                    ptr += 1
                    if ptr == nports:
                        ptr = 0
                    credit = credits[ptr]
            if g < 0:
                break
            idx = queues[g][heads[g]]
            heads[g] += 1
            pending.append(idx)
            bypass.append(0)
            grant_order[granted] = idx
            granted_port[granted] = g
            granted += 1
        if not pending:                      # -- idle-gap advance
            target = min(arr_l[queues[p][heads[p]]] for p in range(nports)
                         if heads[p] < qlen[p]) if any(
                heads[p] < qlen[p] for p in range(nports)) else replay_q[0][0]
            if replay_q and replay_q[0][0] < target:
                target = replay_q[0][0]
            if t_refi:
                while next_ref <= target:
                    n_ref += 1
                    opened_l = [False] * timings.num_banks
                    end = next_ref + t_rfc
                    next_ref += t_refi_eff
                    if end > target:
                        target = end
            idle += target - (anchor + off)
            anchor, off = target, 0
            continue
        now = anchor + off
        jumped = False
        for s, e in wins:                    # -- outage window stall
            if s <= now < e:
                target = float(e)
                if t_refi:
                    while next_ref <= target:
                        n_ref += 1
                        opened_l = [False] * timings.num_banks
                        end = next_ref + t_rfc
                        next_ref += t_refi_eff
                        if end > target:
                            target = end
                st.outage_dram_cycles += target - now
                anchor, off = target, 0
                jumped = True
                break
        if jumped:
            continue
        if t_refi:
            while anchor + off >= next_ref:
                off += t_rfc
                n_ref += 1
                opened_l = [False] * timings.num_banks
                next_ref += t_refi_eff
        pick = 0
        if w > 1:
            forced = -1
            if use_cap:
                for i, bp in enumerate(bypass):
                    if bp >= cap:
                        forced = i
                        break
            if forced >= 0:
                pick = forced
            elif retired:
                for i, j in enumerate(pending):
                    b = banks_l[j]
                    rj = rows_l[j]
                    if opened_l[b] and open_l[b] == retired.get(rj, rj):
                        pick = i
                        break
            else:
                for i, j in enumerate(pending):
                    b = banks_l[j]
                    if opened_l[b] and open_l[b] == rows_l[j]:
                        pick = i
                        break
        idx = pending.pop(pick)
        bypass.pop(pick)
        b, r_nat = banks_l[idx], rows_l[idx]
        r = retired.get(r_nat, r_nat) if retired else r_nat
        if r != r_nat:
            st.spare_issues += 1
        if not opened_l[b]:
            n_first += 1
            cost = t_rcd + t_cl
        elif open_l[b] == r:
            n_hit += 1
            cost = t_cl
        else:
            n_conflict += 1
            cost = t_rp + t_rcd + t_cl
        opened_l[b] = True
        open_l[b] = r
        cost += t_burst
        if rw_l is not None:
            d = rw_l[idx]
            if last_dir == 1 and d == 0:
                turn += t_wtr
                cost += t_wtr
            elif last_dir == 0 and d == 1:
                turn += t_rtw
                cost += t_rtw
            last_dir = d
        att = attempts[idx] + 1
        attempts[idx] = att
        if att > 1:
            st.n_replays += 1
        p_err = (p_l[idx] if r == r_nat else p_base) if weak_l[idx] \
            else p_l[idx]
        errored = False
        u = 0.0
        if p_err > 0.0:
            u = u1_l[idx] if att == 1 else F.error_uniform(
                fc, channel, idx, att)
            errored = u < p_err
        failed = False
        if errored:
            st.n_injected += 1
            if retire_thresh and r < F.SPARE_ROW_BASE:
                c = err_count.get(r, 0) + 1
                err_count[r] = c
                if (c >= retire_thresh and r_nat not in retired
                        and len(retired) < fc.max_retired_rows):
                    retired[r_nat] = F.SPARE_ROW_BASE + r_nat
                    retired_seq.append((channel, r_nat))
            if esc_thresh and t_refi:
                while (esc_level < fc.refresh_escalate_max
                       and st.n_injected >= esc_thresh * (esc_level + 1)):
                    esc_level += 1
                    st.refresh_escalations += 1
                    shrunk = t_refi >> esc_level
                    t_refi_eff = shrunk if shrunk > t_rfc else t_rfc + 1
            is_read = rw_l is None or rw_l[idx] == 0
            if is_read:
                if secded:
                    if u < p_err * due_frac:
                        failed = True
                    else:
                        st.n_corrected += 1
                        st.correction_dram_cycles += ecc_clocks
                        cost += ecc_clocks
                else:
                    st.n_silent += 1
            else:
                if write_crc:
                    failed = True
                else:
                    st.n_silent += 1
        off += cost
        for i in range(pick):
            bypass[i] += 1
        service[idx] += cost
        order.append(idx)
        if failed:
            st.n_uncorrectable += 1
            st.replay_dram_cycles += cost
            if att > max_replays:
                dropped[idx] = True
                st.n_dropped += 1
                port = ports_l[idx]
                dropped_by_port[port] = dropped_by_port.get(port, 0) + 1
                completion[idx] = anchor + off
                served += 1
            else:
                rseq += 1
                heapq.heappush(
                    replay_q,
                    (anchor + off + fc.backoff_for(att), rseq, idx))
        else:
            completion[idx] = anchor + off
            served += 1

    st.rows_retired = tuple(retired_seq)
    st.dropped_by_port = dropped_by_port
    attempts_np = np.asarray(attempts, np.int64)
    res = FaultSimResult(
        total_fpga_cycles=(anchor + off) * timings.clock_ratio,
        row_hits=n_hit, row_conflicts=n_conflict, first_accesses=n_first,
        n_refreshes=n_ref, refresh_dram_cycles=n_ref * t_rfc,
        turnaround_dram_cycles=turn,
        service_order=np.asarray(order, dtype=np.int64),
        completion_fpga_cycles=completion * timings.clock_ratio,
        service_dram_cycles=service,
        grant_order=grant_order[:granted],
        granted_port=granted_port[:granted],
        idle_dram_cycles=idle,
        fault=st, attempts=attempts_np, dropped=dropped)
    if trace is not None:
        from repro.core import telemetry
        telemetry.replay_fault_events(
            addrs, timings, sched, rw_arr, faults=fc, channel=channel,
            arrival_fpga=arrival_fpga, pe_id=pe_id, num_ports=num_ports,
            result=res, trace=trace)
    return res
