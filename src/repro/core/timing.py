"""DRAM/HBM timing model and cycle-level simulator (paper §IV).

Two roles:

1. *Analytic model* — Equations 1-3 of the paper, used by the autotuner to
   predict controller performance for a candidate configuration, and by the
   benchmarks to reproduce Fig. 9.

2. *Cycle-level open-row DRAM simulator* — the measurement substrate for the
   paper-claim reproductions (Fig. 7: 27% GCN / 58% CNN, Fig. 8: 20x, Fig. 9:
   batch 32-64 optimum). Real DDR4/Alveo hardware is unavailable in this
   container, so modeled access time — the same metric the paper plots — is
   produced by simulating each request stream against DDR4 bank/row state.

All times are reported in FPGA/accelerator clock cycles unless noted.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.config import (DRAMSchedConfig, FaultConfig,
                               MemoryControllerConfig,
                               scheduler_sort_stages)


@dataclasses.dataclass(frozen=True)
class DRAMTimings:
    """DDR4-2400-class timing parameters (in DRAM clock cycles)."""

    t_cl: int = 17    # CAS latency
    t_rcd: int = 17   # row address to column address delay
    t_rp: int = 17    # row precharge
    # Clock periods (ns): DDR4-2400 command clock 1200 MHz; FPGA fabric
    # 300 MHz (typical U250 memory-controller clock domain).
    t_mem_ns: float = 0.833
    t_fpga_ns: float = 3.333
    num_banks: int = 16
    row_bytes: int = 8192           # row buffer (page) size
    burst_bytes: int = 64           # one BL8 x 64b burst
    t_burst: int = 4                # cycles to stream one burst after CAS
    # Bus-turnaround penalties (DDR4 tWTR/tRTW class): cycles lost when the
    # data bus flips direction between a write and a read burst. Charged
    # per direction change in the serviced stream — the reason the
    # scheduler issues single-type (read xor write) batches.
    t_wtr: int = 8                  # write -> read turnaround
    t_rtw: int = 4                  # read -> write turnaround

    # --- paper's derived averages (§IV, 'DRAM Timing Model') -------------
    @property
    def clock_ratio(self) -> float:
        return self.t_mem_ns / self.t_fpga_ns

    def t_mem_seq(self) -> float:
        """Average sequential-access latency in FPGA cycles (row-buffer hit)."""
        return self.t_cl * self.clock_ratio

    def t_mem_rand(self) -> float:
        """Average random-access latency in FPGA cycles (row conflict)."""
        return (self.t_rp + self.t_cl + self.t_rcd) * self.clock_ratio

    def row_of(self, addr: np.ndarray) -> np.ndarray:
        return addr // self.row_bytes

    def bank_of(self, addr: np.ndarray) -> np.ndarray:
        # Bank interleave on row index (closed-form, matches common DDR4
        # address mappings at this granularity).
        return (addr // self.row_bytes) % self.num_banks


DDR4_2400 = DRAMTimings()

# TPU v5e HBM modeled with the same open-row abstraction: much wider rows and
# higher relative conflict penalty against the 940 MHz core clock.
# Bus-turnaround overrides: the DDR4 defaults (t_wtr=8, t_rtw=4) are wrong
# for HBM — its single-cycle burst occupancy (t_burst=1 vs 4) and wide
# per-pseudo-channel bus leave far less data-bus tail to drain before the
# direction can flip, so the turnaround gaps are proportionally smaller
# in command clocks.
HBM_V5E = DRAMTimings(
    t_cl=14, t_rcd=14, t_rp=14,
    t_mem_ns=0.55, t_fpga_ns=1.064,
    num_banks=32, row_bytes=16384, burst_bytes=512, t_burst=1,
    t_wtr=4, t_rtw=2,
)


# ---------------------------------------------------------------------------
# Analytic cost model: Equations 1-3
# ---------------------------------------------------------------------------

def t_schedule(batch_size: int, data_cond_cycles: int = 2) -> float:
    """Eq. 1 — scheduling time for a batch of N requests (FPGA cycles).

    N cycles of batch formation (one request accepted per cycle) plus the
    bitonic network's log2(N)(log2(N)+1)/2 compare-exchange stages plus
    serial<->parallel data conditioning.
    """
    if batch_size <= 0:
        return 0.0
    return batch_size + scheduler_sort_stages(batch_size) + data_cond_cycles


def t_overlapped_schedule(
    batch_size: int,
    n_batches: int,
    service_cycles: float,
    data_cond_cycles: int = 2,
) -> float:
    """Eq. 1 extended with the DMA engine's double-buffer overlap.

    Only the first batch's scheduling latency is fully exposed: while a
    batch streams from DRAM the next one forms and sorts in the second
    input buffer (paper Fig. 5 discussion), so each subsequent batch
    exposes only the residual ``max(0, t_schedule - service/n_batches)``.
    This is the scheduling term of the pipeline's ``DMAOverlap`` stage
    and of the autotuner's score.
    """
    if n_batches <= 0:
        return 0.0
    t_sch = t_schedule(batch_size, data_cond_cycles)
    resid = max(0.0, t_sch - service_cycles / n_batches) * (n_batches - 1)
    return t_sch + resid


def t_cache_trace(
    cfg: MemoryControllerConfig,
    hits: np.ndarray,
    t_mem_access: float,
    l_cache: int = 4,
    l_mem: int = 3,
) -> float:
    """Eq. 2 — total cache-engine time for a trace with known hit mask.

    ``hits`` is a boolean vector (1 = cache hit). Hits cost one pipeline
    beat; misses pay the memory pipeline + scheduling + DRAM access.
    ``l_cache`` is the 4-stage PE pipeline depth, ``l_mem`` the 3-stage MEM
    pipeline fill latency.
    """
    hits = np.asarray(hits, dtype=bool)
    n_miss = int((~hits).sum())
    n_hit = int(hits.sum())
    t_sch = t_schedule(cfg.scheduler.batch_size,
                       cfg.scheduler.data_cond_cycles) if \
        cfg.scheduler.enabled else 0.0
    return (cfg.ctrl_overhead_cycles + l_cache
            + n_hit * 1.0
            + n_miss * (l_mem + t_sch + t_mem_access))


def t_dma_transfer(
    cfg: MemoryControllerConfig,
    num_elems: int,
    seq_mask: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    l_data_convert: int = 2,
    channel_ids: np.ndarray | None = None,
) -> float:
    """Eq. 3 — total DMA time for a bulk transfer of N elements.

    ``seq_mask[i]`` is True when element i is a sequential DRAM access
    (row-buffer hit) and False when random (row conflict); the paper requires
    exactly one of the two per element.

    ``channel_ids`` (one memory-channel index per element, from
    ``channels.AddressMap.channel_of``) extends Eq. 3 to a multi-channel
    interface: each channel streams its share of the elements
    concurrently, so the element term is the *slowest channel's* sum
    (makespan) rather than the single-interface total. ``None`` keeps
    the paper's single-channel equation exactly.
    """
    seq_mask = np.asarray(seq_mask, dtype=bool)
    if seq_mask.shape != (num_elems,):
        raise ValueError("seq_mask must have one entry per element")
    t_sch = t_schedule(cfg.scheduler.batch_size,
                       cfg.scheduler.data_cond_cycles) if \
        cfg.scheduler.enabled else 0.0
    if channel_ids is None:
        t_elems = (seq_mask.sum() * timings.t_mem_seq()
                   + (~seq_mask).sum() * timings.t_mem_rand())
    else:
        ch = np.asarray(channel_ids, dtype=np.int64)
        if ch.shape != (num_elems,):
            raise ValueError("channel_ids must have one entry per element")
        per_elem = np.where(seq_mask, timings.t_mem_seq(),
                            timings.t_mem_rand())
        sums = np.bincount(ch, weights=per_elem)
        t_elems = float(sums.max()) if sums.size else 0.0
    # Parallel DMA buffers overlap element streaming within a channel
    # (paper Fig. 5 discussion); memory channels overlap across channels.
    t_elems /= max(1, cfg.dma.num_parallel_dma)
    return cfg.ctrl_overhead_cycles + t_sch + l_data_convert + t_elems


# ---------------------------------------------------------------------------
# Cycle-level open-row simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    total_fpga_cycles: float
    row_hits: int
    row_conflicts: int
    first_accesses: int

    @property
    def hit_rate(self) -> float:
        n = self.row_hits + self.row_conflicts + self.first_accesses
        return self.row_hits / max(1, n)


def simulate_dram_access(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    burst_bytes: int | None = None,
    rw: np.ndarray | None = None,
) -> SimResult:
    """Simulate an address trace against per-bank open-row state.

    Open-row policy (paper §IV): the first access to a bank costs
    ``t_rcd + t_cl``; subsequent accesses to the *same open row* cost
    ``t_cl`` (plus burst streaming); a different row costs
    ``t_rp + t_rcd + t_cl``. Returns totals in FPGA cycles.

    When ``rw`` (0=read / 1=write per request) is given, every data-bus
    direction change additionally pays the ``t_wtr`` / ``t_rtw``
    turnaround — the cost the scheduler's single-type batches amortize.

    Vectorized: classify each access by comparing with the previous access
    to the same bank (np-based; traces run to millions of requests).
    """
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    if addrs.size == 0:
        return SimResult(0.0, 0, 0, 0)
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)

    # prev_row_same_bank[i] = row of the previous access that hit bank[i]
    order = np.arange(addrs.size)
    # Stable sort by bank, then position, groups each bank's accesses while
    # preserving trace order within the bank.
    perm = np.lexsort((order, banks))
    sorted_rows = rows[perm]
    sorted_banks = banks[perm]
    prev_rows = np.empty_like(sorted_rows)
    prev_rows[0] = -1
    prev_rows[1:] = sorted_rows[:-1]
    same_bank = np.empty_like(sorted_banks, dtype=bool)
    same_bank[0] = False
    same_bank[1:] = sorted_banks[1:] == sorted_banks[:-1]

    first = ~same_bank
    hit = same_bank & (prev_rows == sorted_rows)
    conflict = same_bank & ~hit

    n_first = int(first.sum())
    n_hit = int(hit.sum())
    n_conflict = int(conflict.sum())

    dram_cycles = (
        n_first * (timings.t_rcd + timings.t_cl)
        + n_hit * timings.t_cl
        + n_conflict * (timings.t_rp + timings.t_rcd + timings.t_cl)
        + addrs.size * timings.t_burst
    )
    if rw is not None:
        dram_cycles += turnaround_cycles(rw, timings)
    return SimResult(
        total_fpga_cycles=dram_cycles * timings.clock_ratio,
        row_hits=n_hit,
        row_conflicts=n_conflict,
        first_accesses=n_first,
    )


def turnaround_cycles(rw: np.ndarray, timings: DRAMTimings = DDR4_2400) -> int:
    """DRAM cycles lost to bus direction changes in a serviced rw stream:
    each WRITE→READ edge costs ``t_wtr``, each READ→WRITE edge ``t_rtw``."""
    rw = np.asarray(rw, dtype=np.int32).ravel()
    if rw.size < 2:
        return 0
    prev, cur = rw[:-1], rw[1:]
    wtr = int(((prev == 1) & (cur == 0)).sum())
    rtw = int(((prev == 0) & (cur == 1)).sum())
    return wtr * timings.t_wtr + rtw * timings.t_rtw


def simulate_dram_access_windowed_seq(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    window: int = 4,
) -> SimResult:
    """Reference implementation of :func:`simulate_dram_access_windowed`
    — one python iteration (with an O(window) scan) per serviced request.
    Kept as the oracle the vectorized version is property-tested
    against."""
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.size
    if n == 0:
        return SimResult(0.0, 0, 0, 0)
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    open_row = {}
    pending: list[int] = []
    nxt = 0
    n_hit = n_conflict = n_first = 0
    while nxt < n or pending:
        while nxt < n and len(pending) < window:
            pending.append(nxt)
            nxt += 1
        pick = None
        for i, idx in enumerate(pending):        # oldest-first greedy
            b = banks[idx]
            if b in open_row and open_row[b] == rows[idx]:
                pick = i
                break
        if pick is None:
            pick = 0
        idx = pending.pop(pick)
        b, r = banks[idx], rows[idx]
        if b not in open_row:
            n_first += 1
        elif open_row[b] == r:
            n_hit += 1
        else:
            n_conflict += 1
        open_row[b] = r
    dram_cycles = (
        n_first * (timings.t_rcd + timings.t_cl)
        + n_hit * timings.t_cl
        + n_conflict * (timings.t_rp + timings.t_rcd + timings.t_cl)
        + n * timings.t_burst)
    return SimResult(total_fpga_cycles=dram_cycles * timings.clock_ratio,
                     row_hits=n_hit, row_conflicts=n_conflict,
                     first_accesses=n_first)


def simulate_dram_access_windowed(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    window: int = 4,
) -> SimResult:
    """Commercial-IP baseline: FIFO with a small greedy reorder window.

    Real memory-interface IPs (e.g. Xilinx MIG) service mostly in order
    but can promote a request within a shallow lookahead window when it
    hits an already-open row. ``window=1`` degenerates to pure FIFO. The
    paper's controller differs by reordering over a *whole batch* (up to
    512) with the bitonic network — this function is what it is compared
    against in the Fig. 7/8 reproductions.

    Vectorized, with counts identical to the sequential walk
    (:func:`simulate_dram_access_windowed_seq`):

    * ``window == 1`` is pure FIFO, which is exactly the per-bank
      previous-row classification :func:`simulate_dram_access` computes
      in one vectorized pass.
    * ``window > 1`` exploits that open-row state only changes when a
      *miss* is serviced: every request that hits a currently open row is
      drained from the window first (in any order — the counts are the
      same), so the walk alternates between a numpy chunk-scan that
      serves hit-runs at array speed while collecting up to ``window``
      deferred misses, and a single miss service (the oldest deferred
      request) that re-opens one bank's row and re-checks the deferred
      set against it.
    """
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.size
    if n == 0:
        return SimResult(0.0, 0, 0, 0)
    if window <= 1:
        return simulate_dram_access(addrs, timings)
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    open_arr = np.zeros(timings.num_banks, np.int64)
    opened = np.zeros(timings.num_banks, bool)   # no sentinel: negative
    deferred: list[int] = []                     # rows are legal values
    f = 0
    n_hit = n_conflict = n_first = 0
    while True:
        # Scan forward, serving hits and deferring misses, until the
        # window is full of misses (or the trace is exhausted).
        while f < n and len(deferred) < window:
            room = window - len(deferred)
            chunk = min(max(64, 4 * window), n - f)
            sl = slice(f, f + chunk)
            hit_mask = opened[banks[sl]] & (open_arr[banks[sl]] == rows[sl])
            miss_pos = np.flatnonzero(~hit_mask)
            if miss_pos.size >= room:
                take = miss_pos[room - 1] + 1   # through the room-th miss
                n_hit += int(take - room)
                deferred.extend((f + miss_pos[:room]).tolist())
                f += int(take)
            else:
                n_hit += int(hit_mask.sum())
                deferred.extend((f + miss_pos).tolist())
                f += chunk
        if not deferred:
            break
        # Service the oldest deferred miss; its bank's new open row may
        # turn other deferred requests into hits — drain them.
        d = deferred.pop(0)
        b, r = banks[d], rows[d]
        if not opened[b]:
            n_first += 1
        elif open_arr[b] == r:                  # unreachable: d missed
            n_hit += 1
        else:
            n_conflict += 1
        open_arr[b] = r
        opened[b] = True
        now_hit = [i for i in deferred if banks[i] == b and rows[i] == r]
        if now_hit:
            n_hit += len(now_hit)
            deferred = [i for i in deferred if not (banks[i] == b
                                                    and rows[i] == r)]
    dram_cycles = (
        n_first * (timings.t_rcd + timings.t_cl)
        + n_hit * timings.t_cl
        + n_conflict * (timings.t_rp + timings.t_rcd + timings.t_cl)
        + n * timings.t_burst)
    return SimResult(total_fpga_cycles=dram_cycles * timings.clock_ratio,
                     row_hits=n_hit, row_conflicts=n_conflict,
                     first_accesses=n_first)


# ---------------------------------------------------------------------------
# Out-of-order DRAM command scheduling (FR-FCFS + refresh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SchedSimResult(SimResult):
    """:class:`SimResult` extended with command-scheduler observability.

    ``service_order`` is the permutation actually issued (request index
    per service slot) — the first modeled quantity in this repo where
    the makespan depends on *order*, not just stream contents; the
    property tests compute per-request slip from it. Turnaround and
    refresh cycles are broken out (DRAM command clocks) so tests can
    check the open-row class costs independently of the bus-direction
    and refresh terms.
    """

    n_refreshes: int = 0
    refresh_dram_cycles: int = 0
    turnaround_dram_cycles: int = 0
    service_order: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))


def _sched_result(n_first, n_hit, n_conflict, n, turn, n_ref, t_rfc,
                  timings, order) -> SchedSimResult:
    dram_cycles = (
        n_first * (timings.t_rcd + timings.t_cl)
        + n_hit * timings.t_cl
        + n_conflict * (timings.t_rp + timings.t_rcd + timings.t_cl)
        + n * timings.t_burst + turn + n_ref * t_rfc)
    return SchedSimResult(
        total_fpga_cycles=dram_cycles * timings.clock_ratio,
        row_hits=n_hit, row_conflicts=n_conflict, first_accesses=n_first,
        n_refreshes=n_ref, refresh_dram_cycles=n_ref * t_rfc,
        turnaround_dram_cycles=turn,
        service_order=np.asarray(order, dtype=np.int64))


def simulate_dram_sched_seq(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    sched: DRAMSchedConfig = DRAMSchedConfig(),
    rw: np.ndarray | None = None,
    trace=None,
) -> SchedSimResult:
    """Request-at-a-time oracle for the out-of-order DRAM command
    scheduler — THE specification the vectorized path
    (:func:`simulate_dram_sched`) is property-tested bit-identical
    against.

    One service slot per iteration over a ``reorder_window``-deep
    pending queue:

    * fill the queue from the trace (arrival order);
    * refresh: whenever the accumulated service time crosses the next
      ``t_refi`` boundary the channel stalls ``t_rfc`` cycles and every
      bank precharges (open rows close — the re-activation after a
      refresh is charged like a first access: ``t_rcd + t_cl``, no
      precharge needed);
    * pick: ``fifo`` (or window 1) always issues the oldest;
      ``frfcfs`` issues the oldest pending request whose row is already
      open, else the oldest overall; ``frfcfs_cap`` first checks for a
      starved request (``bypass >= starvation_cap`` where ``bypass``
      counts younger requests issued past it while it waited) and
      forces the oldest such one;
    * service: classify against per-bank open-row state, charge the
      class cost + burst (+ tWTR/tRTW against the *issued* direction
      sequence, which the reorder can change).

    With ``window=1`` and refresh disabled this degenerates exactly to
    the per-bank FIFO classification of :func:`simulate_dram_access`
    (bit-identical, including turnarounds).

    ``trace`` (a :class:`repro.core.telemetry.ChannelTrace`) makes this
    oracle emit the per-request lifecycle event stream natively — the
    event schema's specification, which the fast path reconstructs via
    :func:`repro.core.telemetry.replay_sched_events` (property-tested
    tuple-for-tuple equal). ``trace=None`` changes nothing.
    """
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.size
    if n == 0:
        return _sched_result(0, 0, 0, 0, 0, 0, sched.t_rfc, timings, [])
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    rw_arr = None if rw is None else np.asarray(rw, np.int32).ravel()
    w = sched.effective_window
    use_cap = sched.policy == "frfcfs_cap"
    t_refi = sched.t_refi

    open_row: dict[int, int] = {}
    pending: list[int] = []
    bypass: dict[int, int] = {}
    nxt = 0
    cycle = 0                       # DRAM clocks serviced so far
    next_ref = t_refi
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    order: list[int] = []
    ev = None if trace is None else trace.events
    while nxt < n or pending:
        while nxt < n and len(pending) < w:
            if ev is not None:
                ev.append(("window", cycle, nxt))
            pending.append(nxt)
            bypass[nxt] = 0
            nxt += 1
        if t_refi:
            while cycle >= next_ref:
                if ev is not None:
                    ev.append(("refresh", cycle, cycle + sched.t_rfc))
                cycle += sched.t_rfc
                n_ref += 1
                open_row.clear()
                next_ref += t_refi
        pick = 0
        if w > 1:
            forced = None
            if use_cap:
                for i, j in enumerate(pending):
                    if bypass[j] >= sched.starvation_cap:
                        forced = i
                        break
            if forced is not None:
                pick = forced
            else:
                for i, j in enumerate(pending):
                    b = int(banks[j])
                    if b in open_row and open_row[b] == rows[j]:
                        pick = i
                        break
        idx = pending.pop(pick)
        del bypass[idx]
        b, r = int(banks[idx]), int(rows[idx])
        if b not in open_row:
            n_first += 1
            cls = "first"
            cost = timings.t_rcd + timings.t_cl
        elif open_row[b] == r:
            n_hit += 1
            cls = "hit"
            cost = timings.t_cl
        else:
            n_conflict += 1
            cls = "conflict"
            cost = timings.t_rp + timings.t_rcd + timings.t_cl
        open_row[b] = r
        cost += timings.t_burst
        if rw_arr is not None:
            d = int(rw_arr[idx])
            if last_dir == 1 and d == 0:
                turn += timings.t_wtr
                cost += timings.t_wtr
                if ev is not None:
                    ev.append(("turn", cycle, "wtr", timings.t_wtr))
            elif last_dir == 0 and d == 1:
                turn += timings.t_rtw
                cost += timings.t_rtw
                if ev is not None:
                    ev.append(("turn", cycle, "rtw", timings.t_rtw))
            last_dir = d
        if ev is not None:
            ev.append(("issue", cycle, idx, b, r, cls, cost, 1, "ok"))
        cycle += cost
        for j in pending:
            if j < idx:
                bypass[j] += 1
        order.append(idx)
        if ev is not None:
            ev.append(("complete", cycle, idx))
    return _sched_result(n_first, n_hit, n_conflict, n, turn, n_ref,
                         sched.t_rfc, timings, order)


def simulate_dram_sched(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    sched: DRAMSchedConfig = DRAMSchedConfig(),
    rw: np.ndarray | None = None,
    engine: str = "auto",
    trace=None,
) -> SchedSimResult:
    """Out-of-order DRAM command scheduling — vectorized, bit-identical
    to :func:`simulate_dram_sched_seq`.

    Dispatch: ``fifo``/window-1 configs without refresh are exactly the
    one-pass per-bank classification of :func:`simulate_dram_access`
    (today's FIFO model — the degeneracy the golden tests pin down);
    everything else runs the chunked event walk in
    ``repro.core.trace_engine`` (hit runs at array speed, one python
    event per serviced miss / refresh / forced starvation pick).

    ``trace`` requests the lifecycle event stream: the sequential
    engine emits natively, the fast engines reconstruct it from their
    outputs after the timing run (``trace=None`` is the zero-overhead
    hot path — no code on it changes).
    """
    if engine not in ("auto", "fast", "sequential"):
        raise ValueError(f"engine={engine!r} must be auto|fast|sequential")
    if engine == "sequential":
        return simulate_dram_sched_seq(addrs, timings, sched, rw, trace)
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.size
    if n == 0:
        return _sched_result(0, 0, 0, 0, 0, 0, sched.t_rfc, timings, [])
    if sched.effective_window == 1 and not sched.t_refi:
        base = simulate_dram_access(addrs, timings, rw=rw)
        turn = 0 if rw is None else turnaround_cycles(rw, timings)
        res = SchedSimResult(
            total_fpga_cycles=base.total_fpga_cycles,
            row_hits=base.row_hits, row_conflicts=base.row_conflicts,
            first_accesses=base.first_accesses,
            turnaround_dram_cycles=turn,
            service_order=np.arange(n, dtype=np.int64))
        if trace is not None:
            from repro.core import telemetry
            telemetry.replay_sched_events(addrs, timings, sched, rw, res,
                                          trace)
        return res
    from repro.core import trace_engine
    return trace_engine.simulate_dram_sched_fast(addrs, timings, sched, rw,
                                                 trace=trace)


# ---------------------------------------------------------------------------
# Open-loop (arrival-aware) serving simulator
# ---------------------------------------------------------------------------

#: Arbitration policies the serving loop understands — semantically the
#: same set as ``repro.core.channels.ARBITER_POLICIES`` (this module
#: cannot import channels, which imports it).
SERVING_ARB_POLICIES = ("round_robin", "priority", "weighted")


def _serving_weights(num_ports: int, policy: str, weights) -> list[int]:
    """Validate (policy, weights) exactly like the channels-layer
    arbiter does and return one integer credit per port."""
    if policy not in SERVING_ARB_POLICIES:
        raise ValueError(f"arbiter policy {policy!r} must be one of "
                         f"{SERVING_ARB_POLICIES}")
    if policy != "weighted":
        return [1] * num_ports
    if weights is None:
        raise ValueError("policy='weighted' requires per-port weights")
    w = np.asarray(weights, dtype=np.int64)
    if w.shape != (num_ports,) or (w < 1).any():
        raise ValueError("weights must be one positive integer per port")
    return [int(x) for x in w]


@dataclasses.dataclass
class ServingSimResult(SchedSimResult):
    """:class:`SchedSimResult` extended with open-loop observability.

    ``total_fpga_cycles`` becomes the channel *span* — the completion
    time of the last request including any idle gaps spent waiting for
    arrivals (with all arrivals at 0 there are no gaps and the closed-
    loop count identity holds exactly). ``completion_fpga_cycles[i]``
    is request ``i``'s service-completion time on the channel clock
    (sojourn = completion − arrival); ``service_dram_cycles[i]`` the
    DRAM-command clocks its issue occupied the interface (class cost +
    burst + any turnaround it triggered; refresh stalls excluded).
    ``grant_order`` is the admission permutation (request index per
    grant slot), ``granted_port`` the port that won each slot, and
    ``idle_dram_cycles`` the clocks the interface sat with an empty
    pending window (including the tail of a refresh it had to wait out
    after an idle gap).
    """

    completion_fpga_cycles: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.float64))
    service_dram_cycles: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    grant_order: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    granted_port: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    idle_dram_cycles: float = 0.0


def _serving_trace(addrs, timings, rw, arrival_fpga, pe_id, num_ports):
    """Shared input validation/decode for both serving implementations —
    the FPGA-cycle → DRAM-clock conversion in particular must be the
    *same float expression* on both paths (bit-identity)."""
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.size
    rw_arr = None if rw is None else np.asarray(rw, np.int32).ravel()
    if arrival_fpga is None:
        arr = np.zeros(n, np.float64)
    else:
        arr = np.asarray(arrival_fpga, np.float64).ravel()
        if arr.shape[0] != n:
            raise ValueError("arrival_fpga must have one entry per request")
        if n and (not np.isfinite(arr).all() or arr.min() < 0):
            raise ValueError("arrival_fpga must be finite and non-negative")
    arr = arr / timings.clock_ratio          # FPGA cycles -> DRAM clocks
    if pe_id is None or num_ports is None or num_ports <= 1:
        ports, nports = np.zeros(n, np.int64), 1
    else:
        ports = np.asarray(pe_id, np.int64).ravel()
        nports = int(num_ports)
        if ports.shape[0] != n:
            raise ValueError("pe_id must have one entry per request")
        if n and (ports.min() < 0 or ports.max() >= nports):
            raise ValueError("pe_id outside [0, num_ports)")
    return addrs, n, rw_arr, arr, ports, nports


def simulate_arrivals_seq(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    sched: DRAMSchedConfig = DRAMSchedConfig(),
    rw: np.ndarray | None = None,
    *,
    arrival_fpga: np.ndarray | None = None,
    pe_id: np.ndarray | None = None,
    num_ports: int | None = None,
    arb_policy: str = "round_robin",
    weights=None,
    trace=None,
) -> ServingSimResult:
    """Request-at-a-time oracle for the *open-loop* channel — THE
    specification for arrival gating, idle-gap advance and service-paced
    arbitration that the fast path
    (:func:`repro.core.trace_engine.simulate_arrivals_fast`) is
    property-tested bit-identical against.

    Requests live in per-port FIFO queues (``pe_id``); a head is
    *eligible* once its ``arrival_fpga`` stamp (converted once to DRAM
    clocks) is ≤ the channel clock. One coupled loop:

    * **admission**: eligible heads are granted into the
      ``reorder_window``-deep pending window at service pace. The grant
      order is the arbiter's: fixed ``priority`` takes the lowest
      eligible port; (weighted) round robin keeps a rotating pointer
      with per-rotation credits — a port whose head has not arrived (or
      whose queue is empty) forfeits the rest of its credit for that
      rotation. This coupling is what makes arbitration a tenant-
      isolation mechanism: a backlogged hog cannot pre-enqueue its whole
      burst ahead of a later-arriving victim, because grants happen one
      service slot at a time against a bounded window.
    * **idle-gap advance**: with nothing pending and every queued head
      in the future, the clock jumps to the earliest head arrival.
      Refreshes that complete inside the gap overlap with idleness
      (banks still close, nothing stalls); one still in progress at the
      jump target delays the next issue to its end.
    * **refresh / pick / service**: identical to
      :func:`simulate_dram_sched_seq` — the accumulated-service refresh
      rule, the fifo / frfcfs / frfcfs_cap pick over the pending window
      (oldest = earliest *grant*), per-bank open-row classification,
      bus-turnaround against the issued direction sequence, and the
      positional bypass counters behind the starvation cap.

    The channel clock is tracked as ``anchor + offset`` — a float
    anchor assigned only at idle jumps plus an exact integer offset of
    service/refresh clocks — so every timestamp is produced by a single
    float rounding and the fast path can batch integer cost sums while
    remaining bit-identical. With every arrival at 0 the anchor stays
    integer zero and the loop degenerates *exactly*: single-port to
    :func:`simulate_dram_sched_seq`, multi-port to
    ``arbitrate_ports_seq`` composed with it (same permutation, counts
    and makespan — the closed-loop degeneracy property tests).

    ``trace`` (a :class:`repro.core.telemetry.ChannelTrace`) emits the
    lifecycle event stream natively — grants, idle gaps, refresh
    windows, turnarounds, issues, completions — which
    :func:`repro.core.telemetry.replay_arrival_events` reconstructs
    from the fast path's outputs (property-tested tuple-for-tuple
    equal). ``trace=None`` changes nothing.
    """
    addrs, n, rw_arr, arr, ports, nports = _serving_trace(
        addrs, timings, rw, arrival_fpga, pe_id, num_ports)
    if n == 0:
        return ServingSimResult(total_fpga_cycles=0.0, row_hits=0,
                                row_conflicts=0, first_accesses=0)
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    w = sched.effective_window
    use_cap = sched.policy == "frfcfs_cap"
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    credits = _serving_weights(nports, arb_policy, weights)
    priority = arb_policy == "priority"

    queues = [list(np.flatnonzero(ports == p)) for p in range(nports)]
    heads = [0] * nports
    open_row: dict[int, int] = {}
    pending: list[int] = []
    bypass: list[int] = []          # positional, parallel to ``pending``
    ptr, credit = 0, credits[0]     # (weighted) round-robin rotation state
    anchor: float | int = 0         # set only by idle jumps
    off = 0                         # integer service/refresh clocks since
    next_ref = t_refi
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    idle = 0.0
    served = 0
    completion = np.zeros(n, np.float64)
    service = np.zeros(n, np.int64)
    grant_order: list[int] = []
    granted_port: list[int] = []
    order: list[int] = []
    ev = None if trace is None else trace.events

    def eligible(p: int) -> bool:
        h = heads[p]
        return h < len(queues[p]) and arr[queues[p][h]] <= anchor + off

    while served < n:
        while len(pending) < w:              # -- admission
            g = -1
            if priority:
                for p in range(nports):
                    if eligible(p):
                        g = p
                        break
            else:
                for _ in range(nports + 1):
                    if credit > 0 and eligible(ptr):
                        g = ptr
                        credit -= 1
                        break
                    ptr = (ptr + 1) % nports
                    credit = credits[ptr]
            if g < 0:
                break
            idx = queues[g][heads[g]]
            heads[g] += 1
            pending.append(idx)
            bypass.append(0)
            grant_order.append(idx)
            granted_port.append(g)
            if ev is not None:
                ev.append(("grant", anchor + off, idx, g))
        if not pending:                      # -- idle-gap advance
            target = min(arr[queues[p][heads[p]]] for p in range(nports)
                         if heads[p] < len(queues[p]))
            now0 = anchor + off
            if t_refi:
                while next_ref <= target:
                    n_ref += 1
                    open_row.clear()
                    end = next_ref + t_rfc
                    if ev is not None:
                        ev.append(("refresh", next_ref, end))
                    next_ref += t_refi
                    if end > target:
                        target = end         # arrived mid-refresh
            if ev is not None:
                ev.append(("idle", now0, target))
            idle += target - (anchor + off)
            anchor, off = target, 0
            continue
        if t_refi:
            while anchor + off >= next_ref:  # refresh precedes the issue
                if ev is not None:
                    ev.append(("refresh", anchor + off,
                               anchor + off + t_rfc))
                off += t_rfc
                n_ref += 1
                open_row.clear()
                next_ref += t_refi
        pick = 0
        if w > 1:
            forced = None
            if use_cap:
                for i in range(len(pending)):
                    if bypass[i] >= sched.starvation_cap:
                        forced = i
                        break
            if forced is not None:
                pick = forced
            else:
                for i, j in enumerate(pending):
                    b = int(banks[j])
                    if b in open_row and open_row[b] == rows[j]:
                        pick = i
                        break
        idx = pending.pop(pick)
        bypass.pop(pick)
        now_t = anchor + off
        b, r = int(banks[idx]), int(rows[idx])
        if b not in open_row:
            n_first += 1
            cls = "first"
            cost = timings.t_rcd + timings.t_cl
        elif open_row[b] == r:
            n_hit += 1
            cls = "hit"
            cost = timings.t_cl
        else:
            n_conflict += 1
            cls = "conflict"
            cost = timings.t_rp + timings.t_rcd + timings.t_cl
        open_row[b] = r
        cost += timings.t_burst
        if rw_arr is not None:
            d = int(rw_arr[idx])
            if last_dir == 1 and d == 0:
                turn += timings.t_wtr
                cost += timings.t_wtr
                if ev is not None:
                    ev.append(("turn", now_t, "wtr", timings.t_wtr))
            elif last_dir == 0 and d == 1:
                turn += timings.t_rtw
                cost += timings.t_rtw
                if ev is not None:
                    ev.append(("turn", now_t, "rtw", timings.t_rtw))
            last_dir = d
        if ev is not None:
            ev.append(("issue", now_t, idx, b, r, cls, cost, 1, "ok"))
        off += cost
        for i in range(pick):        # entries granted earlier were bypassed
            bypass[i] += 1
        completion[idx] = anchor + off
        service[idx] = cost
        order.append(idx)
        served += 1
        if ev is not None:
            ev.append(("complete", anchor + off, idx))

    return ServingSimResult(
        total_fpga_cycles=(anchor + off) * timings.clock_ratio,
        row_hits=n_hit, row_conflicts=n_conflict, first_accesses=n_first,
        n_refreshes=n_ref, refresh_dram_cycles=n_ref * t_rfc,
        turnaround_dram_cycles=turn,
        service_order=np.asarray(order, dtype=np.int64),
        completion_fpga_cycles=completion * timings.clock_ratio,
        service_dram_cycles=service,
        grant_order=np.asarray(grant_order, dtype=np.int64),
        granted_port=np.asarray(granted_port, dtype=np.int64),
        idle_dram_cycles=idle)


def simulate_arrivals(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    sched: DRAMSchedConfig = DRAMSchedConfig(),
    rw: np.ndarray | None = None,
    *,
    arrival_fpga: np.ndarray | None = None,
    pe_id: np.ndarray | None = None,
    num_ports: int | None = None,
    arb_policy: str = "round_robin",
    weights=None,
    engine: str = "auto",
    trace=None,
) -> ServingSimResult:
    """Open-loop channel service — the fast engine, bit-identical to
    :func:`simulate_arrivals_seq` (property-tested over arrival process
    × ports × arbiter policy × DRAM policy × window × cap × refresh ×
    rw). Single-port streams run the chunked frontier scan in
    ``repro.core.trace_engine`` (row-hit runs at array speed, truncated
    by arrival/refresh/window boundaries); multi-port streams run its
    optimized admission-coupled event loop. ``trace`` requests the
    lifecycle event stream (oracle-emitted or fast-path-reconstructed;
    ``trace=None`` is the unchanged hot path)."""
    if engine not in ("auto", "fast", "sequential"):
        raise ValueError(f"engine={engine!r} must be auto|fast|sequential")
    if engine == "sequential":
        return simulate_arrivals_seq(
            addrs, timings, sched, rw, arrival_fpga=arrival_fpga,
            pe_id=pe_id, num_ports=num_ports, arb_policy=arb_policy,
            weights=weights, trace=trace)
    from repro.core import trace_engine
    return trace_engine.simulate_arrivals_fast(
        addrs, timings, sched, rw, arrival_fpga=arrival_fpga,
        pe_id=pe_id, num_ports=num_ports, arb_policy=arb_policy,
        weights=weights, trace=trace)


# ---------------------------------------------------------------------------
# Fault-injected (RAS) serving simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultSimResult(ServingSimResult):
    """:class:`ServingSimResult` extended with RAS observability.

    ``fault`` is the :class:`repro.core.faults.FaultStats` block for the
    run. ``attempts[i]`` counts the issues request ``i`` consumed
    (1 = clean or corrected first try; at most ``max_replays + 1``);
    ``dropped[i]`` flags requests whose last allowed attempt still
    failed — their completion stamp is the give-up time and they are
    counted in ``fault.n_dropped`` / ``fault.dropped_by_port``, never
    silently lost. With faults, ``service_dram_cycles[i]`` accumulates
    the bus clocks of *all* of request ``i``'s issues and
    ``service_order`` carries one entry per issue (replays repeat the
    index); ``grant_order`` remains the first-admission permutation.
    """

    fault: "object" = None
    attempts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))
    dropped: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, bool))

    def __post_init__(self):
        if self.fault is None:
            from repro.core.faults import FaultStats
            self.fault = FaultStats()


def simulate_faults_seq(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    sched: DRAMSchedConfig = DRAMSchedConfig(),
    rw: np.ndarray | None = None,
    *,
    faults: FaultConfig | None = None,
    channel: int = 0,
    arrival_fpga: np.ndarray | None = None,
    pe_id: np.ndarray | None = None,
    num_ports: int | None = None,
    arb_policy: str = "round_robin",
    weights=None,
    trace=None,
) -> FaultSimResult:
    """Request-at-a-time oracle for the *fault-injected* open-loop
    channel — THE specification for error injection, ECC handling,
    bounded replay with backoff, outage stalls and graceful
    degradation that the fast path
    (:func:`repro.core.trace_engine.simulate_faults_fast`) is
    property-tested bit-identical against.

    The loop is :func:`simulate_arrivals_seq` (admission / idle-gap
    advance / refresh / pick / service — unchanged) with a RAS layer
    around the service step:

    * **injection**: each *issue* of request ``i`` (attempt ``a``,
      1-based) draws ``u = error_uniform(seed, channel, i, a)`` and
      errors when ``u < transient_ber (+ weak_row_ber on a weak
      row)``. Weak rows are a seeded hash of the row id; ``channel``
      keys this channel's streams so multi-channel runs draw
      independently.
    * **outage windows**: before issuing, a channel inside a declared
      ``(start, end)`` outage jumps its clock to the window end
      (refreshes absorbed like an idle gap); pending work stalls —
      counted in ``fault.outage_dram_cycles`` — but nothing drops.
    * **classification**: an errored read under SECDED is *corrected*
      (``ecc_correction_clocks`` added to the issue's bus time) unless
      ``u < p * due_fraction`` makes it detected-uncorrectable; an
      errored write fails the link CRC when ``write_crc``; with
      ``ecc="none"`` / ``write_crc=False`` errors are silent (counted,
      no timing effect). The failed issue still occupied the bus
      (class cost + burst + turnaround it triggered) — that time is
      ``fault.replay_dram_cycles``.
    * **bounded replay**: a failed issue re-enters a replay queue
      ready at ``now + backoff_clocks << (attempt-1)``; ready replays
      are re-admitted into the reorder window *before* new arbiter
      grants (oldest-ready first). A request whose attempt
      ``max_replays + 1`` still fails is dropped at that stamp.
    * **degradation**: every injected error charges the effective row;
      at ``row_retire_threshold`` the natural row is retired — later
      accesses serve from spare row ``SPARE_ROW_BASE + row`` (same
      bank, never weak, capacity capped by ``max_retired_rows``).
      Every ``refresh_escalate_threshold`` injected errors shrink the
      effective refresh interval to ``t_refi >> level`` (floor
      ``t_rfc + 1``, at most ``refresh_escalate_max`` levels).

    With ``faults=None`` or an inactive config no draw, queue, or
    clock expression differs from :func:`simulate_arrivals_seq` — the
    zero-rate degeneracy is bit-identical (property-tested).

    ``trace`` (a :class:`repro.core.telemetry.ChannelTrace`) emits the
    lifecycle event stream natively — the serving events plus replay
    re-admissions, outage windows, per-attempt issue outcomes
    (ok/corrected/silent/failed), replay enqueues and drops — which
    :func:`repro.core.telemetry.replay_fault_events` reconstructs from
    the fast path's outputs and the deterministic fault draws
    (property-tested tuple-for-tuple equal). ``trace=None`` changes
    nothing.
    """
    import heapq

    from repro.core import faults as F

    fc = faults if faults is not None else FaultConfig()
    addrs, n, rw_arr, arr, ports, nports = _serving_trace(
        addrs, timings, rw, arrival_fpga, pe_id, num_ports)
    if n == 0:
        return FaultSimResult(total_fpga_cycles=0.0, row_hits=0,
                              row_conflicts=0, first_accesses=0)
    rows = timings.row_of(addrs)
    banks = timings.bank_of(addrs)
    w = sched.effective_window
    use_cap = sched.policy == "frfcfs_cap"
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    credits = _serving_weights(nports, arb_policy, weights)
    priority = arb_policy == "priority"
    weak_flags = F.weak_rows(fc, channel, rows)
    wins = fc.outage_windows_for(channel)
    secded = fc.ecc == "secded"

    queues = [list(np.flatnonzero(ports == p)) for p in range(nports)]
    heads = [0] * nports
    open_row: dict[int, int] = {}
    pending: list[int] = []
    bypass: list[int] = []
    ptr, credit = 0, credits[0]
    anchor: float | int = 0
    off = 0
    next_ref = t_refi
    t_refi_eff = t_refi             # shrinks under refresh escalation
    esc_level = 0
    n_hit = n_conflict = n_first = n_ref = turn = 0
    last_dir = -1
    idle = 0.0
    served = 0
    completion = np.zeros(n, np.float64)
    service = np.zeros(n, np.int64)
    attempts = np.zeros(n, np.int64)
    dropped = np.zeros(n, bool)
    grant_order: list[int] = []
    granted_port: list[int] = []
    order: list[int] = []
    replay_q: list[tuple[float, int, int]] = []   # (ready, seq, idx)
    rseq = 0
    retired: dict[int, int] = {}    # natural row -> spare row
    err_count: dict[int, int] = {}  # effective row -> charged errors
    st = F.FaultStats()
    retired_seq: list[tuple[int, int]] = []
    dropped_by_port: dict[int, int] = {}
    ev = None if trace is None else trace.events

    def eligible(p: int) -> bool:
        h = heads[p]
        return h < len(queues[p]) and arr[queues[p][h]] <= anchor + off

    while served < n:
        while len(pending) < w:              # -- admission
            if replay_q and replay_q[0][0] <= anchor + off:
                _, _, ridx = heapq.heappop(replay_q)
                pending.append(ridx)         # replays re-enter first
                bypass.append(0)
                if ev is not None:
                    ev.append(("readmit", anchor + off, ridx))
                continue
            g = -1
            if priority:
                for p in range(nports):
                    if eligible(p):
                        g = p
                        break
            else:
                for _ in range(nports + 1):
                    if credit > 0 and eligible(ptr):
                        g = ptr
                        credit -= 1
                        break
                    ptr = (ptr + 1) % nports
                    credit = credits[ptr]
            if g < 0:
                break
            idx = queues[g][heads[g]]
            heads[g] += 1
            pending.append(idx)
            bypass.append(0)
            grant_order.append(idx)
            granted_port.append(g)
            if ev is not None:
                ev.append(("grant", anchor + off, idx, g))
        if not pending:                      # -- idle-gap advance
            targets = [arr[queues[p][heads[p]]] for p in range(nports)
                       if heads[p] < len(queues[p])]
            if replay_q:
                targets.append(replay_q[0][0])
            target = min(targets)
            now0 = anchor + off
            if t_refi:
                while next_ref <= target:
                    n_ref += 1
                    open_row.clear()
                    end = next_ref + t_rfc
                    if ev is not None:
                        ev.append(("refresh", next_ref, end))
                    next_ref += t_refi_eff
                    if end > target:
                        target = end         # arrived mid-refresh
            if ev is not None:
                ev.append(("idle", now0, target))
            idle += target - (anchor + off)
            anchor, off = target, 0
            continue
        now = anchor + off
        jumped = False
        for s, e in wins:                    # -- outage window stall
            if s <= now < e:
                target = float(e)
                if t_refi:
                    while next_ref <= target:
                        n_ref += 1
                        open_row.clear()
                        end = next_ref + t_rfc
                        if ev is not None:
                            ev.append(("refresh", next_ref, end))
                        next_ref += t_refi_eff
                        if end > target:
                            target = end
                if ev is not None:
                    ev.append(("outage", now, target))
                st.outage_dram_cycles += target - now
                anchor, off = target, 0
                jumped = True
                break
        if jumped:
            continue
        if t_refi:
            while anchor + off >= next_ref:  # refresh precedes the issue
                if ev is not None:
                    ev.append(("refresh", anchor + off,
                               anchor + off + t_rfc))
                off += t_rfc
                n_ref += 1
                open_row.clear()
                next_ref += t_refi_eff
        pick = 0
        if w > 1:
            forced = None
            if use_cap:
                for i in range(len(pending)):
                    if bypass[i] >= sched.starvation_cap:
                        forced = i
                        break
            if forced is not None:
                pick = forced
            else:
                for i, j in enumerate(pending):
                    b = int(banks[j])
                    eff = retired.get(int(rows[j]), int(rows[j]))
                    if b in open_row and open_row[b] == eff:
                        pick = i
                        break
        idx = pending.pop(pick)
        bypass.pop(pick)
        now_t = anchor + off
        b, r_nat = int(banks[idx]), int(rows[idx])
        r = retired.get(r_nat, r_nat)
        if r != r_nat:
            st.spare_issues += 1
        if b not in open_row:
            n_first += 1
            cls = "first"
            cost = timings.t_rcd + timings.t_cl
        elif open_row[b] == r:
            n_hit += 1
            cls = "hit"
            cost = timings.t_cl
        else:
            n_conflict += 1
            cls = "conflict"
            cost = timings.t_rp + timings.t_rcd + timings.t_cl
        open_row[b] = r
        cost += timings.t_burst
        tpen = None
        if rw_arr is not None:
            d = int(rw_arr[idx])
            if last_dir == 1 and d == 0:
                turn += timings.t_wtr
                cost += timings.t_wtr
                tpen = ("wtr", timings.t_wtr)
            elif last_dir == 0 and d == 1:
                turn += timings.t_rtw
                cost += timings.t_rtw
                tpen = ("rtw", timings.t_rtw)
            last_dir = d
        attempts[idx] += 1
        att = int(attempts[idx])
        if att > 1:
            st.n_replays += 1
        weak = bool(weak_flags[idx]) and r == r_nat
        p_err = F.error_prob(fc, weak)
        errored = False
        u = 0.0
        if p_err > 0.0:
            u = F.error_uniform(fc, channel, idx, att)
            errored = u < p_err
        failed = False
        outcome = "ok"
        if errored:
            st.n_injected += 1
            if fc.row_retire_threshold and r < F.SPARE_ROW_BASE:
                c = err_count.get(r, 0) + 1
                err_count[r] = c
                if (c >= fc.row_retire_threshold
                        and r_nat not in retired
                        and len(retired) < fc.max_retired_rows):
                    retired[r_nat] = F.SPARE_ROW_BASE + r_nat
                    retired_seq.append((channel, r_nat))
            if fc.refresh_escalate_threshold and t_refi:
                while (esc_level < fc.refresh_escalate_max
                       and st.n_injected >= fc.refresh_escalate_threshold
                       * (esc_level + 1)):
                    esc_level += 1
                    st.refresh_escalations += 1
                    shrunk = t_refi >> esc_level
                    t_refi_eff = shrunk if shrunk > t_rfc else t_rfc + 1
            is_read = rw_arr is None or int(rw_arr[idx]) == 0
            if is_read:
                if secded:
                    if u < p_err * fc.due_fraction:
                        failed = True            # detected-uncorrectable
                        outcome = "failed"
                    else:
                        st.n_corrected += 1
                        st.correction_dram_cycles += fc.ecc_correction_clocks
                        cost += fc.ecc_correction_clocks
                        outcome = "corrected"
                else:
                    st.n_silent += 1
                    outcome = "silent"
            else:
                if fc.write_crc:
                    failed = True                # link CRC retry
                    outcome = "failed"
                else:
                    st.n_silent += 1
                    outcome = "silent"
        if ev is not None:
            if tpen is not None:
                ev.append(("turn", now_t, tpen[0], tpen[1]))
            ev.append(("issue", now_t, idx, b, r, cls, cost, att, outcome))
        off += cost
        for i in range(pick):
            bypass[i] += 1
        service[idx] += cost
        order.append(idx)
        if failed:
            st.n_uncorrectable += 1
            st.replay_dram_cycles += cost
            if att > fc.max_replays:             # out of attempts: drop
                dropped[idx] = True
                st.n_dropped += 1
                port = int(ports[idx])
                dropped_by_port[port] = dropped_by_port.get(port, 0) + 1
                completion[idx] = anchor + off
                served += 1
                if ev is not None:
                    ev.append(("drop", anchor + off, idx, att))
            else:
                rseq += 1
                ready = anchor + off + fc.backoff_for(att)
                heapq.heappush(replay_q, (ready, rseq, idx))
                if ev is not None:
                    ev.append(("replay", anchor + off, idx, att, ready))
        else:
            completion[idx] = anchor + off
            served += 1
            if ev is not None:
                ev.append(("complete", anchor + off, idx))

    st.rows_retired = tuple(retired_seq)
    st.dropped_by_port = dropped_by_port
    return FaultSimResult(
        total_fpga_cycles=(anchor + off) * timings.clock_ratio,
        row_hits=n_hit, row_conflicts=n_conflict, first_accesses=n_first,
        n_refreshes=n_ref, refresh_dram_cycles=n_ref * t_rfc,
        turnaround_dram_cycles=turn,
        service_order=np.asarray(order, dtype=np.int64),
        completion_fpga_cycles=completion * timings.clock_ratio,
        service_dram_cycles=service,
        grant_order=np.asarray(grant_order, dtype=np.int64),
        granted_port=np.asarray(granted_port, dtype=np.int64),
        idle_dram_cycles=idle,
        fault=st, attempts=attempts, dropped=dropped)


def simulate_faults(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    sched: DRAMSchedConfig = DRAMSchedConfig(),
    rw: np.ndarray | None = None,
    *,
    faults: FaultConfig | None = None,
    channel: int = 0,
    arrival_fpga: np.ndarray | None = None,
    pe_id: np.ndarray | None = None,
    num_ports: int | None = None,
    arb_policy: str = "round_robin",
    weights=None,
    engine: str = "auto",
    trace=None,
) -> FaultSimResult:
    """Fault-injected channel service — the fast engine, bit-identical
    to :func:`simulate_faults_seq`. An inactive fault config (``None``
    or nothing to inject on any channel) delegates to the fault-free
    fast path and wraps its result — the zero-rate degeneracy costs
    nothing (and emits the fault-free event stream, which is what the
    oracle emits too when nothing injects). ``trace`` requests the
    lifecycle event stream; ``trace=None`` is the unchanged hot
    path."""
    if engine not in ("auto", "fast", "sequential"):
        raise ValueError(f"engine={engine!r} must be auto|fast|sequential")
    if engine == "sequential":
        return simulate_faults_seq(
            addrs, timings, sched, rw, faults=faults, channel=channel,
            arrival_fpga=arrival_fpga, pe_id=pe_id, num_ports=num_ports,
            arb_policy=arb_policy, weights=weights, trace=trace)
    if faults is None or not faults.injects:
        base = simulate_arrivals(
            addrs, timings, sched, rw, arrival_fpga=arrival_fpga,
            pe_id=pe_id, num_ports=num_ports, arb_policy=arb_policy,
            weights=weights, trace=trace)
        n = base.completion_fpga_cycles.size
        return FaultSimResult(
            total_fpga_cycles=base.total_fpga_cycles,
            row_hits=base.row_hits, row_conflicts=base.row_conflicts,
            first_accesses=base.first_accesses,
            n_refreshes=base.n_refreshes,
            refresh_dram_cycles=base.refresh_dram_cycles,
            turnaround_dram_cycles=base.turnaround_dram_cycles,
            service_order=base.service_order,
            completion_fpga_cycles=base.completion_fpga_cycles,
            service_dram_cycles=base.service_dram_cycles,
            grant_order=base.grant_order,
            granted_port=base.granted_port,
            idle_dram_cycles=base.idle_dram_cycles,
            attempts=np.ones(n, np.int64),
            dropped=np.zeros(n, bool))
    from repro.core import trace_engine
    return trace_engine.simulate_faults_fast(
        addrs, timings, sched, rw, faults=faults, channel=channel,
        arrival_fpga=arrival_fpga, pe_id=pe_id, num_ports=num_ports,
        arb_policy=arb_policy, weights=weights, trace=trace)


def modeled_bandwidth_gbps(
    result: SimResult, total_bytes: int, timings: DRAMTimings = DDR4_2400
) -> float:
    """Sustained bandwidth implied by a simulation result."""
    seconds = result.total_fpga_cycles * timings.t_fpga_ns * 1e-9
    return total_bytes / max(seconds, 1e-12) / 1e9


def roofline_time_s(
    flops: float,
    hbm_bytes: float,
    collective_bytes: float,
    *,
    chips: int,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    ici_bw: float = 50e9 * 4,  # ~50 GB/s/link x 4 links per v5e chip (2D torus)
) -> dict:
    """Three-term roofline for §Roofline of EXPERIMENTS.md.

    Inputs are *global* HLO quantities; each term divides by the chip count
    (SPMD: every chip executes 1/chips of the work in parallel).
    """
    compute_s = flops / (chips * peak_flops)
    memory_s = hbm_bytes / (chips * hbm_bw)
    collective_s = collective_bytes / (chips * ici_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).removesuffix("_s")
    terms["bound_s"] = max(compute_s, memory_s, collective_s)
    return terms
