"""Per-request lifecycle tracing for the staged simulator.

Every modeled number in this repo is an aggregate (makespan, stage
cycles, sojourn percentiles); this module adds the *per-request* lens —
where did each request's cycles go? — as an opt-in recorder threaded
through ``MemoryController.simulate(..., trace=...)``.

Design (docs/ARCHITECTURE.md §11):

* The **seq oracles** (``simulate_dram_sched_seq``,
  ``simulate_arrivals_seq``, ``simulate_faults_seq``) emit events
  natively — the event stream is part of THE specification.
* The **fast paths** stay event-free on the hot path; when a trace is
  requested they run unchanged and the ``replay_*_events`` functions
  here *reconstruct* the identical stream from their recorded outputs
  (``grant_order`` / ``granted_port`` / ``service_order`` plus the
  deterministic fault draws), property-tested event-for-event equal to
  the oracle. ``trace=None`` changes no code path — every golden and
  fast-path result stays bit-identical.

Event schema — plain tuples, kind first. Channel events
(:class:`ChannelTrace`; timestamps in DRAM command clocks on that
channel's clock, request ids are *local* to the simulated stream and
mapped to global ``seq`` via ``req_ids``):

====================================================  =====================
``("window",  t, req)``                               closed-loop reorder-
                                                      window entry
``("grant",   t, req, port)``                         serving admission
                                                      (= window entry in
                                                      the coupled model)
``("readmit", t, req)``                               replay re-admission
``("refresh", t0, t1)``                               refresh stall /
                                                      absorbed window
``("idle",    t0, t1)``                               idle gap (waiting
                                                      for arrivals)
``("outage",  t0, t1)``                               channel outage stall
``("turn",    t, dir, penalty)``                      bus turnaround
                                                      (dir "wtr"|"rtw")
``("issue",   t, req, bank, row, cls, cost,           DRAM issue; cls in
  attempt, outcome)``                                 first|hit|conflict,
                                                      outcome in ok|
                                                      corrected|silent|
                                                      failed
``("replay",  t, req, attempt, ready)``               failed issue queued
                                                      for replay
``("drop",    t, req, attempt)``                      out of attempts
``("complete", t, req)``                              service completion
====================================================  =====================

Stage events (:attr:`TraceRecorder.stage_events`; ordinal, no clock —
the closed-loop front-end stages are order-based):

``("grant_slot", channel, slot, seq, port)`` — closed-loop arbiter grant;
``("cache", channel, seq, "hit"|"miss")`` — cache filter verdict;
``("cache_wb", channel, seq)`` — victim write-back inserted;
``("batch", channel, seq, batch_idx)`` — batch assignment.

Arrival events are stored vectorized (``arrival_fpga`` / ``pe_by_seq``
arrays on the recorder — one ``("arrival", t, seq, port)`` per request
via :meth:`TraceRecorder.arrival_events`) rather than as per-event
tuples; they are pure inputs, so there is nothing to reconstruct.

On top of the recorder, :class:`CycleAttribution` decomposes each
request's sojourn into arrival-gating / arbitration / cache / batch /
reorder-slip / refresh / outage / replay / service components that sum
*exactly* (bit-for-bit, left-to-right) to ``ServingStats.sojourn`` —
property-tested — with per-tenant and top-K hot-row rollups.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.config import DRAMSchedConfig, FaultConfig

#: attribution components, in the documented left-to-right summation
#: order (the exact-sum identity is defined over this order).
COMPONENTS = ("gating", "arbitration", "cache", "batch", "reorder",
              "refresh", "outage", "replay", "service")


class ChannelTrace:
    """Event sink for one simulated channel stream.

    ``events`` holds the raw tuples (request ids local to the simulated
    stream); ``req_ids`` maps local index -> global ``seq`` (``None``
    = identity). Emission sites append directly to ``events`` — the
    recorder adds no per-event overhead beyond the list append.
    """

    __slots__ = ("channel", "events", "req_ids")

    def __init__(self, channel: int = 0, req_ids=None):
        self.channel = int(channel)
        self.events: list[tuple] = []
        self.req_ids = None if req_ids is None else \
            np.asarray(req_ids, np.int64)

    def resolve(self, local: int) -> int:
        """Global ``seq`` of a local request index (-1 = retired)."""
        if self.req_ids is None:
            return int(local)
        return int(self.req_ids[local])

    def __len__(self) -> int:
        return len(self.events)


class TraceRecorder:
    """Opt-in per-run event recorder (pass as
    ``MemoryController.simulate(..., trace=TraceRecorder())``).

    Collects one :class:`ChannelTrace` per memory channel plus the
    ordinal stage events, and — filled in by ``run_pipeline`` — the
    metadata the exporter and attribution need (timings, the uniform
    pre-DRAM shift, arrival/port arrays by ``seq``).
    """

    def __init__(self):
        self.meta: dict = {}
        self.timings = None
        self.stage_events: list[tuple] = []
        self.channels: dict[int, ChannelTrace] = {}
        self.arrival_fpga: np.ndarray | None = None   # by seq
        self.pe_by_seq: np.ndarray | None = None      # by seq
        self.pre_fpga: float = 0.0                    # uniform pre-DRAM shift
        self.makespan_fpga: float = 0.0
        self.open_loop: bool = False

    def channel(self, k: int, req_ids=None) -> ChannelTrace:
        ct = ChannelTrace(k, req_ids)
        self.channels[k] = ct
        return ct

    @property
    def n_events(self) -> int:
        return (sum(len(c) for c in self.channels.values())
                + len(self.stage_events))

    def arrival_events(self):
        """Yield ``("arrival", t_fpga, seq, port)`` per request (the
        vectorized arrival store rendered as lifecycle events)."""
        if self.arrival_fpga is None:
            return
        pe = self.pe_by_seq if self.pe_by_seq is not None else \
            np.zeros(self.arrival_fpga.shape[0], np.int64)
        for s in range(self.arrival_fpga.shape[0]):
            yield ("arrival", float(self.arrival_fpga[s]), s, int(pe[s]))

    def finalize(self, ctx, total: float) -> None:
        """Called by ``run_pipeline`` once the makespan is known."""
        self.timings = ctx.timings
        self.makespan_fpga = float(total)
        self.open_loop = ctx.serving_completion is not None
        if self.open_loop:
            self.pre_fpga = float(total - ctx.dram_makespan)
            self.arrival_fpga = ctx.serving_arrival
            self.pe_by_seq = ctx.serving_pe
        self.meta.setdefault("num_channels", ctx.num_channels)
        self.meta.setdefault("open_loop", self.open_loop)


# ---------------------------------------------------------------------------
# Fast-path event reconstruction — replays the oracle's loop structure
# with every *decision* read from the fast path's recorded outputs
# (no O(window) pick scans, no O(ports) arbiter scans).
# ---------------------------------------------------------------------------

def replay_sched_events(addrs, timings, sched, rw, result,
                        trace: ChannelTrace) -> None:
    """Reconstruct the closed-loop event stream of
    :func:`repro.core.timing.simulate_dram_sched_seq` from a fast-path
    :class:`~repro.core.timing.SchedSimResult` (its ``service_order``
    is the decision record). Appends into ``trace.events``."""
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.size
    if n == 0:
        return
    rows = timings.row_of(addrs).tolist()
    banks = timings.bank_of(addrs).tolist()
    rw_l = None if rw is None else np.asarray(rw, np.int32).ravel().tolist()
    w = sched.effective_window
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    order = np.asarray(result.service_order, np.int64).tolist()
    ev = trace.events

    open_row: dict[int, int] = {}
    npend = 0
    nxt = 0
    cycle = 0
    next_ref = t_refi
    last_dir = -1
    for idx in order:
        while nxt < n and npend < w:
            ev.append(("window", cycle, nxt))
            nxt += 1
            npend += 1
        if t_refi:
            while cycle >= next_ref:
                ev.append(("refresh", cycle, cycle + t_rfc))
                cycle += t_rfc
                open_row.clear()
                next_ref += t_refi
        npend -= 1
        b, r = banks[idx], rows[idx]
        if b not in open_row:
            cls = "first"
            cost = timings.t_rcd + timings.t_cl
        elif open_row[b] == r:
            cls = "hit"
            cost = timings.t_cl
        else:
            cls = "conflict"
            cost = timings.t_rp + timings.t_rcd + timings.t_cl
        open_row[b] = r
        cost += timings.t_burst
        if rw_l is not None:
            d = rw_l[idx]
            if last_dir == 1 and d == 0:
                cost += timings.t_wtr
                ev.append(("turn", cycle, "wtr", timings.t_wtr))
            elif last_dir == 0 and d == 1:
                cost += timings.t_rtw
                ev.append(("turn", cycle, "rtw", timings.t_rtw))
            last_dir = d
        ev.append(("issue", cycle, idx, b, r, cls, cost, 1, "ok"))
        cycle += cost
        ev.append(("complete", cycle, idx))


def replay_arrival_events(addrs, timings, sched, rw, *, arrival_fpga,
                          pe_id, num_ports, result,
                          trace: ChannelTrace) -> None:
    """Reconstruct the open-loop event stream of
    :func:`repro.core.timing.simulate_arrivals_seq` from a fast-path
    :class:`~repro.core.timing.ServingSimResult`.

    The oracle's arbiter decision at every admission slot is exactly
    ``grant_order`` / ``granted_port``; its pick at every service slot
    is ``service_order``. Replaying the same loop skeleton (admission
    until the window is full or the next-granted request has not yet
    arrived; idle-gap advance with refresh absorption; refresh-precedes-
    issue; classify + charge) with those recorded decisions, using the
    identical ``anchor + off`` clock expressions, lands on bit-identical
    timestamps — property-tested event-for-event against the oracle."""
    from repro.core.timing import _serving_trace

    addrs, n, rw_arr, arr, ports, nports = _serving_trace(
        addrs, timings, rw, arrival_fpga, pe_id, num_ports)
    if n == 0:
        return
    rows = timings.row_of(addrs).tolist()
    banks = timings.bank_of(addrs).tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    arr_l = arr.tolist()
    w = sched.effective_window
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    go = np.asarray(result.grant_order, np.int64).tolist()
    gp = np.asarray(result.granted_port, np.int64).tolist()
    so = np.asarray(result.service_order, np.int64).tolist()
    ev = trace.events

    queues = [list(np.flatnonzero(ports == p)) for p in range(nports)]
    heads = [0] * nports
    open_row: dict[int, int] = {}
    npend = 0
    gi = 0
    anchor: float | int = 0
    off = 0
    next_ref = t_refi
    last_dir = -1
    served = 0
    si = 0
    while served < n:
        while npend < w and gi < n:
            idx = go[gi]
            if arr_l[idx] <= anchor + off:
                g = gp[gi]
                heads[g] += 1
                ev.append(("grant", anchor + off, idx, g))
                gi += 1
                npend += 1
            else:
                break
        if npend == 0:                       # -- idle-gap advance
            target = min(arr[queues[p][heads[p]]] for p in range(nports)
                         if heads[p] < len(queues[p]))
            now0 = anchor + off
            if t_refi:
                while next_ref <= target:
                    end = next_ref + t_rfc
                    ev.append(("refresh", next_ref, end))
                    open_row.clear()
                    next_ref += t_refi
                    if end > target:
                        target = end
            ev.append(("idle", now0, target))
            anchor, off = target, 0
            continue
        if t_refi:
            while anchor + off >= next_ref:
                ev.append(("refresh", anchor + off, anchor + off + t_rfc))
                off += t_rfc
                open_row.clear()
                next_ref += t_refi
        idx = so[si]
        si += 1
        npend -= 1
        now_t = anchor + off
        b, r = banks[idx], rows[idx]
        if b not in open_row:
            cls = "first"
            cost = timings.t_rcd + timings.t_cl
        elif open_row[b] == r:
            cls = "hit"
            cost = timings.t_cl
        else:
            cls = "conflict"
            cost = timings.t_rp + timings.t_rcd + timings.t_cl
        open_row[b] = r
        cost += timings.t_burst
        if rw_l is not None:
            d = rw_l[idx]
            if last_dir == 1 and d == 0:
                cost += timings.t_wtr
                ev.append(("turn", now_t, "wtr", timings.t_wtr))
            elif last_dir == 0 and d == 1:
                cost += timings.t_rtw
                ev.append(("turn", now_t, "rtw", timings.t_rtw))
            last_dir = d
        ev.append(("issue", now_t, idx, b, r, cls, cost, 1, "ok"))
        off += cost
        ev.append(("complete", anchor + off, idx))
        served += 1


def replay_fault_events(addrs, timings, sched, rw, *, faults, channel,
                        arrival_fpga, pe_id, num_ports, result,
                        trace: ChannelTrace) -> None:
    """Reconstruct the fault-injected event stream of
    :func:`repro.core.timing.simulate_faults_seq` from a fast-path
    :class:`~repro.core.timing.FaultSimResult`.

    Replays :func:`replay_arrival_events`' skeleton with the RAS layer
    woven back in: ``service_order`` carries one entry per *issue*
    (replays repeat the index), and because every fault draw is a pure
    function of ``(seed, channel, index, attempt)`` the error outcome,
    ECC correction charge, replay-queue schedule, retirement map and
    refresh escalation replay deterministically — no extra state needs
    to be recorded by the fast path."""
    from repro.core import faults as F
    from repro.core.timing import _serving_trace

    fc = faults if faults is not None else FaultConfig()
    addrs, n, rw_arr, arr, ports, nports = _serving_trace(
        addrs, timings, rw, arrival_fpga, pe_id, num_ports)
    if n == 0:
        return
    rows_a = timings.row_of(addrs)
    rows = rows_a.tolist()
    banks = timings.bank_of(addrs).tolist()
    rw_l = None if rw_arr is None else rw_arr.tolist()
    arr_l = arr.tolist()
    w = sched.effective_window
    t_refi, t_rfc = sched.t_refi, sched.t_rfc
    weak_flags = F.weak_rows(fc, channel, rows_a)
    wins = fc.outage_windows_for(channel)
    secded = fc.ecc == "secded"
    go = np.asarray(result.grant_order, np.int64).tolist()
    gp = np.asarray(result.granted_port, np.int64).tolist()
    so = np.asarray(result.service_order, np.int64).tolist()
    ev = trace.events

    queues = [list(np.flatnonzero(ports == p)) for p in range(nports)]
    heads = [0] * nports
    open_row: dict[int, int] = {}
    npend = 0
    gi = 0
    anchor: float | int = 0
    off = 0
    next_ref = t_refi
    t_refi_eff = t_refi
    esc_level = 0
    n_injected = 0
    last_dir = -1
    served = 0
    si = 0
    attempts = [0] * n
    replay_q: list[tuple[float, int, int]] = []
    rseq = 0
    retired: dict[int, int] = {}
    err_count: dict[int, int] = {}
    while served < n:
        while npend < w:                     # -- admission
            if replay_q and replay_q[0][0] <= anchor + off:
                _, _, ridx = heapq.heappop(replay_q)
                ev.append(("readmit", anchor + off, ridx))
                npend += 1
                continue
            if gi < n and arr_l[go[gi]] <= anchor + off:
                idx = go[gi]
                g = gp[gi]
                heads[g] += 1
                ev.append(("grant", anchor + off, idx, g))
                gi += 1
                npend += 1
                continue
            break
        if npend == 0:                       # -- idle-gap advance
            targets = [arr[queues[p][heads[p]]] for p in range(nports)
                       if heads[p] < len(queues[p])]
            if replay_q:
                targets.append(replay_q[0][0])
            target = min(targets)
            now0 = anchor + off
            if t_refi:
                while next_ref <= target:
                    end = next_ref + t_rfc
                    ev.append(("refresh", next_ref, end))
                    open_row.clear()
                    next_ref += t_refi_eff
                    if end > target:
                        target = end
            ev.append(("idle", now0, target))
            anchor, off = target, 0
            continue
        now = anchor + off
        jumped = False
        for s, e in wins:                    # -- outage window stall
            if s <= now < e:
                target = float(e)
                if t_refi:
                    while next_ref <= target:
                        end = next_ref + t_rfc
                        ev.append(("refresh", next_ref, end))
                        open_row.clear()
                        next_ref += t_refi_eff
                        if end > target:
                            target = end
                ev.append(("outage", now, target))
                anchor, off = target, 0
                jumped = True
                break
        if jumped:
            continue
        if t_refi:
            while anchor + off >= next_ref:
                ev.append(("refresh", anchor + off, anchor + off + t_rfc))
                off += t_rfc
                open_row.clear()
                next_ref += t_refi_eff
        idx = so[si]
        si += 1
        npend -= 1
        now_t = anchor + off
        b, r_nat = banks[idx], rows[idx]
        r = retired.get(r_nat, r_nat)
        if b not in open_row:
            cls = "first"
            cost = timings.t_rcd + timings.t_cl
        elif open_row[b] == r:
            cls = "hit"
            cost = timings.t_cl
        else:
            cls = "conflict"
            cost = timings.t_rp + timings.t_rcd + timings.t_cl
        open_row[b] = r
        cost += timings.t_burst
        tpen = None
        if rw_l is not None:
            d = rw_l[idx]
            if last_dir == 1 and d == 0:
                cost += timings.t_wtr
                tpen = ("wtr", timings.t_wtr)
            elif last_dir == 0 and d == 1:
                cost += timings.t_rtw
                tpen = ("rtw", timings.t_rtw)
            last_dir = d
        attempts[idx] += 1
        att = attempts[idx]
        weak = bool(weak_flags[idx]) and r == r_nat
        p_err = F.error_prob(fc, weak)
        errored = False
        u = 0.0
        if p_err > 0.0:
            u = F.error_uniform(fc, channel, idx, att)
            errored = u < p_err
        failed = False
        outcome = "ok"
        if errored:
            n_injected += 1
            if fc.row_retire_threshold and r < F.SPARE_ROW_BASE:
                c = err_count.get(r, 0) + 1
                err_count[r] = c
                if (c >= fc.row_retire_threshold
                        and r_nat not in retired
                        and len(retired) < fc.max_retired_rows):
                    retired[r_nat] = F.SPARE_ROW_BASE + r_nat
            if fc.refresh_escalate_threshold and t_refi:
                while (esc_level < fc.refresh_escalate_max
                       and n_injected >= fc.refresh_escalate_threshold
                       * (esc_level + 1)):
                    esc_level += 1
                    shrunk = t_refi >> esc_level
                    t_refi_eff = shrunk if shrunk > t_rfc else t_rfc + 1
            is_read = rw_l is None or rw_l[idx] == 0
            if is_read:
                if secded:
                    if u < p_err * fc.due_fraction:
                        failed = True
                        outcome = "failed"
                    else:
                        outcome = "corrected"
                        cost += fc.ecc_correction_clocks
                else:
                    outcome = "silent"
            else:
                if fc.write_crc:
                    failed = True
                    outcome = "failed"
                else:
                    outcome = "silent"
        if tpen is not None:
            ev.append(("turn", now_t, tpen[0], tpen[1]))
        ev.append(("issue", now_t, idx, b, r, cls, cost, att, outcome))
        off += cost
        if failed:
            if att > fc.max_replays:
                ev.append(("drop", anchor + off, idx, att))
                served += 1
            else:
                rseq += 1
                ready = anchor + off + fc.backoff_for(att)
                heapq.heappush(replay_q, (ready, rseq, idx))
                ev.append(("replay", anchor + off, idx, att, ready))
        else:
            ev.append(("complete", anchor + off, idx))
            served += 1


# ---------------------------------------------------------------------------
# Cycle attribution
# ---------------------------------------------------------------------------

def _merge_intervals(ivs: list[tuple[float, float]]):
    """Sorted, merged (start, end, cumulative-length-before) arrays."""
    if not ivs:
        e = np.empty(0, np.float64)
        return e, e, e
    ivs = sorted(ivs)
    ms, me = [ivs[0][0]], [ivs[0][1]]
    for s, e in ivs[1:]:
        if s <= me[-1]:
            me[-1] = max(me[-1], e)
        else:
            ms.append(s)
            me.append(e)
    s_arr = np.asarray(ms, np.float64)
    e_arr = np.asarray(me, np.float64)
    cum = np.concatenate([[0.0], np.cumsum(e_arr - s_arr)])[:-1]
    return s_arr, e_arr, cum


def _coverage(s_arr, e_arr, cum, x):
    """Total merged-interval length before point(s) ``x``."""
    x = np.asarray(x, np.float64)
    j = np.searchsorted(s_arr, x, side="right") - 1
    jj = np.clip(j, 0, max(0, s_arr.size - 1))
    if s_arr.size == 0:
        return np.zeros_like(x)
    inside = np.clip(x - s_arr[jj], 0.0, e_arr[jj] - s_arr[jj])
    return np.where(j >= 0, cum[jj] + inside, 0.0)


def _overlap(s_arr, e_arr, cum, a, b):
    """Per-request overlap of merged intervals with ``[a, b)``."""
    return np.maximum(
        _coverage(s_arr, e_arr, cum, np.maximum(b, a))
        - _coverage(s_arr, e_arr, cum, a), 0.0)


@dataclasses.dataclass
class CycleAttribution:
    """Decomposition of each request's sojourn into the nine
    :data:`COMPONENTS`, in FPGA cycles.

    The identity — enforced by construction and property-tested — is
    that the *left-to-right* sum of the component arrays equals
    ``ServingStats.sojourn_fpga_cycles`` bit-for-bit: the service
    component (last in the chain, so only one float addition follows
    it) absorbs the float-conversion residue of the DRAM-clock →
    FPGA-cycle telescoping (a few ULPs; every other component is its
    documented interval length exactly).

    Component semantics (per request):

    * ``gating``      — the uniform pre-DRAM pipeline fill (controller
      overhead + arbiter grant tree) every request crosses;
    * ``arbitration`` — arrival → port grant, minus refresh/outage
      stalls in that span (waiting for the arbiter / window slot);
    * ``cache`` / ``batch`` — front-end stage residence; the serving
      datapath bypasses both engines, so they are zero in open-loop
      runs (closed-loop runs report them in the aggregate view);
    * ``reorder``     — grant → first DRAM issue, minus refresh/outage
      stalls in that span (slip inside the reorder window);
    * ``refresh`` / ``outage`` — stall overlap with the request's
      pre-issue wait ([arrival, first issue)); refreshes absorbed
      *inside* an outage window count as outage, so the two never
      double-book a clock;
    * ``replay``      — first issue start → final issue start (earlier
      attempts' bus time, backoff and re-admission waits; includes any
      refresh during those waits);
    * ``service``     — the final issue's own bus occupancy (class cost
      + burst + turnaround + ECC correction), plus the ULP-scale float
      residue that makes the left-to-right sum land exactly on sojourn.
    """

    components: dict[str, np.ndarray]
    sojourn: np.ndarray
    pe_id: np.ndarray
    channel_by_seq: np.ndarray
    row_by_seq: np.ndarray
    dropped: np.ndarray
    aggregate_totals: dict[str, float] | None = None

    @property
    def n(self) -> int:
        return int(self.sojourn.shape[0])

    def ltr_sum(self) -> np.ndarray:
        """The documented left-to-right component sum (== sojourn)."""
        out = None
        for name in COMPONENTS:
            c = self.components[name]
            out = c.copy() if out is None else out + c
        return out

    def totals(self) -> dict[str, float]:
        if self.aggregate_totals is not None:
            return dict(self.aggregate_totals)
        return {k: float(v.sum()) for k, v in self.components.items()}

    def per_tenant(self) -> dict[int, dict[str, float]]:
        out: dict[int, dict[str, float]] = {}
        for p in np.unique(self.pe_id):
            m = self.pe_id == p
            rec = {k: float(v[m].sum()) for k, v in self.components.items()}
            rec["n"] = int(m.sum())
            rec["mean_sojourn"] = float(self.sojourn[m].mean())
            out[int(p)] = rec
        return out

    def top_rows(self, k: int = 10) -> list[dict]:
        """Top-``k`` (channel, row) keys by summed sojourn."""
        key = self.channel_by_seq.astype(np.int64) * (1 << 44) \
            + self.row_by_seq
        uniq, inv = np.unique(key, return_inverse=True)
        tot = np.bincount(inv, weights=self.sojourn)
        cnt = np.bincount(inv)
        top = np.argsort(tot)[::-1][:k]
        return [{"channel": int(uniq[i] >> 44),
                 "row": int(uniq[i] & ((1 << 44) - 1)),
                 "n_requests": int(cnt[i]),
                 "sojourn_fpga_cycles": float(tot[i])}
                for i in top]

    def as_dict(self, top_k: int = 10) -> dict:
        return {
            "n_requests": self.n,
            "components_total": self.totals(),
            "per_tenant": {str(p): rec
                           for p, rec in self.per_tenant().items()},
            "top_rows": self.top_rows(top_k),
            "n_dropped": int(self.dropped.sum()),
        }

    def summary_text(self, top_k: int = 5) -> str:
        tot = self.totals()
        grand = sum(tot.values()) or 1.0
        head = (f"aggregate cycle attribution "
                f"(makespan {grand:.0f} FPGA cycles)"
                if self.aggregate_totals is not None else
                f"cycle attribution over {self.n} requests "
                f"(total sojourn {grand:.0f} FPGA cycles)")
        lines = [head]
        for name in COMPONENTS:
            v = tot.get(name, 0.0)
            lines.append(f"  {name:<12} {v:>16.1f}  ({100 * v / grand:5.1f}%)")
        if self.aggregate_totals is None:
            for p, rec in sorted(self.per_tenant().items()):
                top = max(((k, rec[k]) for k in COMPONENTS),
                          key=lambda kv: kv[1])
                lines.append(
                    f"  tenant {p}: n={rec['n']} mean_sojourn="
                    f"{rec['mean_sojourn']:.1f} dominant={top[0]}")
            for r in self.top_rows(top_k):
                lines.append(
                    f"  hot row ch{r['channel']}/r{r['row']}: "
                    f"{r['n_requests']} reqs, "
                    f"{r['sojourn_fpga_cycles']:.0f} cycles")
        return "\n".join(lines)

    # -- builders ----------------------------------------------------------

    @classmethod
    def from_recorder(cls, recorder: TraceRecorder,
                      serving) -> "CycleAttribution":
        """Per-request attribution for an open-loop run, from the
        recorder's channel events + the run's ``ServingStats``."""
        n = serving.arrival_fpga_cycles.shape[0]
        ratio = recorder.timings.clock_ratio
        sojourn = serving.sojourn_fpga_cycles
        grant_t = np.zeros(n, np.float64)
        s1 = np.zeros(n, np.float64)        # first issue start
        sl = np.zeros(n, np.float64)        # last issue start
        last_cost = np.zeros(n, np.float64)
        end_t = np.zeros(n, np.float64)
        seen_issue = np.zeros(n, bool)
        dropped = np.zeros(n, bool)
        ch_of = np.zeros(n, np.int64)
        row_of = np.zeros(n, np.int64)
        arr_dram = np.zeros(n, np.float64)
        comp = {name: np.zeros(n, np.float64) for name in COMPONENTS}
        for k, ct in sorted(recorder.channels.items()):
            ref_iv: list[tuple[float, float]] = []
            out_iv: list[tuple[float, float]] = []
            members: list[int] = []
            for e in ct.events:
                kind = e[0]
                if kind == "refresh":
                    ref_iv.append((e[1], e[2]))
                elif kind == "outage":
                    out_iv.append((e[1], e[2]))
                elif kind == "grant":
                    s = ct.resolve(e[2])
                    grant_t[s] = e[1]
                    members.append(s)
                elif kind == "issue":
                    s = ct.resolve(e[2])
                    if not seen_issue[s]:
                        s1[s] = e[1]
                        seen_issue[s] = True
                    sl[s] = e[1]
                    last_cost[s] = e[6]
                    ch_of[s] = k
                    row_of[s] = e[4]
                elif kind in ("complete", "drop"):
                    s = ct.resolve(e[2])
                    end_t[s] = e[1]
                    if kind == "drop":
                        dropped[s] = True
            if not members:
                continue
            m = np.asarray(members, np.int64)
            arr_dram[m] = serving.arrival_fpga_cycles[m] / ratio
            # refresh and outage windows can nest (refreshes absorbed
            # inside an outage are emitted too) — subtract their UNION
            # from the wait spans, and attribute the overlap to outage
            # (refresh = union minus outage, always >= 0).
            us, ue, uc = _merge_intervals(ref_iv + out_iv)
            os_, oe, oc = _merge_intervals(out_iv)
            a, g, f1 = arr_dram[m], grant_t[m], s1[m]
            u1 = _overlap(us, ue, uc, a, g)
            u2 = _overlap(us, ue, uc, g, f1)
            o1 = _overlap(os_, oe, oc, a, g)
            o2 = _overlap(os_, oe, oc, g, f1)
            comp["arbitration"][m] = (g - a - u1) * ratio
            comp["reorder"][m] = (f1 - g - u2) * ratio
            comp["refresh"][m] = (u1 + u2 - o1 - o2) * ratio
            comp["outage"][m] = (o1 + o2) * ratio
            comp["replay"][m] = (sl[m] - f1) * ratio
            comp["service"][m] = (end_t[m] - sl[m]) * ratio
        comp["gating"][:] = recorder.pre_fpga
        # Exact-sum identity: service (last in the left-to-right chain,
        # so a single float addition follows it) absorbs the ULP-scale
        # residue of the per-component DRAM->FPGA conversion. Direct
        # solve lands exactly in practice; the nextafter loop covers the
        # one-rounding-step stragglers (the map x -> fl(prefix + x) is
        # onto, so an exact preimage always exists).
        prefix = None
        for name in COMPONENTS[:-1]:
            c = comp[name]
            prefix = c.copy() if prefix is None else prefix + c
        svc = sojourn - prefix
        for _ in range(64):
            cur = prefix + svc
            bad = cur != sojourn
            if not bad.any():
                break
            svc[bad] = np.nextafter(
                svc[bad], np.where(cur[bad] < sojourn[bad],
                                   np.inf, -np.inf))
        comp["service"] = svc
        return cls(components=comp, sojourn=sojourn,
                   pe_id=serving.pe_id, channel_by_seq=ch_of,
                   row_by_seq=row_of, dropped=dropped)

    @classmethod
    def from_pipeline(cls, result,
                      recorder: TraceRecorder | None = None
                      ) -> "CycleAttribution":
        """Attribution for any pipeline run: per-request when the run
        was open-loop and traced; otherwise the aggregate stage-cycle
        view (``breakdown()`` re-keyed onto the component names)."""
        if (result.serving is not None and recorder is not None
                and recorder.channels):
            return cls.from_recorder(recorder, result.serving)
        bd = result.breakdown()
        refresh = 0.0
        ratio = 1.0 if recorder is None or recorder.timings is None \
            else recorder.timings.clock_ratio
        for r in result.per_channel:
            refresh += getattr(r, "refresh_dram_cycles", 0) * ratio
        totals = {
            "gating": bd.get("ctrl_overhead", 0.0)
            + bd.get("address_map", 0.0),
            "arbitration": bd.get("port_arbiter", 0.0),
            "cache": bd.get("cache_filter", 0.0),
            "batch": bd.get("batch_scheduler", 0.0)
            + bd.get("dma_overlap", 0.0),
            "reorder": 0.0,
            "refresh": refresh,
            "outage": 0.0,
            "replay": 0.0,
            "service": bd.get("dram_service", 0.0) - refresh,
        }
        z = np.zeros(0, np.float64)
        zi = np.zeros(0, np.int64)
        return cls(components={k: z for k in COMPONENTS}, sojourn=z,
                   pe_id=zi, channel_by_seq=zi, row_by_seq=zi,
                   dropped=np.zeros(0, bool), aggregate_totals=totals)
