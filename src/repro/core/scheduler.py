"""Memory scheduler — batch formation and locality reordering (paper §IV, Fig. 2).

The scheduler accumulates incoming requests into batches (double-buffered
input queues, bounded by ``batch_size`` and ``timeout_cycles``), reorders each
batch by DRAM row index with a stable bitonic sorting network, and emits the
reordered stream. Stability is what implements the paper's consistency rule:
requests to the *same address keep their arrival order* even though requests
to different addresses are reordered. A batch holds a single request type
(reads xor writes), which preserves the weak consistency model.

Two planes:

* **Control plane** (`form_batches`) — host-side trace segmentation with the
  timeout/full/type-change rules; numpy, used by benchmarks and the serving
  scheduler.
* **Data plane** (`reorder_batch` / `sort_requests`) — device-side stable
  key sort; dispatches to the Pallas bitonic kernel on TPU and to
  ``jnp.argsort(..., stable=True)`` elsewhere. The fused
  ``repro.core.controller.mc_gather`` consumes this.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.config import SchedulerConfig
from repro.core.timing import DRAMTimings, DDR4_2400

READ = 0
WRITE = 1


@dataclasses.dataclass
class RequestBatch:
    """Struct-of-arrays FLIT batch (paper's PE->controller interface).

    Fields mirror the FLIT header: originating PE, access type, address,
    payload size; ``seq`` is the arrival stamp (the input-buffer read-pointer
    value in Fig. 2) used to keep the sort stable and to unsort responses.
    """

    pe_id: np.ndarray
    rw: int                      # READ or WRITE — one type per batch
    addr: np.ndarray
    size: np.ndarray
    seq: np.ndarray

    def __len__(self) -> int:
        return int(self.addr.shape[0])


def form_batches(
    addrs: Sequence[int],
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    pe_id: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> Iterator[RequestBatch]:
    """Segment a request trace into scheduler batches.

    A batch closes when (a) it reaches ``config.batch_size`` requests,
    (b) the gap since the batch's first request exceeds
    ``config.timeout_cycles`` (deadlock avoidance under low traffic), or
    (c) the request type flips read<->write (single-type batches).
    """
    addrs = np.asarray(addrs, dtype=np.int64)
    rw_arr = np.asarray(rw, dtype=np.int32)
    n = addrs.shape[0]
    if arrival_cycle is None:
        # Default regime: saturated traffic — many PEs issue in parallel, the
        # input queue never starves, so the timeout never fires (this is the
        # Fig. 9 benchmarking condition). Pass explicit arrival cycles to
        # model low-traffic behaviour.
        arrival_cycle = np.zeros(n, dtype=np.int64)
    else:
        arrival_cycle = np.asarray(arrival_cycle, dtype=np.int64)
    if pe_id is None:
        pe_id = np.zeros(n, dtype=np.int32)
    else:
        pe_id = np.asarray(pe_id, dtype=np.int32)
    if sizes is None:
        sizes = np.full(n, 1, dtype=np.int32)
    else:
        sizes = np.asarray(sizes, dtype=np.int32)

    start = 0
    for i in range(1, n + 1):
        close = False
        if i == n:
            close = True
        else:
            full = (i - start) >= config.batch_size
            timed_out = (arrival_cycle[i] - arrival_cycle[start]
                         ) > config.timeout_cycles
            type_flip = rw_arr[i] != rw_arr[start]
            close = full or timed_out or type_flip
        if close:
            yield RequestBatch(
                pe_id=pe_id[start:i],
                rw=int(rw_arr[start]),
                addr=addrs[start:i],
                size=sizes[start:i],
                seq=np.arange(start, i, dtype=np.int64),
            )
            start = i
            if start == n:
                break


def reorder_batch(
    batch: RequestBatch, timings: DRAMTimings = DDR4_2400
) -> RequestBatch:
    """Stable-sort one batch by DRAM row index (the Bitonic network's job).

    Stable ⇒ equal rows (and in particular equal addresses) keep arrival
    order, satisfying the scheduler consistency rule.
    """
    rows = timings.row_of(batch.addr)
    perm = np.argsort(rows, kind="stable")
    return RequestBatch(
        pe_id=batch.pe_id[perm],
        rw=batch.rw,
        addr=batch.addr[perm],
        size=batch.size[perm],
        seq=batch.seq[perm],
    )


def schedule_trace(
    addrs: Sequence[int],
    rw: Sequence[int],
    *,
    config: SchedulerConfig,
    timings: DRAMTimings = DDR4_2400,
    arrival_cycle: Sequence[int] | None = None,
) -> np.ndarray:
    """Run the full control plane over a trace; return the reordered
    address stream as seen by the DRAM (used by the Fig. 7/9 benchmarks)."""
    if not config.enabled:
        return np.asarray(addrs, dtype=np.int64)
    out = []
    for batch in form_batches(addrs, rw, arrival_cycle, config=config):
        if config.bypass_sequential and _is_sequential(batch.addr, timings):
            out.append(batch.addr)          # bypass path (paper §V-C)
        else:
            out.append(reorder_batch(batch, timings).addr)
    return np.concatenate(out) if out else np.empty(0, dtype=np.int64)


def _is_sequential(addr: np.ndarray, timings: DRAMTimings) -> bool:
    if addr.shape[0] < 2:
        return True
    rows = timings.row_of(addr)
    return bool(np.all(np.diff(rows) >= 0))


# ---------------------------------------------------------------------------
# Data plane — device-side stable sort used inside jitted programs
# ---------------------------------------------------------------------------

def sort_requests(keys: jnp.ndarray, *, use_pallas: bool = False):
    """Return (sorted_keys, perm, inv_perm) with a *stable* sort.

    ``perm`` gathers request payloads into service order; ``inv_perm``
    unsorts responses back to arrival order (the read-pointer writeback in
    Fig. 2). With ``use_pallas`` the Pallas bitonic network kernel runs the
    sort; otherwise XLA's stable sort is used (identical semantics — the
    kernel is validated against this path in tests).
    """
    if use_pallas:
        from repro.kernels.bitonic_sort import ops as bitonic_ops
        sorted_keys, perm = bitonic_ops.sort_with_indices(keys)
    else:
        perm = jnp.argsort(keys, stable=True)
        sorted_keys = jnp.take(keys, perm, axis=0)
    inv_perm = jnp.argsort(perm, stable=True)
    return sorted_keys, perm, inv_perm
