"""Memory scheduler — batch formation and locality reordering (paper §IV, Fig. 2).

The scheduler accumulates incoming requests into batches (double-buffered
input queues, bounded by ``batch_size`` and ``timeout_cycles``), reorders each
batch by DRAM row index with a stable bitonic sorting network, and emits the
reordered stream. Stability is what implements the paper's consistency rule:
requests to the *same address keep their arrival order* even though requests
to different addresses are reordered. A batch holds a single request type
(reads xor writes), which preserves the weak consistency model.

Two planes:

* **Control plane** (`form_batches`) — host-side trace segmentation with the
  timeout/full/type-change rules; numpy, used by benchmarks and the serving
  scheduler.
* **Data plane** (`reorder_batch` / `sort_requests`) — device-side stable
  key sort; dispatches to the Pallas bitonic kernel on TPU and to
  ``jnp.argsort(..., stable=True)`` elsewhere. The fused
  ``repro.core.controller.mc_gather`` consumes this.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.config import SchedulerConfig
from repro.core.timing import DRAMTimings, DDR4_2400

READ = 0
WRITE = 1


@dataclasses.dataclass
class RequestBatch:
    """Struct-of-arrays FLIT batch (paper's PE->controller interface).

    Fields mirror the FLIT header: originating PE, access type, address,
    payload size; ``seq`` is the arrival stamp (the input-buffer read-pointer
    value in Fig. 2) used to keep the sort stable and to unsort responses.
    """

    pe_id: np.ndarray
    rw: int                      # READ or WRITE — one type per batch
    addr: np.ndarray
    size: np.ndarray
    seq: np.ndarray

    def __len__(self) -> int:
        return int(self.addr.shape[0])


def _normalize_trace(addrs, rw, arrival_cycle, pe_id, sizes):
    """Shared input conditioning for both batch formers.

    ``arrival_cycle=None`` means the saturated-traffic regime — many PEs
    issue in parallel, the input queue never starves, so the timeout
    never fires (the Fig. 9 benchmarking condition). Pass explicit
    arrival cycles to model low-traffic behaviour.
    """
    addrs = np.asarray(addrs, dtype=np.int64)
    rw_arr = np.asarray(rw, dtype=np.int32)
    n = addrs.shape[0]
    if arrival_cycle is None:
        arrival_cycle = np.zeros(n, dtype=np.int64)
    else:
        arrival_cycle = np.asarray(arrival_cycle, dtype=np.int64)
    if pe_id is None:
        pe_id = np.zeros(n, dtype=np.int32)
    else:
        pe_id = np.asarray(pe_id, dtype=np.int32)
    if sizes is None:
        sizes = np.full(n, 1, dtype=np.int32)
    else:
        sizes = np.asarray(sizes, dtype=np.int32)
    return addrs, rw_arr, arrival_cycle, pe_id, sizes


def form_batches(
    addrs: Sequence[int],
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    pe_id: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> Iterator[RequestBatch]:
    """Segment a request trace into scheduler batches.

    A batch closes when (a) it reaches ``config.batch_size`` requests,
    (b) the gap since the batch's first request exceeds
    ``config.timeout_cycles`` (deadlock avoidance under low traffic), or
    (c) the request type flips read<->write (single-type batches).
    """
    addrs, rw_arr, arrival_cycle, pe_id, sizes = _normalize_trace(
        addrs, rw, arrival_cycle, pe_id, sizes)
    n = addrs.shape[0]

    start = 0
    for i in range(1, n + 1):
        close = False
        if i == n:
            close = True
        else:
            full = (i - start) >= config.batch_size
            timed_out = (arrival_cycle[i] - arrival_cycle[start]
                         ) > config.timeout_cycles
            type_flip = rw_arr[i] != rw_arr[start]
            close = full or timed_out or type_flip
        if close:
            yield RequestBatch(
                pe_id=pe_id[start:i],
                rw=int(rw_arr[start]),
                addr=addrs[start:i],
                size=sizes[start:i],
                seq=np.arange(start, i, dtype=np.int64),
            )
            start = i
            if start == n:
                break


def form_batches_typed(
    addrs: Sequence[int],
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    pe_id: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> Iterator[RequestBatch]:
    """Dual-queue batch formation: one pending batch per request type.

    The FPGA's double-buffered input queues let reads and writes
    accumulate *concurrently*; a read↔write flip in the arrival stream
    parks the request in the other queue instead of closing the current
    batch. On mixed streams this yields full-size single-type batches —
    the property that amortizes both the sort (Eq. 1) and the bus
    turnaround (tWTR/tRTW) — where the single-queue ``form_batches``
    degenerates to tiny batches.

    Consistency: same-address same-type order is preserved (stable queues);
    a read is *not* ordered against a concurrent write to the same address
    — exactly the paper's weak consistency model. Request streams that
    need read-after-write ordering must fence (close batches) between the
    write and the read.
    """
    addrs, rw_arr, arrival_cycle, pe_id, sizes = _normalize_trace(
        addrs, rw, arrival_cycle, pe_id, sizes)
    n = addrs.shape[0]

    queues: dict[int, list[int]] = {READ: [], WRITE: []}

    def emit(t: int) -> RequestBatch:
        q = queues[t]
        batch = RequestBatch(
            pe_id=pe_id[q], rw=t, addr=addrs[q], size=sizes[q],
            seq=np.asarray(q, dtype=np.int64))
        queues[t] = []
        return batch

    for i in range(n):
        t = int(rw_arr[i])
        for qt in (READ, WRITE):
            q = queues[qt]
            if q and (arrival_cycle[i] - arrival_cycle[q[0]]
                      ) > config.timeout_cycles:
                yield emit(qt)
        queues[t].append(i)
        if len(queues[t]) >= config.batch_size:
            yield emit(t)
    # Flush partials, oldest queue first (FIFO drain at end of trace).
    rest = [t for t in (READ, WRITE) if queues[t]]
    for t in sorted(rest, key=lambda t: queues[t][0]):
        yield emit(t)


def reorder_batch(
    batch: RequestBatch, timings: DRAMTimings = DDR4_2400
) -> RequestBatch:
    """Stable-sort one batch by DRAM row index (the Bitonic network's job).

    Stable ⇒ equal rows (and in particular equal addresses) keep arrival
    order, satisfying the scheduler consistency rule.
    """
    rows = timings.row_of(batch.addr)
    perm = np.argsort(rows, kind="stable")
    return RequestBatch(
        pe_id=batch.pe_id[perm],
        rw=batch.rw,
        addr=batch.addr[perm],
        size=batch.size[perm],
        seq=batch.seq[perm],
    )


def schedule_trace(
    addrs: Sequence[int],
    rw: Sequence[int],
    *,
    config: SchedulerConfig,
    timings: DRAMTimings = DDR4_2400,
    arrival_cycle: Sequence[int] | None = None,
) -> np.ndarray:
    """Run the full control plane over a trace; return the reordered
    address stream as seen by the DRAM (used by the Fig. 7/9 benchmarks)."""
    return schedule_trace_rw(addrs, rw, config=config, timings=timings,
                             arrival_cycle=arrival_cycle)[0]


def schedule_trace_rw(
    addrs: Sequence[int],
    rw: Sequence[int],
    *,
    config: SchedulerConfig,
    timings: DRAMTimings = DDR4_2400,
    arrival_cycle: Sequence[int] | None = None,
    coalesce_writes: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Like :func:`schedule_trace` but also returns the serviced rw stream.

    Uses the dual-queue (typed) batch former, so an interleaved
    read/write stream still yields full-size single-type batches; the
    scheduled stream then changes bus direction at most once per batch
    boundary — feed the pair into
    ``timing.simulate_dram_access(addrs, rw=rw)`` to charge the tWTR/tRTW
    turnarounds the batching amortizes (mixed read/write workloads,
    Fig. 7-write methodology).

    ``coalesce_writes`` additionally models the sorted_scatter kernel's
    VMEM coalescing: within each WRITE batch, adjacent duplicate rows
    collapse to one HBM burst (last-writer-wins / accumulated add).
    Coalescing never crosses a batch boundary — each batch is a separate
    kernel invocation with its own flush.
    """
    if not config.enabled:
        return (np.asarray(addrs, dtype=np.int64),
                np.asarray(rw, dtype=np.int32))
    out, out_rw = [], []
    for batch in form_batches_typed(addrs, rw, arrival_cycle, config=config):
        if config.bypass_sequential and _is_sequential(batch.addr, timings):
            srv = batch.addr                # bypass path (paper §V-C)
        else:
            srv = reorder_batch(batch, timings).addr
        if coalesce_writes and batch.rw == WRITE and srv.shape[0] > 1:
            keep = np.ones(srv.shape[0], dtype=bool)
            keep[1:] = srv[1:] != srv[:-1]
            srv = srv[keep]
        out.append(srv)
        out_rw.append(np.full(srv.shape[0], batch.rw, dtype=np.int32))
    if not out:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int32)
    return np.concatenate(out), np.concatenate(out_rw)


def _is_sequential(addr: np.ndarray, timings: DRAMTimings) -> bool:
    if addr.shape[0] < 2:
        return True
    rows = timings.row_of(addr)
    return bool(np.all(np.diff(rows) >= 0))


# ---------------------------------------------------------------------------
# Data plane — device-side stable sort used inside jitted programs
# ---------------------------------------------------------------------------

def sort_requests(keys: jnp.ndarray, *, use_pallas: bool = False):
    """Return (sorted_keys, perm, inv_perm) with a *stable* sort.

    ``perm`` gathers request payloads into service order; ``inv_perm``
    unsorts responses back to arrival order (the read-pointer writeback in
    Fig. 2). With ``use_pallas`` the Pallas bitonic network kernel runs the
    sort; otherwise XLA's stable sort is used (identical semantics — the
    kernel is validated against this path in tests).
    """
    if use_pallas:
        from repro.kernels.bitonic_sort import ops as bitonic_ops
        sorted_keys, perm = bitonic_ops.sort_with_indices(keys)
    else:
        perm = jnp.argsort(keys, stable=True)
        sorted_keys = jnp.take(keys, perm, axis=0)
    inv_perm = jnp.argsort(perm, stable=True)
    return sorted_keys, perm, inv_perm
