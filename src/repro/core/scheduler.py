"""Memory scheduler — batch formation and locality reordering (paper §IV, Fig. 2).

The scheduler accumulates incoming requests into batches (double-buffered
input queues, bounded by ``batch_size`` and ``timeout_cycles``), reorders each
batch by DRAM row index with a stable bitonic sorting network, and emits the
reordered stream. Stability is what implements the paper's consistency rule:
requests to the *same address keep their arrival order* even though requests
to different addresses are reordered. A batch holds a single request type
(reads xor writes), which preserves the weak consistency model.

Two planes:

* **Control plane** (`form_batches`) — host-side trace segmentation with the
  timeout/full/type-change rules; numpy, used by benchmarks and the serving
  scheduler.
* **Data plane** (`reorder_batch` / `sort_requests`) — device-side stable
  key sort; dispatches to the Pallas bitonic kernel on TPU and to
  ``jnp.argsort(..., stable=True)`` elsewhere. The fused gather consumers
  are ``repro.core.controller.sorted_gather`` and the model-side
  ``repro.models.layers.mc_embed`` / ``mc_scatter`` wrappers.

Both batch formers compute their boundaries *vectorized* (type-change
segmentation + per-batch searchsorted over a restart cummax of the
arrival cycles — one python iteration per emitted batch, not per
request); the generator API is a thin wrapper that slices the planned
boundaries. The original request-at-a-time walks are kept as
``form_batches_seq`` / ``form_batches_typed_seq`` — the oracles the
planners are property-tested against.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.config import SchedulerConfig
from repro.core.timing import DRAMTimings, DDR4_2400

READ = 0
WRITE = 1


@dataclasses.dataclass
class RequestBatch:
    """Struct-of-arrays FLIT batch (paper's PE->controller interface).

    Fields mirror the FLIT header: originating PE, access type, address,
    payload size; ``seq`` is the arrival stamp (the input-buffer read-pointer
    value in Fig. 2) used to keep the sort stable and to unsort responses.
    """

    pe_id: np.ndarray
    rw: int                      # READ or WRITE — one type per batch
    addr: np.ndarray
    size: np.ndarray
    seq: np.ndarray

    def __len__(self) -> int:
        return int(self.addr.shape[0])


def _normalize_trace(addrs, rw, arrival_cycle, pe_id, sizes):
    """Shared input conditioning for both batch formers.

    ``arrival_cycle=None`` means the saturated-traffic regime — many PEs
    issue in parallel, the input queue never starves, so the timeout
    never fires (the Fig. 9 benchmarking condition). Pass explicit
    arrival cycles to model low-traffic behaviour.
    """
    addrs = np.asarray(addrs, dtype=np.int64)
    rw_arr = np.asarray(rw, dtype=np.int32)
    n = addrs.shape[0]
    if arrival_cycle is None:
        arrival_cycle = np.zeros(n, dtype=np.int64)
    else:
        arrival_cycle = np.asarray(arrival_cycle, dtype=np.int64)
    if pe_id is None:
        pe_id = np.zeros(n, dtype=np.int32)
    else:
        pe_id = np.asarray(pe_id, dtype=np.int32)
    if sizes is None:
        sizes = np.full(n, 1, dtype=np.int32)
    else:
        sizes = np.asarray(sizes, dtype=np.int32)
    return addrs, rw_arr, arrival_cycle, pe_id, sizes


def form_batches_seq(
    addrs: Sequence[int],
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    pe_id: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> Iterator[RequestBatch]:
    """Reference implementation of :func:`form_batches` — one python
    iteration per request. Kept as the oracle the vectorized boundary
    planner is property-tested against."""
    addrs, rw_arr, arrival_cycle, pe_id, sizes = _normalize_trace(
        addrs, rw, arrival_cycle, pe_id, sizes)
    n = addrs.shape[0]

    start = 0
    for i in range(1, n + 1):
        close = False
        if i == n:
            close = True
        else:
            full = (i - start) >= config.batch_size
            timed_out = (arrival_cycle[i] - arrival_cycle[start]
                         ) > config.timeout_cycles
            type_flip = rw_arr[i] != rw_arr[start]
            close = full or timed_out or type_flip
        if close:
            yield RequestBatch(
                pe_id=pe_id[start:i],
                rw=int(rw_arr[start]),
                addr=addrs[start:i],
                size=sizes[start:i],
                seq=np.arange(start, i, dtype=np.int64),
            )
            start = i
            if start == n:
                break


def _first_timeout(arrival: np.ndarray, lo: int, hi: int,
                   head_cycle: int, timeout: int) -> int | None:
    """First global step ``i`` in ``(lo, hi]`` whose arrival exceeds
    ``head_cycle + timeout``, or None. Uses a restart running-max so the
    probe is a single searchsorted even on non-monotone arrival streams
    (``arrival[i] > thr`` first holds exactly where ``cummax > thr``)."""
    win = arrival[lo + 1:hi + 1]
    if not win.size:
        return None
    cm = np.maximum.accumulate(win)
    pos = int(np.searchsorted(cm, head_cycle + timeout, side="right"))
    return lo + 1 + pos if pos < win.size else None


def _single_queue_bounds(rw_arr: np.ndarray, arrival: np.ndarray,
                         config: SchedulerConfig) -> list[tuple[int, int]]:
    """Batch boundary plan for the single-queue former.

    Type flips are fixed closing points (every request in a batch shares
    ``rw[start]``, so a flip vs the start is a flip vs the predecessor):
    segment the trace at ``diff(rw) != 0``, then walk each segment one
    *batch* at a time — the close point is the earlier of the size rule
    (``start + batch_size``) and the first timeout inside that span.
    """
    n = rw_arr.shape[0]
    seg_edges = np.concatenate(
        [[0], np.flatnonzero(np.diff(rw_arr) != 0) + 1, [n]])
    # Saturated-traffic regime (constant arrival cycles — the default):
    # gaps are all zero, the timeout can never fire, and boundaries are
    # pure arithmetic.
    timeouts_possible = n > 0 and bool((arrival != arrival[0]).any())
    bounds: list[tuple[int, int]] = []
    for a, b in zip(seg_edges[:-1], seg_edges[1:]):
        s = int(a)
        while s < b:
            e = min(s + config.batch_size, int(b))
            if timeouts_possible:
                t = _first_timeout(arrival, s, e - 1, int(arrival[s]),
                                   config.timeout_cycles)
                if t is not None:
                    e = t
            bounds.append((s, e))
            s = e
    return bounds


def form_batches(
    addrs: Sequence[int],
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    pe_id: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> Iterator[RequestBatch]:
    """Segment a request trace into scheduler batches.

    A batch closes when (a) it reaches ``config.batch_size`` requests,
    (b) the gap since the batch's first request exceeds
    ``config.timeout_cycles`` (deadlock avoidance under low traffic), or
    (c) the request type flips read<->write (single-type batches).

    Boundaries are planned vectorized (one python iteration per *batch*);
    identical output to :func:`form_batches_seq`.
    """
    addrs, rw_arr, arrival_cycle, pe_id, sizes = _normalize_trace(
        addrs, rw, arrival_cycle, pe_id, sizes)
    for s, e in _single_queue_bounds(rw_arr, arrival_cycle, config):
        yield RequestBatch(
            pe_id=pe_id[s:e],
            rw=int(rw_arr[s]),
            addr=addrs[s:e],
            size=sizes[s:e],
            seq=np.arange(s, e, dtype=np.int64),
        )


def form_batches_typed_seq(
    addrs: Sequence[int],
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    pe_id: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> Iterator[RequestBatch]:
    """Reference implementation of :func:`form_batches_typed` — one python
    iteration (and queue append) per request. Kept as the oracle the
    vectorized planner is property-tested against.

    The FPGA's double-buffered input queues let reads and writes
    accumulate *concurrently*; a read↔write flip in the arrival stream
    parks the request in the other queue instead of closing the current
    batch. On mixed streams this yields full-size single-type batches —
    the property that amortizes both the sort (Eq. 1) and the bus
    turnaround (tWTR/tRTW) — where the single-queue ``form_batches``
    degenerates to tiny batches.

    Consistency: same-address same-type order is preserved (stable queues);
    a read is *not* ordered against a concurrent write to the same address
    — exactly the paper's weak consistency model. Request streams that
    need read-after-write ordering must fence (close batches) between the
    write and the read.
    """
    addrs, rw_arr, arrival_cycle, pe_id, sizes = _normalize_trace(
        addrs, rw, arrival_cycle, pe_id, sizes)
    n = addrs.shape[0]

    queues: dict[int, list[int]] = {READ: [], WRITE: []}

    def emit(t: int) -> RequestBatch:
        q = queues[t]
        batch = RequestBatch(
            pe_id=pe_id[q], rw=t, addr=addrs[q], size=sizes[q],
            seq=np.asarray(q, dtype=np.int64))
        queues[t] = []
        return batch

    for i in range(n):
        t = int(rw_arr[i])
        for qt in (READ, WRITE):
            q = queues[qt]
            if q and (arrival_cycle[i] - arrival_cycle[q[0]]
                      ) > config.timeout_cycles:
                yield emit(qt)
        queues[t].append(i)
        if len(queues[t]) >= config.batch_size:
            yield emit(t)
    # Flush partials, oldest queue first (FIFO drain at end of trace).
    rest = [t for t in (READ, WRITE) if queues[t]]
    for t in sorted(rest, key=lambda t: queues[t][0]):
        yield emit(t)


def _typed_batch_plan(rw_arr: np.ndarray, arrival: np.ndarray,
                      config: SchedulerConfig):
    """Emission plan for the dual-queue former, one python iteration per
    *batch*.

    The two queues never interact (each emits based only on its own head
    and the global arrival stream), so each type's batch boundaries are
    walked independently over that type's request positions; emissions
    are then merged by event key ``(global_step, phase, tiebreak)`` —
    a timeout fires *before* the arriving request is appended (phase 0,
    READ queue checked first), a size-full batch emits right after the
    append (phase 1), and end-of-trace flushes drain oldest head first
    (phase 2).
    """
    n = rw_arr.shape[0]
    B, T = config.batch_size, config.timeout_cycles
    timeouts_possible = n > 0 and bool((arrival != arrival[0]).any())
    events: list[tuple[tuple[int, int, int], int, np.ndarray]] = []
    for t_order, t in enumerate((READ, WRITE)):
        idxs = np.flatnonzero(rw_arr == t)
        m = idxs.shape[0]
        p = 0
        while p < m:
            h = int(idxs[p])
            size_p = p + B - 1
            limit = int(idxs[size_p]) if size_p < m else n - 1
            t_out = _first_timeout(arrival, h, limit, int(arrival[h]), T) \
                if timeouts_possible else None
            if t_out is not None:
                q = int(np.searchsorted(idxs, t_out, side="left"))
                events.append(((t_out, 0, t_order), t, idxs[p:q]))
                p = q
            elif size_p < m:
                events.append(((limit, 1, t_order), t, idxs[p:p + B]))
                p = p + B
            else:
                events.append(((n, 2, h), t, idxs[p:]))
                p = m
    events.sort(key=lambda e: e[0])
    return events


def form_batches_typed(
    addrs: Sequence[int],
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    pe_id: Sequence[int] | None = None,
    sizes: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> Iterator[RequestBatch]:
    """Dual-queue batch formation: one pending batch per request type.

    The FPGA's double-buffered input queues let reads and writes
    accumulate *concurrently*; a read↔write flip in the arrival stream
    parks the request in the other queue instead of closing the current
    batch. On mixed streams this yields full-size single-type batches —
    the property that amortizes both the sort (Eq. 1) and the bus
    turnaround (tWTR/tRTW) — where the single-queue ``form_batches``
    degenerates to tiny batches.

    Consistency: same-address same-type order is preserved (stable queues);
    a read is *not* ordered against a concurrent write to the same address
    — exactly the paper's weak consistency model. Request streams that
    need read-after-write ordering must fence (close batches) between the
    write and the read.

    Batch membership is planned vectorized (one python iteration per
    batch, see :func:`_typed_batch_plan`); identical output to
    :func:`form_batches_typed_seq`.
    """
    addrs, rw_arr, arrival_cycle, pe_id, sizes = _normalize_trace(
        addrs, rw, arrival_cycle, pe_id, sizes)
    for _key, t, q in _typed_batch_plan(rw_arr, arrival_cycle, config):
        yield RequestBatch(pe_id=pe_id[q], rw=t, addr=addrs[q],
                           size=sizes[q], seq=q.astype(np.int64))


def count_batches(
    rw: Sequence[int],
    arrival_cycle: Sequence[int] | None = None,
    *,
    config: SchedulerConfig,
) -> int:
    """Number of batches the dual-queue former emits for a trace — the
    per-batch Eq. 1 charge count of the pipeline's overlap model. Uses
    the same boundary plan as :func:`form_batches_typed`, so on a
    saturated all-read trace it reduces to ``ceil(n / batch_size)``."""
    if not config.enabled:
        return 0
    rw_arr = np.asarray(rw, dtype=np.int32).ravel()
    n = rw_arr.shape[0]
    if arrival_cycle is None:
        arrival = np.zeros(n, dtype=np.int64)
    else:
        arrival = np.asarray(arrival_cycle, dtype=np.int64)
    return len(_typed_batch_plan(rw_arr, arrival, config))


def reorder_batch(
    batch: RequestBatch, timings: DRAMTimings = DDR4_2400
) -> RequestBatch:
    """Stable-sort one batch by DRAM row index (the Bitonic network's job).

    Stable ⇒ equal rows (and in particular equal addresses) keep arrival
    order, satisfying the scheduler consistency rule.
    """
    rows = timings.row_of(batch.addr)
    perm = np.argsort(rows, kind="stable")
    return RequestBatch(
        pe_id=batch.pe_id[perm],
        rw=batch.rw,
        addr=batch.addr[perm],
        size=batch.size[perm],
        seq=batch.seq[perm],
    )


def schedule_trace(
    addrs: Sequence[int],
    rw: Sequence[int],
    *,
    config: SchedulerConfig,
    timings: DRAMTimings = DDR4_2400,
    arrival_cycle: Sequence[int] | None = None,
) -> np.ndarray:
    """Run the full control plane over a trace; return the reordered
    address stream as seen by the DRAM (used by the Fig. 7/9 benchmarks)."""
    return schedule_trace_rw(addrs, rw, config=config, timings=timings,
                             arrival_cycle=arrival_cycle)[0]


def schedule_trace_rw_seq(
    addrs: Sequence[int],
    rw: Sequence[int],
    *,
    config: SchedulerConfig,
    timings: DRAMTimings = DDR4_2400,
    arrival_cycle: Sequence[int] | None = None,
    coalesce_writes: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Reference implementation of :func:`schedule_trace_rw` — a python
    loop over batches, one ``argsort`` each. Kept as the seed path for
    old-vs-new benchmarking and as the property-test oracle."""
    if not config.enabled:
        return (np.asarray(addrs, dtype=np.int64),
                np.asarray(rw, dtype=np.int32))
    out, out_rw = [], []
    for batch in form_batches_typed_seq(addrs, rw, arrival_cycle,
                                        config=config):
        if config.bypass_sequential and _is_sequential(batch.addr, timings):
            srv = batch.addr                # bypass path (paper §V-C)
        else:
            srv = reorder_batch(batch, timings).addr
        if coalesce_writes and batch.rw == WRITE and srv.shape[0] > 1:
            keep = np.ones(srv.shape[0], dtype=bool)
            keep[1:] = srv[1:] != srv[:-1]
            srv = srv[keep]
        out.append(srv)
        out_rw.append(np.full(srv.shape[0], batch.rw, dtype=np.int32))
    if not out:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int32)
    return np.concatenate(out), np.concatenate(out_rw)


def schedule_trace_rw(
    addrs: Sequence[int],
    rw: Sequence[int],
    *,
    config: SchedulerConfig,
    timings: DRAMTimings = DDR4_2400,
    arrival_cycle: Sequence[int] | None = None,
    coalesce_writes: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Like :func:`schedule_trace` but also returns the serviced rw stream.

    Uses the dual-queue (typed) batch former, so an interleaved
    read/write stream still yields full-size single-type batches; the
    scheduled stream then changes bus direction at most once per batch
    boundary — feed the pair into
    ``timing.simulate_dram_access(addrs, rw=rw)`` to charge the tWTR/tRTW
    turnarounds the batching amortizes (mixed read/write workloads,
    Fig. 7-write methodology).

    ``coalesce_writes`` additionally models the sorted_scatter kernel's
    VMEM coalescing: within each WRITE batch, adjacent duplicate rows
    collapse to one HBM burst (last-writer-wins / accumulated add).
    Coalescing never crosses a batch boundary — each batch is a separate
    kernel invocation with its own flush.

    The whole data plane is one vectorized pass: a single stable
    ``lexsort`` on ``(batch, row, arrival)`` row-sorts every batch at
    once (a batch that is already row-sorted — the §V-C bypass case — is
    left untouched by a stable sort, so the bypass needs no separate
    branch), and coalescing is one shifted comparison. Output is
    identical to :func:`schedule_trace_rw_seq`.
    """
    if not config.enabled:
        return (np.asarray(addrs, dtype=np.int64),
                np.asarray(rw, dtype=np.int32))
    addrs64, rw_arr, arr_cyc, _, _ = _normalize_trace(
        addrs, rw, arrival_cycle, None, None)
    events = _typed_batch_plan(rw_arr, arr_cyc, config)
    if not events:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int32)
    lens = np.fromiter((e[2].shape[0] for e in events), np.int64,
                       len(events))
    idx_cat = np.concatenate([e[2] for e in events])
    batch_id = np.repeat(np.arange(lens.shape[0]), lens)
    a = addrs64[idx_cat]
    rows = timings.row_of(a)
    # One stable sort on a fused (batch, row) key when the row range is
    # non-negative and fits in int64 (~2x faster than a 2-key lexsort);
    # stability keeps arrival order within equal rows — the
    # weak-consistency rule. Negative rows (negative addresses) fall back
    # to lexsort so batch keys can never overlap.
    row_span = int(rows.max()) + 1 if rows.size else 1
    if rows.size and int(rows.min()) >= 0 \
            and row_span < (1 << 62) // (lens.shape[0] + 1):
        perm = np.argsort(batch_id * row_span + rows, kind="stable")
    else:
        perm = np.lexsort((np.arange(a.shape[0]), rows, batch_id))
    srv = a[perm]
    srv_rw = np.repeat(
        np.fromiter((e[1] for e in events), np.int32, len(events)), lens)
    if coalesce_writes:
        keep = np.ones(srv.shape[0], bool)
        keep[1:] = ((srv[1:] != srv[:-1])
                    | (batch_id[1:] != batch_id[:-1])
                    | (srv_rw[1:] != WRITE))
        srv, srv_rw = srv[keep], srv_rw[keep]
    return srv, srv_rw


def _is_sequential(addr: np.ndarray, timings: DRAMTimings) -> bool:
    if addr.shape[0] < 2:
        return True
    rows = timings.row_of(addr)
    return bool(np.all(np.diff(rows) >= 0))


# ---------------------------------------------------------------------------
# Data plane — device-side stable sort used inside jitted programs
# ---------------------------------------------------------------------------

def sort_requests(keys: jnp.ndarray, *, use_pallas: bool = False):
    """Return (sorted_keys, perm, inv_perm) with a *stable* sort.

    ``perm`` gathers request payloads into service order; ``inv_perm``
    unsorts responses back to arrival order (the read-pointer writeback in
    Fig. 2). With ``use_pallas`` the Pallas bitonic network kernel runs the
    sort; otherwise XLA's stable sort is used (identical semantics — the
    kernel is validated against this path in tests).
    """
    if use_pallas:
        from repro.kernels.bitonic_sort import ops as bitonic_ops
        sorted_keys, perm = bitonic_ops.sort_with_indices(keys)
    else:
        perm = jnp.argsort(keys, stable=True)
        sorted_keys = jnp.take(keys, perm, axis=0)
    inv_perm = jnp.argsort(perm, stable=True)
    return sorted_keys, perm, inv_perm
