"""Deterministic fault-injection primitives for the RAS layer
(ARCHITECTURE.md §10).

Everything the fault model draws — whether an access errors, whether a
detected error exceeds SECDED correction, which rows are weak — is a
pure function of ``(seed, channel, request index, attempt)`` through a
counter-based splitmix64 hash. There is no RNG *stream*: the oracle in
``timing.simulate_faults_seq`` and the fast path in
``trace_engine.simulate_faults_fast`` evaluate the same hash at the
same coordinates and therefore see the *same storm* bit-for-bit, no
matter in which order or how many times each evaluates it. The scalar
(python-int) and vectorized (numpy uint64) implementations below are
the same wrapping 64-bit arithmetic and are property-tested equal.

Row retirement uses a reserved spare-row id space: retiring natural row
``r`` remaps every later access to ``SPARE_ROW_BASE + r`` in the same
bank — a distinct open-row id (so the retirement costs the row buffer
locality the natural row had) that is never weak and never retired
itself. ``SPARE_ROW_BASE`` sits far above any reachable natural row id
(31-40 bit address spaces / row_bytes >= 4096 keep natural rows under
2^50 even after failed-channel remapping).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.config import FaultConfig

__all__ = [
    "SPARE_ROW_BASE", "REMAP_LOCAL_BASE", "FaultStats",
    "error_uniform", "error_uniforms", "error_prob",
    "weak_row", "weak_rows",
]

#: Spare rows used by row retirement live at ``SPARE_ROW_BASE + row``.
SPARE_ROW_BASE = 1 << 60

#: Re-homed traffic from failed channel index ``i`` (position in the
#: sorted failed list) lands at local addresses
#: ``(i+1) * REMAP_LOCAL_BASE + natural_local`` on its surviving
#: channel — a reserved region far above any natural local address
#: (40-bit app address spaces), but whose row ids stay far below
#: ``SPARE_ROW_BASE``.
REMAP_LOCAL_BASE = 1 << 44

_M64 = (1 << 64) - 1
_GOLD = 0x9E3779B97F4A7C15       # splitmix64 increment / seed stride
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB
_G_CH = 0xD1342543DE82EF95       # per-channel stream stride
_G_IDX = 0xAF251AF3B0F025B5      # per-request stride (odd)
_G_ATT = 0x9E6C63D0876A9A61      # per-attempt stride (odd)
_ERR_SALT = 0x6A09E667F3BCC909   # error-draw stream
_WEAK_SALT = 0xBB67AE8584CAA73B  # weak-row-selection stream
_U53 = float(2.0 ** -53)


def _splitmix64_int(x: int) -> int:
    """splitmix64 finalizer on a python int (wrapping 64-bit)."""
    x = (x + _GOLD) & _M64
    x = ((x ^ (x >> 30)) * _MIX1) & _M64
    x = ((x ^ (x >> 27)) * _MIX2) & _M64
    return x ^ (x >> 31)


def _splitmix64_arr(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer on a uint64 array — the same wrapping
    arithmetic as :func:`_splitmix64_int` (numpy uint64 ops wrap)."""
    x = x + np.uint64(_GOLD)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(_MIX1)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(_MIX2)
    return x ^ (x >> np.uint64(31))


def _stream_base(seed: int, channel: int, salt: int) -> int:
    return _splitmix64_int(
        (int(seed) * _GOLD + int(channel) * _G_CH + salt) & _M64)


def error_uniform(faults: FaultConfig, channel: int, idx: int,
                  attempt: int) -> float:
    """The uniform(0,1) deciding the fate of request ``idx``'s issue
    number ``attempt`` (1-based) on ``channel``. Scalar spec."""
    base = _stream_base(faults.seed, int(channel), _ERR_SALT)
    x = (base + int(idx) * _G_IDX + int(attempt) * _G_ATT) & _M64
    return (_splitmix64_int(x) >> 11) * _U53


def error_uniforms(faults: FaultConfig, channel: int, idx: np.ndarray,
                   attempt: int = 1) -> np.ndarray:
    """Vectorized :func:`error_uniform` over a request-index array for
    one fixed attempt number — bit-identical to the scalar spec."""
    base = _stream_base(faults.seed, channel, _ERR_SALT)
    x = (np.uint64(base)
         + np.asarray(idx, np.int64).astype(np.uint64) * np.uint64(_G_IDX)
         + np.uint64((attempt * _G_ATT) & _M64))
    return (_splitmix64_arr(x) >> np.uint64(11)).astype(np.float64) * _U53


def weak_row(faults: FaultConfig, channel: int, row: int) -> bool:
    """Whether natural row ``row`` on ``channel`` is a weak-row hot
    spot. Spare rows (>= ``SPARE_ROW_BASE``) are never weak. Scalar
    spec."""
    if faults.weak_row_fraction <= 0.0 or faults.weak_row_ber <= 0.0:
        return False
    if row >= SPARE_ROW_BASE:
        return False
    base = _stream_base(faults.seed, int(channel), _WEAK_SALT)
    x = (base + int(row) * _G_IDX) & _M64
    u = (_splitmix64_int(x) >> 11) * _U53
    return u < faults.weak_row_fraction


def weak_rows(faults: FaultConfig, channel: int,
              rows: np.ndarray) -> np.ndarray:
    """Vectorized :func:`weak_row` — bit-identical to the scalar
    spec."""
    rows = np.asarray(rows, np.int64)
    if faults.weak_row_fraction <= 0.0 or faults.weak_row_ber <= 0.0:
        return np.zeros(rows.shape, bool)
    base = _stream_base(faults.seed, channel, _WEAK_SALT)
    x = (np.uint64(base)
         + rows.astype(np.uint64) * np.uint64(_G_IDX))
    u = (_splitmix64_arr(x) >> np.uint64(11)).astype(np.float64) * _U53
    return (u < faults.weak_row_fraction) & (rows < SPARE_ROW_BASE)


def error_prob(faults: FaultConfig, weak: bool) -> float:
    """Per-issue error probability — the same float expression on both
    simulator paths (bit-identity)."""
    p = faults.transient_ber + (faults.weak_row_ber if weak else 0.0)
    return p if p < 1.0 else 1.0


@dataclasses.dataclass
class FaultStats:
    """Observability block for one fault-injected run (or an aggregate
    over channels). All cycle counts are DRAM command clocks.

    ``n_injected`` counts raw injected device errors (one per errored
    issue, replays included); each is classified exactly one of
    corrected / uncorrectable (enters replay) / silent. ``n_replays``
    counts re-issues actually performed; ``n_dropped`` requests whose
    last allowed attempt still failed — they complete (with a stamp)
    but are flagged, never silently lost. ``replay_dram_cycles`` is the
    bus time wasted by failed issues, ``correction_dram_cycles`` the
    ECC-pipeline stalls, ``outage_dram_cycles`` time the channel sat in
    a declared outage window with work pending. Degradation events:
    ``rows_retired`` is the ``(channel, row)`` retirement sequence,
    ``spare_issues`` counts issues served from spare rows afterwards,
    ``refresh_escalations`` the number of t_refi halvings triggered.
    ``dropped_by_port`` maps port/tenant id -> dropped requests (the
    per-tenant SLO impact of the storm).
    """

    n_injected: int = 0
    n_corrected: int = 0
    n_uncorrectable: int = 0
    n_silent: int = 0
    n_replays: int = 0
    n_dropped: int = 0
    correction_dram_cycles: int = 0
    replay_dram_cycles: int = 0
    outage_dram_cycles: float = 0.0
    spare_issues: int = 0
    refresh_escalations: int = 0
    rows_retired: tuple = ()
    dropped_by_port: dict = dataclasses.field(default_factory=dict)

    @property
    def degraded(self) -> bool:
        """True when any graceful-degradation policy fired."""
        return bool(self.rows_retired or self.refresh_escalations
                    or self.n_dropped)

    def combine(self, other: "FaultStats") -> "FaultStats":
        """Aggregate two channels' stats (order-preserving on the
        retirement sequence)."""
        merged = dict(self.dropped_by_port)
        for port, cnt in other.dropped_by_port.items():
            merged[port] = merged.get(port, 0) + cnt
        return FaultStats(
            n_injected=self.n_injected + other.n_injected,
            n_corrected=self.n_corrected + other.n_corrected,
            n_uncorrectable=self.n_uncorrectable + other.n_uncorrectable,
            n_silent=self.n_silent + other.n_silent,
            n_replays=self.n_replays + other.n_replays,
            n_dropped=self.n_dropped + other.n_dropped,
            correction_dram_cycles=(self.correction_dram_cycles
                                    + other.correction_dram_cycles),
            replay_dram_cycles=(self.replay_dram_cycles
                                + other.replay_dram_cycles),
            outage_dram_cycles=(self.outage_dram_cycles
                                + other.outage_dram_cycles),
            spare_issues=self.spare_issues + other.spare_issues,
            refresh_escalations=(self.refresh_escalations
                                 + other.refresh_escalations),
            rows_retired=self.rows_retired + other.rows_retired,
            dropped_by_port=merged)

    def as_dict(self) -> dict:
        """JSON-able snapshot (golden records / bench artifacts)."""
        return {
            "n_injected": self.n_injected,
            "n_corrected": self.n_corrected,
            "n_uncorrectable": self.n_uncorrectable,
            "n_silent": self.n_silent,
            "n_replays": self.n_replays,
            "n_dropped": self.n_dropped,
            "correction_dram_cycles": self.correction_dram_cycles,
            "replay_dram_cycles": self.replay_dram_cycles,
            "outage_dram_cycles": round(float(self.outage_dram_cycles), 3),
            "spare_issues": self.spare_issues,
            "refresh_escalations": self.refresh_escalations,
            "rows_retired": [[int(c), int(r)] for c, r in self.rows_retired],
            "dropped_by_port": {str(p): int(c) for p, c
                                in sorted(self.dropped_by_port.items())},
        }
