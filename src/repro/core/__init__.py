"""Core — the paper's programmable memory controller as a JAX module.

Engines (scheduler / cache / DMA) live in sibling modules; the unified
request-routing IP is ``controller.MemoryController``; ``timing`` carries
the DRAM/HBM cost model (Eq. 1-3) and the cycle-level simulator used for
the paper-claim reproductions.
"""

from repro.core.capture import TraceCapture, active_capture
from repro.core.channels import (AddressMap, ArbiterStats, ChannelSimResult,
                                 arbitrate_ports, simulate_channels,
                                 simulate_multiport_channels)
from repro.core.config import (CacheConfig, ChannelConfig, DMAConfig,
                               DRAMSchedConfig, MemoryControllerConfig,
                               PAPER_COMBINED_CONFIG, PAPER_EVAL_CONFIG,
                               SchedulerConfig)
from repro.core.controller import (HotRowCache, MemoryController,
                                   sorted_gather, sorted_scatter)
from repro.core.pipeline import (PipelineContext, PipelineResult,
                                 RequestStream, StageStats, default_stages,
                                 run_pipeline)
from repro.core.timing import (DDR4_2400, DRAMTimings, HBM_V5E,
                               SchedSimResult, roofline_time_s,
                               simulate_dram_access, simulate_dram_sched,
                               simulate_dram_sched_seq, t_schedule,
                               turnaround_cycles)

__all__ = [
    "TraceCapture", "active_capture",
    "CacheConfig", "ChannelConfig", "DMAConfig", "DRAMSchedConfig",
    "MemoryControllerConfig",
    "SchedulerConfig", "PAPER_EVAL_CONFIG", "PAPER_COMBINED_CONFIG",
    "HotRowCache", "MemoryController", "sorted_gather", "sorted_scatter",
    "AddressMap", "ArbiterStats", "ChannelSimResult", "arbitrate_ports",
    "simulate_channels", "simulate_multiport_channels", "PipelineContext",
    "PipelineResult", "RequestStream", "StageStats", "default_stages",
    "run_pipeline", "DDR4_2400", "HBM_V5E", "DRAMTimings",
    "SchedSimResult", "roofline_time_s", "simulate_dram_access",
    "simulate_dram_sched", "simulate_dram_sched_seq", "t_schedule",
    "turnaround_cycles",
]
