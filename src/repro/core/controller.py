"""Unified memory controller — the paper's top-level IP as a JAX module.

``MemoryController`` is the single object models talk to. Like the FPGA IP,
it routes each request class to the right engine:

* single/irregular row requests (embedding rows, KV pages, graph
  adjacency) → **scheduler** (batch → stable sort by row → locality
  gather/scatter → unsort) and optionally the **cache engine**
  (VMEM-resident hot rows, kept write-coherent);
* bulk/streaming requests (weight tiles, activations) → **DMA engine**
  (``bulk_read`` / ``bulk_write``).

Both directions are covered: ``gather``/``cached_gather``/``bulk_read``
on the read side, ``scatter``/``cached_scatter``/``bulk_write`` on the
write side (single-type batches per the paper's weak consistency model).

Every path has identical value semantics to the naive access (``table[idx]``
/ ``table.at[idx].set`` / ``copy``) so engines can be enabled
per-application exactly like the paper's synthesis parameters — disabling
an engine can never change results, only performance. That contract is
property-tested.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capture as capture_mod
from repro.core import channels as channels_mod
from repro.core import dma_engine, pipeline as pipeline_mod
from repro.core import scatter_util, scheduler
from repro.core.config import MemoryControllerConfig
from repro.core.pipeline import PipelineResult, RequestStream
from repro.core.timing import DRAMTimings, DDR4_2400, SimResult


def sorted_gather(
    table: jnp.ndarray, indices: jnp.ndarray, *, use_pallas: bool = False
) -> jnp.ndarray:
    """Scheduler-path gather: reorder requests by row before touching HBM.

    Equivalent to ``table[indices]``; the sort converts a random HBM access
    stream into a quasi-sequential one (row-buffer/burst locality) and lets
    the kernel serve duplicate rows from VMEM. The stable sort preserves
    same-address arrival order (weak consistency rule).
    """
    idx_flat = indices.reshape(-1)
    if use_pallas:
        from repro.kernels.sorted_gather import ops as sg_ops
        out = sg_ops.sorted_gather(table, idx_flat)
    else:
        _, perm, inv_perm = scheduler.sort_requests(idx_flat)
        gathered = jnp.take(table, jnp.take(idx_flat, perm, axis=0), axis=0)
        out = jnp.take(gathered, inv_perm, axis=0)
    return out.reshape(*indices.shape, table.shape[-1])


def sorted_scatter(
    table: jnp.ndarray, indices: jnp.ndarray, values: jnp.ndarray,
    *, mode: str = "set", use_pallas: bool = False,
) -> jnp.ndarray:
    """Scheduler-path scatter: reorder a WRITE batch by row before HBM.

    Value-identical to the in-order write stream: for ``mode="set"`` the
    stable sort keeps same-address arrival order so the last writer wins
    (weak-consistency rule); for ``mode="add"`` each run accumulates in
    promoted (≥f32) precision and rounds to the table dtype once.
    Duplicate rows are coalesced — one HBM burst per distinct row. Thin
    wrapper over the single sort-and-coalesce pipeline in
    ``repro.kernels.sorted_scatter.ops``.
    """
    from repro.kernels.sorted_scatter import ops as ss_ops
    return ss_ops.sorted_scatter(
        table, indices, values, mode=mode,
        backend="pallas" if use_pallas else "xla")


def scatter_set_last(table: jnp.ndarray, idx: jnp.ndarray,
                     vals: jnp.ndarray) -> jnp.ndarray:
    """Deterministic last-writer-wins scatter without sorting.

    XLA's ``table.at[idx].set`` leaves duplicate-index ordering
    implementation-defined, so the unscheduled path cannot rely on it and
    still honor the engine-toggle value-identity contract. Instead the
    winner of each row is found with a commutative reduction (max of
    arrival stamp), and only winners write; losers target a sacrificial
    padding row.
    """
    n = idx.shape[0]
    stamp = jnp.arange(1, n + 1, dtype=jnp.int32)
    winner = jnp.zeros((table.shape[0],), jnp.int32).at[idx].max(stamp)
    is_winner = jnp.take(winner, idx) == stamp
    return scatter_util.masked_row_set(table, idx, vals, is_winner)


@dataclasses.dataclass
class HotRowCache:
    """Cache-engine integration for jitted models: a pinned hot-row set.

    The LRU cache engine (``cache_engine.py``) mutates state per request —
    correct, but sequential. Inside jitted model code we use the static
    variant the FPGA design also supports for re-usable data structures
    (paper §III: "only the re-usable data structures are globally cached"):
    the ``hot_ids`` rows are pinned in fast memory at build time, lookups
    that hit them never touch HBM. Value-identical to ``table[idx]``.
    """

    hot_ids: jnp.ndarray     # (H,) sorted unique row ids
    hot_data: jnp.ndarray    # (H, d) pinned rows (VMEM-resident working set)

    @classmethod
    def build(cls, table: jnp.ndarray, hot_ids) -> "HotRowCache":
        hot_ids = jnp.sort(jnp.asarray(hot_ids, dtype=jnp.int32))
        return cls(hot_ids=hot_ids, hot_data=jnp.take(table, hot_ids, axis=0))

    def gather(self, table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
        idx = indices.reshape(-1)
        # Empty hot set: clipping searchsorted positions to [0, H-1] would
        # wrap to -1 and index from the end — there is nothing to hit, so
        # serve everything from memory. (H is static under jit.)
        if self.hot_ids.shape[0] == 0:
            return jnp.take(table, idx, axis=0).reshape(
                *indices.shape, table.shape[-1])
        pos = jnp.searchsorted(self.hot_ids, idx)
        pos = jnp.clip(pos, 0, self.hot_ids.shape[0] - 1)
        hit = self.hot_ids[pos] == idx
        from_cache = jnp.take(self.hot_data, pos, axis=0)
        from_mem = jnp.take(table, idx, axis=0)
        out = jnp.where(hit[:, None], from_cache, from_mem)
        return out.reshape(*indices.shape, table.shape[-1])

    def hit_mask(self, indices: jnp.ndarray) -> jnp.ndarray:
        idx = indices.reshape(-1)
        if self.hot_ids.shape[0] == 0:      # see gather: all-miss, no wrap
            return jnp.zeros(idx.shape, bool)
        pos = jnp.clip(jnp.searchsorted(self.hot_ids, idx), 0,
                       self.hot_ids.shape[0] - 1)
        return self.hot_ids[pos] == idx

    def repin(self, table: jnp.ndarray) -> "HotRowCache":
        """Refresh the pinned rows from an updated table (the
        write-allocate rule for the static hot set): after any write to
        ``table``, re-pinning keeps subsequent cached gathers coherent."""
        return HotRowCache(hot_ids=self.hot_ids,
                           hot_data=jnp.take(table, self.hot_ids, axis=0))


@dataclasses.dataclass
class MemoryController:
    """The configured controller instance handed to models/pipelines."""

    config: MemoryControllerConfig
    use_pallas: bool = False
    timings: DRAMTimings = dataclasses.field(default_factory=lambda: DDR4_2400)
    # Opt-in trace recorder (ARCHITECTURE §13). When set, the data-plane
    # entry points below report their request batches into it — values
    # are never touched (``capture=None`` is bit-identical, the same
    # contract as ``telemetry.TraceRecorder``). The ``mc_*`` model
    # wrappers use the ambient ``capture.active_capture()`` instead (they
    # only hold a config); this field records *only* to itself so a
    # wrapper delegating to a controller method never double-records.
    capture: "capture_mod.TraceCapture | None" = None

    def _record(self, op: str, table, row_ids, rw: int) -> None:
        if self.capture is None:
            return
        n_rows = int(table.shape[0])
        row_bytes = int(table.shape[-1]) * int(
            jnp.dtype(table.dtype).itemsize)
        self.capture.record(op, f"table:{n_rows}x{row_bytes}", n_rows,
                            row_bytes, row_ids, rw=rw)

    def _record_bulk(self, op: str, dst, nbytes: int, rw: int,
                     offset_bytes: int = 0) -> None:
        if self.capture is None:
            return
        total = int(np.prod(dst.shape)) * int(jnp.dtype(dst.dtype).itemsize)
        rb = capture_mod.DEFAULT_ROW_BYTES
        pages = max(1, -(-total // rb))
        first = int(offset_bytes) // rb
        count = max(1, -(-int(nbytes) // rb))
        self.capture.record_slice(op, f"bulk:{pages}x{rb}", pages, rb,
                                  first, min(count, pages - first), rw=rw)

    # --- cache-line / irregular path ---------------------------------------
    def gather(self, table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
        self._record("gather", table, indices, rw=0)
        if self.config.scheduler.enabled:
            return sorted_gather(table, indices, use_pallas=self.use_pallas)
        return jnp.take(table, indices.reshape(-1), axis=0).reshape(
            *indices.shape, table.shape[-1])

    def cached_gather(
        self, table: jnp.ndarray, indices: jnp.ndarray, cache: HotRowCache
    ) -> jnp.ndarray:
        if self.config.cache.enabled:
            self._record("gather", table, indices, rw=0)
            return cache.gather(table, indices)
        return self.gather(table, indices)

    # --- irregular write path ------------------------------------------------
    def scatter(self, table: jnp.ndarray, indices: jnp.ndarray,
                values: jnp.ndarray, *, mode: str = "set") -> jnp.ndarray:
        """Irregular row writes (embedding-gradient scatter, KV append).

        Value-identical to the in-order write stream whether or not the
        scheduler reorders the batch: ``mode="set"`` resolves duplicate
        rows last-writer-wins; ``mode="add"`` accumulates in promoted
        (≥f32) precision and rounds to the table dtype once — the same
        reference on both paths, so low-precision (bf16) tables don't
        swallow small addends on one path and not the other.
        """
        if mode not in ("set", "add"):
            raise ValueError(f"mode must be 'set' or 'add', got {mode!r}")
        self._record("scatter", table, indices, rw=1)
        if self.config.scheduler.enabled:
            return sorted_scatter(table, indices, values, mode=mode,
                                  use_pallas=self.use_pallas)
        idx = indices.reshape(-1)
        vals = values.reshape(idx.shape[0], table.shape[-1])
        if mode == "add":
            acc = jnp.promote_types(jnp.float32, table.dtype)
            return table.astype(acc).at[idx].add(
                vals.astype(acc)).astype(table.dtype)
        return scatter_set_last(table, idx, vals)

    def cached_scatter(
        self, table: jnp.ndarray, indices: jnp.ndarray,
        values: jnp.ndarray, cache: HotRowCache, *, mode: str = "set",
    ) -> tuple[jnp.ndarray, HotRowCache]:
        """Scatter that keeps a ``HotRowCache`` coherent: the pinned set
        is re-pinned from the updated table (one gather over the hot
        ids). Returns (new_table, new_cache); with the cache engine
        disabled the cache object passes through untouched (and reads
        bypass it, so results are unchanged). The table write itself
        goes through :meth:`scatter`, so the scheduler toggle applies
        independently of the cache toggle."""
        new_table = self.scatter(table, indices, values, mode=mode)
        if self.config.cache.enabled:
            return new_table, cache.repin(new_table)
        return new_table, cache

    # --- bulk path ----------------------------------------------------------
    def bulk_read(self, src: jnp.ndarray) -> jnp.ndarray:
        self._record_bulk(
            "bulk_read", src,
            int(np.prod(src.shape)) * int(jnp.dtype(src.dtype).itemsize),
            rw=0)
        if self.config.dma.enabled:
            return dma_engine.bulk_copy(src, config=self.config.dma,
                                        use_pallas=self.use_pallas)
        return src + 0  # plain copy through the default path

    def bulk_write(self, dst: jnp.ndarray, src: jnp.ndarray,
                   *, offset_elems: int = 0) -> jnp.ndarray:
        """Bulk/streaming write of ``src`` into ``dst`` (weight tiles,
        activation spills, KV page flushes). Value-identical to writing
        the flat region ``[offset, offset+src.size)`` of ``dst``."""
        # Bounds-check on both paths: the default path's
        # dynamic_update_slice would silently clamp, which would make the
        # result depend on the engine toggle.
        if offset_elems < 0 or offset_elems + src.size > dst.size:
            raise ValueError("bulk_write region out of destination bounds")
        item = int(jnp.dtype(dst.dtype).itemsize)
        self._record_bulk("bulk_write", dst, int(src.size) * item, rw=1,
                          offset_bytes=int(offset_elems) * item)
        if self.config.dma.enabled:
            return dma_engine.bulk_write(dst, src, config=self.config.dma,
                                         offset_elems=offset_elems,
                                         use_pallas=self.use_pallas)
        flat = dst.reshape(-1)
        out = jax.lax.dynamic_update_slice(
            flat, src.reshape(-1).astype(dst.dtype), (offset_elems,))
        return out.reshape(dst.shape)

    # --- modeled performance (benchmark substrate) ---------------------------
    # Every modeled number below is produced by the staged pipeline
    # (repro.core.pipeline, ARCHITECTURE §7). ``simulate()`` runs the
    # full composition — arbitration, address mapping, cache filtering,
    # batch scheduling, channel-parallel DRAM service, DMA overlap — and
    # the four ``modeled_*`` entry points are thin wrappers over stage
    # subsets, property-tested bit-identical to their pre-refactor
    # outputs (tests/core/test_pipeline.py).

    def _run(self, stream: RequestStream, *, faults=None, trace=None,
             **stage_kwargs) -> PipelineResult:
        ctx = pipeline_mod.PipelineContext.from_config(self.config,
                                                       self.timings)
        if faults is not None:
            ctx.faults = faults
        ctx.trace = trace
        stages = pipeline_mod.default_stages(ctx, **stage_kwargs)
        return pipeline_mod.run_pipeline(stream, ctx, stages)

    def simulate(
        self, pe_id, row_ids, rw, row_bytes: int,
        *, arbiter_policy: str = "round_robin", weights=None,
        coalesce_writes: bool = False,
        arrival_cycle=None, open_loop: bool | None = None,
        faults=None, trace=None,
    ) -> PipelineResult:
        """Full-pipeline simulation of an irregular row trace — the
        paper's headline composition (cache engine *and* batch scheduler
        *and* multi-channel service together).

        ``pe_id=None`` models a single-port front end (no arbitration);
        otherwise the ``config.num_pes`` per-channel arbiters merge the
        per-PE streams. ``rw=None`` means an all-read trace. Returns a
        :class:`~repro.core.pipeline.PipelineResult` whose per-stage
        breakdown sums to ``makespan_fpga_cycles``; the legacy
        DRAM-only view is ``.as_channel_result()``.

        ``config.dram_sched`` selects each channel interface's DRAM
        *command* scheduler (fifo / frfcfs / frfcfs_cap + refresh,
        ARCHITECTURE §8): the default FIFO window-1 model is
        bit-identical to the pre-PR service stage, pinned by the
        golden-trace suite (``tests/core/test_golden_pipeline.py``).

        ``arrival_cycle`` (per-request FPGA-cycle stamps) switches the
        run to *open-loop serving* (ARCHITECTURE §9): no request is
        granted or issued before it arrives, per-channel idle gaps
        advance the clock, and the result's ``.serving`` reports
        per-request sojourn times with p50/p95/p99 and sustained
        throughput. Serving runs the drop-free stage subset (no cache
        filter, no batch scheduler — both retire the per-request
        identity sojourn accounting needs). With all stamps zero the
        serving datapath is bit-identical to the closed-loop pipeline
        (property-tested); ``open_loop`` forces the mode explicitly.

        ``faults`` overrides ``config.faults`` for this run (RAS layer,
        ARCHITECTURE §10): error injection, ECC/CRC handling, bounded
        replay with backoff, outage windows and graceful degradation —
        the result then carries a ``.fault`` stats block (and, open
        loop, per-request ``.dropped`` flags). ``None`` inherits the
        config; an inactive :class:`~repro.core.config.FaultConfig` is
        bit-identical to no fault layer at all (property-tested).

        ``trace`` (a :class:`~repro.core.telemetry.TraceRecorder`)
        opts into per-request lifecycle tracing (ARCHITECTURE §11):
        every stage emits its events into the recorder — arrivals,
        grants, cache verdicts, batch ids, reorder-window entries,
        per-attempt DRAM issues, replays, completions, plus channel
        timeline events — for the Perfetto exporter
        (``repro.launch.tracing``) and the cycle-attribution report
        (``repro.core.telemetry.CycleAttribution``). ``trace=None``
        leaves every code path bit-identical (property-tested).

        Raises ``ValueError`` on an empty trace — a zero-request
        simulation is almost always an upstream bug (an over-filtered
        trace or a bad selection), so it fails loudly here instead of
        returning an all-zero result that silently poisons derived
        bandwidth/latency numbers. Callers that genuinely want the
        degenerate run can build it from the pipeline primitives
        (``RequestStream.from_rows`` + ``run_pipeline``).
        """
        stream = RequestStream.from_rows(row_ids, rw, row_bytes=row_bytes,
                                         pe_id=pe_id,
                                         arrival_cycle=arrival_cycle)
        if len(stream) == 0:
            raise ValueError(
                "simulate() got an empty trace (0 requests) — refusing "
                "to report an all-zero result; check the upstream trace "
                "generation/filtering (use the pipeline primitives "
                "directly if a degenerate empty run is intended)")
        ports = self.config.num_pes if pe_id is not None else None
        serving = open_loop if open_loop is not None else \
            stream.has_arrivals
        if serving:
            ctx = pipeline_mod.PipelineContext.from_config(self.config,
                                                           self.timings)
            ctx.scheduler = None
            ctx.open_loop = True
            if faults is not None:
                ctx.faults = faults
            ctx.trace = trace
            stages = pipeline_mod.default_stages(
                ctx, ports=ports, arbiter_policy=arbiter_policy,
                weights=weights, cache=False)
            return pipeline_mod.run_pipeline(stream, ctx, stages)
        return self._run(
            stream,
            ports=ports, faults=faults, trace=trace,
            arbiter_policy=arbiter_policy, weights=weights,
            cache=True, coalesce_writes=coalesce_writes)

    def modeled_gather_time(
        self, row_ids: np.ndarray, row_bytes: int
    ) -> SimResult:
        """Modeled DRAM access time for an irregular read-only row trace,
        after the controller's scheduling policy is applied (Fig. 7
        methodology). Pipeline subset: AddressMap → BatchScheduler →
        DRAMService — so a multi-channel config reports the channel
        makespan here too (it used to fall back to single-channel
        numbers); ``num_channels=1`` is bit-identical to the seed
        ``schedule_trace`` + ``simulate_dram_access`` composition."""
        stream = RequestStream.from_rows(row_ids, row_bytes=row_bytes)
        return self._run(stream, cache=False).as_sim_result()

    def modeled_access_time(
        self, row_ids: np.ndarray, rw: np.ndarray, row_bytes: int,
        *, coalesce_writes: bool = False,
    ) -> SimResult:
        """Modeled DRAM time for a mixed read/write row trace: the
        scheduler forms single-type batches and row-sorts each, then the
        stream is costed with open-row state *and* bus-turnaround
        penalties (the Fig. 7 methodology extended to writes).
        ``coalesce_writes`` also models per-batch VMEM write coalescing
        (what the sorted_scatter data plane does; fig7w uses it).

        The trace is first decomposed by the configured
        :class:`~repro.core.channels.AddressMap`; each channel schedules
        and services its share independently, and the returned
        ``total_fpga_cycles`` is the multi-channel *makespan* (slowest
        channel). At ``num_channels=1`` the map is the identity and this
        is exactly the paper's single-interface pipeline (bit-identical:
        ``test_single_channel_matches_plain_simulator``). See
        :meth:`modeled_channel_access_time` for the full per-channel
        breakdown."""
        return self.modeled_channel_access_time(
            row_ids, rw, row_bytes,
            coalesce_writes=coalesce_writes).as_sim_result()

    def modeled_channel_access_time(
        self, row_ids: np.ndarray, rw: np.ndarray, row_bytes: int,
        *, coalesce_writes: bool = False,
    ) -> channels_mod.ChannelSimResult:
        """Multi-channel view of :meth:`modeled_access_time`: the
        configured AddressMap splits the trace, each channel runs its
        own scheduler front end + open-row simulation, and the result
        carries makespan, per-channel occupancy and hit counts.
        Pipeline subset: AddressMap → BatchScheduler → DRAMService."""
        stream = RequestStream.from_rows(row_ids, rw, row_bytes=row_bytes)
        return self._run(
            stream, cache=False,
            coalesce_writes=coalesce_writes).as_channel_result()

    def modeled_multiport_access_time(
        self, pe_id: np.ndarray, row_ids: np.ndarray, rw: np.ndarray,
        row_bytes: int, *, policy: str = "round_robin",
        weights=None, coalesce_writes: bool = False,
    ) -> channels_mod.ChannelSimResult:
        """Modeled completion time when ``config.num_pes`` ports contend
        for the channels: per-PE streams are merged by the per-channel
        arbiters (round_robin / priority / weighted), scheduled, and
        serviced channel-parallel. The result's ``port_stats`` report
        per-port grants, stall slots and Jain fairness. Pipeline subset:
        AddressMap → PortArbiter → BatchScheduler → DRAMService."""
        stream = RequestStream.from_rows(row_ids, rw, row_bytes=row_bytes,
                                         pe_id=pe_id)
        return self._run(
            stream, ports=self.config.num_pes, arbiter_policy=policy,
            weights=weights, cache=False,
            coalesce_writes=coalesce_writes).as_channel_result()
