"""Unified memory controller — the paper's top-level IP as a JAX module.

``MemoryController`` is the single object models talk to. Like the FPGA IP,
it routes each request class to the right engine:

* single/irregular row requests (embedding rows, KV pages, graph
  adjacency) → **scheduler** (batch → stable sort by row → locality gather →
  unsort) and optionally the **cache engine** (VMEM-resident hot rows);
* bulk/streaming requests (weight tiles, activations) → **DMA engine**.

Every path has identical value semantics to the naive access (``table[idx]``
/ ``copy``) so engines can be enabled per-application exactly like the
paper's synthesis parameters — disabling an engine can never change results,
only performance. That contract is property-tested.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import dma_engine, scheduler
from repro.core.config import MemoryControllerConfig
from repro.core.timing import (DRAMTimings, DDR4_2400, SimResult,
                               simulate_dram_access)


def sorted_gather(
    table: jnp.ndarray, indices: jnp.ndarray, *, use_pallas: bool = False
) -> jnp.ndarray:
    """Scheduler-path gather: reorder requests by row before touching HBM.

    Equivalent to ``table[indices]``; the sort converts a random HBM access
    stream into a quasi-sequential one (row-buffer/burst locality) and lets
    the kernel serve duplicate rows from VMEM. The stable sort preserves
    same-address arrival order (weak consistency rule).
    """
    idx_flat = indices.reshape(-1)
    if use_pallas:
        from repro.kernels.sorted_gather import ops as sg_ops
        out = sg_ops.sorted_gather(table, idx_flat)
    else:
        _, perm, inv_perm = scheduler.sort_requests(idx_flat)
        gathered = jnp.take(table, jnp.take(idx_flat, perm, axis=0), axis=0)
        out = jnp.take(gathered, inv_perm, axis=0)
    return out.reshape(*indices.shape, table.shape[-1])


@dataclasses.dataclass
class HotRowCache:
    """Cache-engine integration for jitted models: a pinned hot-row set.

    The LRU cache engine (``cache_engine.py``) mutates state per request —
    correct, but sequential. Inside jitted model code we use the static
    variant the FPGA design also supports for re-usable data structures
    (paper §III: "only the re-usable data structures are globally cached"):
    the ``hot_ids`` rows are pinned in fast memory at build time, lookups
    that hit them never touch HBM. Value-identical to ``table[idx]``.
    """

    hot_ids: jnp.ndarray     # (H,) sorted unique row ids
    hot_data: jnp.ndarray    # (H, d) pinned rows (VMEM-resident working set)

    @classmethod
    def build(cls, table: jnp.ndarray, hot_ids) -> "HotRowCache":
        hot_ids = jnp.sort(jnp.asarray(hot_ids, dtype=jnp.int32))
        return cls(hot_ids=hot_ids, hot_data=jnp.take(table, hot_ids, axis=0))

    def gather(self, table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
        idx = indices.reshape(-1)
        pos = jnp.searchsorted(self.hot_ids, idx)
        pos = jnp.clip(pos, 0, self.hot_ids.shape[0] - 1)
        hit = self.hot_ids[pos] == idx
        from_cache = jnp.take(self.hot_data, pos, axis=0)
        from_mem = jnp.take(table, idx, axis=0)
        out = jnp.where(hit[:, None], from_cache, from_mem)
        return out.reshape(*indices.shape, table.shape[-1])

    def hit_mask(self, indices: jnp.ndarray) -> jnp.ndarray:
        idx = indices.reshape(-1)
        pos = jnp.clip(jnp.searchsorted(self.hot_ids, idx), 0,
                       self.hot_ids.shape[0] - 1)
        return self.hot_ids[pos] == idx


@dataclasses.dataclass
class MemoryController:
    """The configured controller instance handed to models/pipelines."""

    config: MemoryControllerConfig
    use_pallas: bool = False
    timings: DRAMTimings = dataclasses.field(default_factory=lambda: DDR4_2400)

    # --- cache-line / irregular path ---------------------------------------
    def gather(self, table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
        if self.config.scheduler.enabled:
            return sorted_gather(table, indices, use_pallas=self.use_pallas)
        return jnp.take(table, indices.reshape(-1), axis=0).reshape(
            *indices.shape, table.shape[-1])

    def cached_gather(
        self, table: jnp.ndarray, indices: jnp.ndarray, cache: HotRowCache
    ) -> jnp.ndarray:
        if self.config.cache.enabled:
            return cache.gather(table, indices)
        return self.gather(table, indices)

    # --- bulk path ----------------------------------------------------------
    def bulk_read(self, src: jnp.ndarray) -> jnp.ndarray:
        if self.config.dma.enabled:
            return dma_engine.bulk_copy(src, config=self.config.dma,
                                        use_pallas=self.use_pallas)
        return src + 0  # plain copy through the default path

    # --- modeled performance (benchmark substrate) ---------------------------
    def modeled_gather_time(
        self, row_ids: np.ndarray, row_bytes: int
    ) -> SimResult:
        """Modeled DRAM access time for an irregular row trace, after the
        controller's scheduling policy is applied (Fig. 7 methodology)."""
        addrs = np.asarray(row_ids, dtype=np.int64) * row_bytes
        served = scheduler.schedule_trace(
            addrs, np.zeros(addrs.shape[0], np.int32),
            config=self.config.scheduler, timings=self.timings)
        return simulate_dram_access(served, self.timings)
