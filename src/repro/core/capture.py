"""Trace capture: record the request stream a *model* actually emits.

``TraceCapture`` is the controller's observability seam for application
traffic (ARCHITECTURE §13). While ``telemetry.TraceRecorder`` watches the
modeled pipeline from the inside (per-request lifecycle events during a
``simulate()`` run), ``TraceCapture`` watches the *data plane* from the
outside: every controller-routed model operation — embedding gather
(``mc_embed``), embedding-gradient scatter (``mc_scatter``), KV-page
append (``mc_kv_append``), MoE expert dispatch, audio/vision frontend
streaming — reports its ``(pe_id, row_id, rw, bytes, arrival)`` request
batch into the active recorder. The captured trace replays through
``MemoryController.simulate()`` / ``autotune.tune`` as a plain
``RequestStream``, which is what turns the repo's two synthetic
workloads into a per-architecture workload zoo (``data/model_traces.py``).

Contract (same rule the telemetry layer is property-tested under): with
no capture active, every hooked code path is bit-identical to the
unhooked one — recording never changes values, shapes or dtypes, only
observes them. Hooks are *lossy by design* under tracing: a value that
is a JAX tracer (inside ``jit`` / ``scan`` / ``shard_map``) cannot be
read, so the record is skipped and counted in ``n_skipped_traced``;
capture runs are expected to execute the model eagerly (the zoo uses
``scan_layers=False``).

Address space: each traffic class registers a named *region* (an
``n_rows`` × ``row_bytes`` row range). Regions stack, so the embedding
table, KV pages, MoE token buffers and frontend streams occupy disjoint
row ranges of one flat address space — the same flattening an SoC memory
map performs — and reads and writes to the same logical structure (e.g.
``mc_embed`` + ``mc_scatter`` on the embedding table) land on the same
rows. Layers share a region when they share (name, shape): layer-k and
layer-k+1 KV appends to slot *s* hit the same row, modeling page reuse
within a decode step.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

DEFAULT_ROW_BYTES = 4096

# Stack of active recorders (innermost last). Module-level because the
# ``mc_*`` wrappers receive only a ``MemoryControllerConfig`` — there is
# no instance to hang the recorder on at the model call sites.
_ACTIVE: List["TraceCapture"] = []


def active_capture() -> Optional["TraceCapture"]:
    """The innermost active recorder, or None (capture disabled)."""
    return _ACTIVE[-1] if _ACTIVE else None


def is_concrete(x) -> bool:
    """True unless ``x`` is a JAX tracer (no data copy — use to gate
    records whose row ids come from static shapes)."""
    try:
        import jax
        return not isinstance(x, jax.core.Tracer)
    except Exception:
        return True


def concrete(x) -> Optional[np.ndarray]:
    """``np.asarray(x)`` if x is host-readable, else None (JAX tracer)."""
    try:
        return np.asarray(x)
    except Exception:
        return None


@dataclasses.dataclass
class _Region:
    name: str
    base: int
    n_rows: int
    row_bytes: int


class TraceCapture:
    """Append-only recorder of model-emitted memory requests.

    Use as a context manager::

        with TraceCapture() as cap:
            lm.forward(params, batch)          # hooks report into cap
        res = MemoryController(cfg).simulate(*cap.replay_arrays(cfg.num_pes),
                                             capture_rows := ROW_BYTES)

    Requests recorded in one ``record`` call share an *arrival stamp*
    (the op ordinal — a logical clock in program order), the multi-port
    analogue of the serving workloads' same-stamp query bursts.
    """

    def __init__(self) -> None:
        self._regions: Dict[str, _Region] = {}
        self._next_row = 0
        self._pe: List[np.ndarray] = []
        self._row: List[np.ndarray] = []
        self._rw: List[np.ndarray] = []
        self._nbytes: List[np.ndarray] = []
        self._op: List[np.ndarray] = []
        self._arrival: List[np.ndarray] = []
        self.op_labels: List[str] = []
        self._op_index: Dict[str, int] = {}
        self.n_ops = 0                 # record() calls that landed
        self.n_skipped_traced = 0      # record() calls dropped on tracers

    # ---- context management -------------------------------------------------
    def __enter__(self) -> "TraceCapture":
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc) -> None:
        assert _ACTIVE and _ACTIVE[-1] is self, "unbalanced TraceCapture"
        _ACTIVE.pop()

    # ---- address regions ----------------------------------------------------
    def region(self, name: str, n_rows: int, row_bytes: int) -> int:
        """Register (or look up) a named address region; returns its base
        row. Re-registration must agree on the shape — two traffic classes
        may alias a region only by using the same name deliberately."""
        n_rows, row_bytes = int(n_rows), int(row_bytes)
        if n_rows <= 0 or row_bytes <= 0:
            raise ValueError(f"region {name!r}: need n_rows > 0 and "
                             f"row_bytes > 0, got {n_rows}x{row_bytes}")
        reg = self._regions.get(name)
        if reg is not None:
            if (reg.n_rows, reg.row_bytes) != (n_rows, row_bytes):
                raise ValueError(
                    f"region {name!r} re-registered with a different shape: "
                    f"{reg.n_rows}x{reg.row_bytes} vs {n_rows}x{row_bytes}")
            return reg.base
        reg = _Region(name, self._next_row, n_rows, row_bytes)
        self._regions[name] = reg
        self._next_row += n_rows
        return reg.base

    # ---- recording ----------------------------------------------------------
    def record(self, op: str, region_name: str, n_rows: int, row_bytes: int,
               row_ids, *, rw=0, pe_id=0, nbytes=None) -> bool:
        """Report one operation's request batch.

        ``row_ids`` are region-local (hooks never see the global map);
        ``rw``/``pe_id`` broadcast against them. Returns True if the batch
        was recorded, False if any value was a JAX tracer (the call is
        skipped whole — a half-observed op would corrupt the stream — and
        counted in ``n_skipped_traced``)."""
        rows = concrete(row_ids)
        rwv = concrete(rw)
        pev = concrete(pe_id)
        if rows is None or rwv is None or pev is None:
            self.n_skipped_traced += 1
            return False
        rows = rows.astype(np.int64).reshape(-1)
        if rows.size == 0:
            return False
        base = self.region(region_name, n_rows, row_bytes)
        if rows.min() < 0 or rows.max() >= int(n_rows):
            raise ValueError(
                f"op {op!r}: row ids [{rows.min()}, {rows.max()}] outside "
                f"region {region_name!r} (0..{int(n_rows) - 1})")
        n = rows.size
        per_req = int(row_bytes) if nbytes is None else int(nbytes)
        oid = self._op_index.setdefault(op, len(self.op_labels))
        if oid == len(self.op_labels):
            self.op_labels.append(op)
        self._pe.append(np.broadcast_to(
            pev.astype(np.int64).reshape(-1), (n,)).copy())
        self._row.append(rows + base)
        self._rw.append(np.broadcast_to(
            rwv.astype(np.int32).reshape(-1), (n,)).copy())
        self._nbytes.append(np.full(n, per_req, np.int64))
        self._op.append(np.full(n, oid, np.int32))
        self._arrival.append(np.full(n, float(self.n_ops), np.float64))
        self.n_ops += 1
        return True

    def record_slice(self, op: str, region_name: str, n_rows: int,
                     row_bytes: int, start, length: int, *,
                     rw=1, pe_id=0) -> bool:
        """Record a contiguous ``[start, start+length)`` row run — the
        bulk/streaming request class (KV append, DMA tiles)."""
        s = concrete(start)
        if s is None:
            self.n_skipped_traced += 1
            return False
        # clamp exactly like lax.dynamic_update_slice — the record must
        # never fail where the data plane silently succeeds
        first = int(np.asarray(s).reshape(-1)[0])
        first = max(0, min(first, int(n_rows) - int(length)))
        return self.record(op, region_name, n_rows, row_bytes,
                           first + np.arange(int(length), dtype=np.int64),
                           rw=rw, pe_id=pe_id)

    # ---- views --------------------------------------------------------------
    def __len__(self) -> int:
        return int(sum(a.size for a in self._row))

    def _cat(self, chunks: List[np.ndarray], dtype) -> np.ndarray:
        if not chunks:
            return np.zeros(0, dtype)
        return np.concatenate(chunks).astype(dtype)

    def rows(self) -> Dict[str, np.ndarray]:
        """The captured columns as flat arrays (program order)."""
        return {
            "pe_id": self._cat(self._pe, np.int64),
            "row_id": self._cat(self._row, np.int64),
            "rw": self._cat(self._rw, np.int32),
            "nbytes": self._cat(self._nbytes, np.int64),
            "op": self._cat(self._op, np.int32),
            "arrival_cycle": self._cat(self._arrival, np.float64),
        }

    @property
    def n_rows_total(self) -> int:
        """Flat address-space height (rows) across all regions."""
        return self._next_row

    @property
    def n_ports(self) -> int:
        pe = self._cat(self._pe, np.int64)
        return int(pe.max()) + 1 if pe.size else 0

    def op_counts(self) -> Dict[str, int]:
        op = self._cat(self._op, np.int32)
        return {label: int((op == i).sum())
                for i, label in enumerate(self.op_labels)}

    def replay_arrays(self, num_ports: Optional[int] = None):
        """``(pe_id, row_ids, rw)`` for ``MemoryController.simulate``.

        Port ids are folded onto ``num_ports`` arbiter ports (experts and
        sequences map onto the controller's physical PEs round-robin).
        Closed-loop by construction: arrival stamps are *not* returned —
        feeding the logical op clock to ``simulate`` would flip it into
        open-loop serving mode and disable the cache/scheduler stages
        under test. Use ``rows()['arrival_cycle']`` explicitly for
        serving-mode replay."""
        r = self.rows()
        pe = r["pe_id"]
        if num_ports is not None:
            pe = pe % int(num_ports)
        return pe, r["row_id"], r["rw"]

    def as_request_stream(self, row_bytes: int = DEFAULT_ROW_BYTES,
                          num_ports: Optional[int] = None,
                          with_arrivals: bool = False):
        """Validated ``RequestStream`` of the captured trace.

        ``row_bytes`` is the replay granularity: the capture is
        row-indexed (per-request true transfer sizes live in
        ``rows()['nbytes']``), and the pipeline's address map prices every
        row at one fixed stride."""
        from repro.core.pipeline import RequestStream
        r = self.rows()
        pe = r["pe_id"]
        if num_ports is not None:
            pe = pe % int(num_ports)
        return RequestStream.from_rows(
            r["row_id"], r["rw"], row_bytes=row_bytes, pe_id=pe,
            arrival_cycle=r["arrival_cycle"] if with_arrivals else None)

    # ---- on-disk format (tests/goldens/traces/*.json) -----------------------
    def to_dict(self) -> dict:
        r = self.rows()
        return {
            "version": 1,
            "regions": [dataclasses.asdict(reg) for reg in
                        sorted(self._regions.values(), key=lambda g: g.base)],
            "op_labels": list(self.op_labels),
            "n_ops": self.n_ops,
            "pe_id": r["pe_id"].tolist(),
            "row_id": r["row_id"].tolist(),
            "rw": r["rw"].tolist(),
            "nbytes": r["nbytes"].tolist(),
            "op": r["op"].tolist(),
            "arrival_cycle": r["arrival_cycle"].tolist(),
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=None, sort_keys=True,
                      separators=(",", ":"))
            f.write("\n")

    @classmethod
    def from_dict(cls, d: dict) -> "TraceCapture":
        if d.get("version") != 1:
            raise ValueError(f"unknown trace version {d.get('version')!r}")
        cap = cls()
        for reg in d["regions"]:
            base = cap.region(reg["name"], reg["n_rows"], reg["row_bytes"])
            if base != reg["base"]:
                raise ValueError(
                    f"region {reg['name']!r}: stored base {reg['base']} "
                    f"inconsistent with stacking order (got {base})")
        cap.op_labels = list(d["op_labels"])
        cap._op_index = {n: i for i, n in enumerate(cap.op_labels)}
        cap.n_ops = int(d["n_ops"])
        cap._pe = [np.asarray(d["pe_id"], np.int64)]
        cap._row = [np.asarray(d["row_id"], np.int64)]
        cap._rw = [np.asarray(d["rw"], np.int32)]
        cap._nbytes = [np.asarray(d["nbytes"], np.int64)]
        cap._op = [np.asarray(d["op"], np.int32)]
        cap._arrival = [np.asarray(d["arrival_cycle"], np.float64)]
        n = cap._row[0].size
        for k in ("_pe", "_rw", "_nbytes", "_op", "_arrival"):
            if getattr(cap, k)[0].size != n:
                raise ValueError(f"trace column {k[1:]!r} length mismatch")
        if n and cap._row[0].size:
            hi = cap.n_rows_total
            if cap._row[0].min() < 0 or (hi and cap._row[0].max() >= hi):
                raise ValueError("trace row ids outside the region map")
        return cap

    @classmethod
    def load(cls, path: str) -> "TraceCapture":
        with open(path) as f:
            return cls.from_dict(json.load(f))
