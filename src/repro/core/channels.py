"""Multi-port, multi-channel front end — PE arbitration + address mapping
+ channel-parallel DRAM simulation.

The paper's controller is explicitly *multi-port* (several PEs share one
memory interface) and *memory-spec programmable*; HBM-class parts widen
that picture to several independent DRAM channels behind one address
space. This module is the layer between batch formation and the DRAM
model that makes both concrete:

1. **AddressMap** — decomposes a flat physical address into
   ``(channel, bank, row)`` under a configurable interleave policy
   (``ChannelConfig``): row-interleave, block-interleave, or XOR-permuted
   block interleave (the classic fix for power-of-two stride camping).
   The map is a bijection ``addr ↔ (channel, local_addr)``; bank/row are
   then the ordinary ``DRAMTimings`` decode of the *local* address.

2. **Multi-port arbiter** — merges per-``pe_id`` request streams into
   per-channel service queues under round-robin / fixed-priority /
   weighted-round-robin policies. Each port's stream is a FIFO, so
   **per-port arrival order is preserved into every channel queue**
   (the weak-consistency rule the scheduler relies on); per-port
   grant/stall/fairness statistics are reported.

3. **Channel-parallel simulation** — channels are *exactly* independent
   after mapping: a request touches only its own channel's bank/row
   state, and the per-channel rw substream (in arrival order) determines
   that channel's bus turnarounds. The trace therefore partitions by
   channel the same way the cache partitions by set (PR 2's argument),
   so the fast path classifies every channel with the vectorized
   :func:`repro.core.timing.simulate_dram_access` and aggregates
   makespan = max over channels + arbitration fill. The strict
   one-request-at-a-time walk is kept as ``simulate_channels_seq`` — the
   oracle the fast path is property-tested against (bit-identical).

Arbitration and mapping are host-side control plane (numpy), like the
batch formers: they decide *order* and *cost*, never values.

Since the staged-pipeline refactor (``repro.core.pipeline``, ARCHITECTURE
§7) the *fast paths* of the two front-end compositions below delegate to
pipeline stage subsets; the ``use_seq_oracle=True`` compositions keep the
original request-at-a-time code and remain the bit-identity oracles.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.config import ChannelConfig, DRAMSchedConfig, SchedulerConfig
from repro.core.timing import (DRAMTimings, DDR4_2400, SimResult,
                               simulate_dram_access, simulate_dram_sched,
                               simulate_dram_sched_seq)

ARBITER_POLICIES = ("round_robin", "priority", "weighted")


# ---------------------------------------------------------------------------
# 1. Address mapping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AddressMap:
    """Configurable physical-address → (channel, bank, row) decomposition.

    The channel-select field sits at ``granularity`` byte alignment
    (``granularity = row_bytes`` for ``row_interleave``, else
    ``interleave_bytes``). ``local_addr`` removes that field, compacting
    each channel's share into a dense private address space — so per
    channel, the ordinary open-row decode (``DRAMTimings.row_of`` /
    ``bank_of``) applies unchanged, and the map is a bijection
    ``addr ↔ (channel, local_addr)`` for every policy (the XOR policy
    permutes *which* channel a block lands on, never the local image).
    """

    config: ChannelConfig
    timings: DRAMTimings = dataclasses.field(
        default_factory=lambda: DDR4_2400)
    #: RAS config (``faults.failed_channels`` re-maps a failed
    #: channel's traffic onto the survivors — ARCHITECTURE §10).
    faults: "object | None" = None

    @property
    def granularity(self) -> int:
        if self.config.policy == "row_interleave":
            return self.timings.row_bytes
        return self.config.interleave_bytes

    @property
    def failed_channels(self) -> tuple[int, ...]:
        if self.faults is None:
            return ()
        return tuple(sorted(self.faults.failed_channels))

    @property
    def surviving_channels(self) -> tuple[int, ...]:
        dead = set(self.failed_channels)
        return tuple(c for c in range(self.config.num_channels)
                     if c not in dead)

    def _fold(self, block: np.ndarray) -> np.ndarray:
        """XOR-fold every log2(c)-bit digit of ``block`` into one digit.

        Masking once at the end is exact: AND distributes over XOR. The
        fold stops at the widest occupied bit — higher shifts contribute
        zeros (negative blocks sign-extend, so they take all 64)."""
        c = self.config.num_channels
        bits = c.bit_length() - 1
        hi = int(block.max(initial=0))
        max_bits = 64 if int(block.min(initial=0)) < 0 \
            else max(1, hi.bit_length())
        folded = np.zeros_like(block)
        for shift in range(0, max_bits, bits):
            folded ^= block >> shift
        return (folded & (c - 1)).astype(np.int64)

    def _natural_channel(self, addr) -> np.ndarray:
        addr = np.asarray(addr, dtype=np.int64)
        c = self.config.num_channels
        if c == 1:
            return np.zeros_like(addr)
        block = addr // self.granularity
        if self.config.policy == "xor":
            # Permutation-based interleave: XOR-fold *every* log2(c)-bit
            # digit of the block index into the channel select, so any
            # power-of-two stride (however far above the granularity)
            # still touches all channels.
            return self._fold(block)
        return (block % c).astype(np.int64)

    def channel_of(self, addr) -> np.ndarray:
        ch = self._natural_channel(addr)
        failed = self.failed_channels
        if not failed:
            return ch
        # Failed-channel degradation: a dead channel's blocks spread
        # round-robin over the survivors (by natural block index), so
        # the re-homed traffic shares every surviving channel's
        # bandwidth instead of doubling up on one.
        addr = np.asarray(addr, dtype=np.int64)
        block = addr // self.granularity
        surv = np.asarray(self.surviving_channels, np.int64)
        out = ch.copy()
        for f in failed:
            m = ch == f
            if m.any():
                out[m] = surv[block[m] % surv.size]
        return out

    def local_addr(self, addr) -> np.ndarray:
        """Address within the owning channel (channel-select field
        removed). Dense per channel; keeps sub-block offsets. Re-homed
        traffic from a failed channel lands in a reserved region of the
        survivor's space (``REMAP_LOCAL_BASE`` per failed channel) —
        distinct rows from the survivor's native traffic, preserving
        the ``addr ↔ (channel, local_addr)`` bijection."""
        local = self._natural_local(addr)
        failed = self.failed_channels
        if not failed:
            return local
        from repro.core.faults import REMAP_LOCAL_BASE
        ch = self._natural_channel(addr)
        out = local.copy()
        for i, f in enumerate(failed):
            m = ch == f
            if m.any():
                out[m] = (i + 1) * REMAP_LOCAL_BASE + local[m]
        return out

    def _natural_local(self, addr) -> np.ndarray:
        addr = np.asarray(addr, dtype=np.int64)
        c = self.config.num_channels
        if c == 1:
            return addr
        g = self.granularity
        return (addr // g // c) * g + addr % g

    def _natural_global(self, channel, local) -> np.ndarray:
        channel = np.asarray(channel, dtype=np.int64)
        local = np.asarray(local, dtype=np.int64)
        c = self.config.num_channels
        if c == 1:
            return local + np.zeros_like(channel)
        g = self.granularity
        group, offset = local // g, local % g
        if self.config.policy == "xor":
            low = (channel ^ self._fold(group)) & (c - 1)
        else:
            low = channel
        return (group * c + low) * g + offset

    def global_addr(self, channel, local) -> np.ndarray:
        """Inverse of the bijection: recompose ``(channel, local_addr)``
        into the flat physical address. For the XOR policy the low block
        digit is recovered as ``channel XOR fold(group)`` — the fold of
        ``block = group*c + d`` is ``d XOR fold(group)``, so the XOR
        cancels. A re-homed local (>= ``REMAP_LOCAL_BASE``) encodes
        which failed channel it came from, so the natural address is
        recovered from that channel, ignoring the survivor it was
        served on. Used by the pipeline's CacheFilter to give victim
        write-backs a real physical address; round-trip property-tested.
        """
        failed = self.failed_channels
        if not failed:
            return self._natural_global(channel, local)
        from repro.core.faults import REMAP_LOCAL_BASE
        channel = np.asarray(channel, dtype=np.int64)
        local = np.asarray(local, dtype=np.int64)
        remapped = local >= REMAP_LOCAL_BASE
        if not remapped.any():
            return self._natural_global(channel, local)
        fidx = np.clip(local // REMAP_LOCAL_BASE - 1, 0, len(failed) - 1)
        failed_arr = np.asarray(failed, np.int64)
        nat_ch = np.where(remapped, failed_arr[fidx], channel)
        nat_local = np.where(remapped, local % REMAP_LOCAL_BASE, local)
        return self._natural_global(nat_ch, nat_local)

    def decompose(self, addr):
        """``(channel, bank, row)`` of each address."""
        local = self.local_addr(addr)
        return (self.channel_of(addr), self.timings.bank_of(local),
                self.timings.row_of(local))


# ---------------------------------------------------------------------------
# 2. Multi-port arbiter
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ArbiterStats:
    """Per-port service statistics for one arbitrated queue."""

    grants: np.ndarray       # (P,) requests granted to each port
    stall_slots: np.ndarray  # (P,) grant slots a port waited with work
    fairness: float          # Jain index over per-port grant counts

    @staticmethod
    def from_grant_order(ports: np.ndarray, num_ports: int) -> "ArbiterStats":
        """Derive stats from the granted-port sequence (slot order).

        A port *stalls* in every grant slot before its last grant that
        went to a different port — it still had pending requests (FIFO
        queues, saturated arrival) but was not picked.
        """
        ports = np.asarray(ports, dtype=np.int64)
        grants = np.bincount(ports, minlength=num_ports)[:num_ports]
        stalls = np.zeros(num_ports, dtype=np.int64)
        if ports.size:
            slots = np.arange(ports.size, dtype=np.int64)
            last = np.full(num_ports, -1, dtype=np.int64)
            last[ports] = slots          # fancy assignment: last wins
            present = last >= 0
            stalls[present] = last[present] + 1 - grants[present]
        return ArbiterStats(grants=grants, stall_slots=stalls,
                            fairness=_jain(grants))


def _jain(grants: np.ndarray) -> float:
    """Jain fairness index over the ports that received any service
    (1.0 = perfectly even; → 1/n as one port dominates)."""
    n_active = int((grants > 0).sum())
    if n_active == 0:
        return 1.0
    g = grants[grants > 0].astype(np.float64)
    return float(g.sum() ** 2 / (n_active * (g ** 2).sum()))


def _normalize_weights(num_ports: int, policy: str,
                       weights: Sequence[int] | None) -> np.ndarray:
    if policy not in ARBITER_POLICIES:
        raise ValueError(f"arbiter policy {policy!r} must be one of "
                         f"{ARBITER_POLICIES}")
    if policy != "weighted":
        return np.ones(num_ports, dtype=np.int64)
    if weights is None:
        raise ValueError("policy='weighted' requires per-port weights")
    w = np.asarray(weights, dtype=np.int64)
    if w.shape != (num_ports,) or (w < 1).any():
        raise ValueError("weights must be one positive integer per port")
    return w


def arbitrate_ports_seq(
    pe_id: np.ndarray,
    *,
    num_ports: int,
    policy: str = "round_robin",
    weights: Sequence[int] | None = None,
) -> tuple[np.ndarray, ArbiterStats]:
    """Reference arbiter — an explicit grant-per-slot loop over per-port
    FIFOs (saturated arrival: every request is pending from slot 0).
    Kept as the oracle :func:`arbitrate_ports` is property-tested
    against.

    Returns ``(perm, stats)``: ``perm`` lists request indices (into the
    input stream) in grant order; within each port the FIFO pop
    preserves arrival order by construction.
    """
    pe = np.asarray(pe_id, dtype=np.int64)
    if pe.size and (pe.min() < 0 or pe.max() >= num_ports):
        raise ValueError("pe_id outside [0, num_ports)")
    w = _normalize_weights(num_ports, policy, weights)
    queues = [list(np.flatnonzero(pe == p)) for p in range(num_ports)]
    heads = [0] * num_ports
    out: list[int] = []
    granted_port: list[int] = []
    if policy == "priority":
        # Fixed priority = ascending pe_id: the highest-priority port with
        # pending work wins every slot, so lower ports drain first.
        for p in range(num_ports):
            out.extend(queues[p])
            granted_port.extend([p] * len(queues[p]))
    else:
        # (Weighted) round robin with a rotating grant pointer: each full
        # rotation grants every still-busy port up to weight[p] requests,
        # ports in cyclic index order.
        remaining = sum(len(q) for q in queues)
        while remaining:
            for p in range(num_ports):
                q, h = queues[p], heads[p]
                take = min(int(w[p]), len(q) - h)
                for k in range(take):
                    out.append(q[h + k])
                    granted_port.append(p)
                heads[p] += take
                remaining -= take
    perm = np.asarray(out, dtype=np.int64)
    return perm, ArbiterStats.from_grant_order(
        np.asarray(granted_port, dtype=np.int64), num_ports)


def arbitrate_ports(
    pe_id: np.ndarray,
    *,
    num_ports: int,
    policy: str = "round_robin",
    weights: Sequence[int] | None = None,
) -> tuple[np.ndarray, ArbiterStats]:
    """Vectorized arbiter — identical grant order to
    :func:`arbitrate_ports_seq` via one stable sort.

    Key construction: each request's position within its port's FIFO is
    its cumulative count; under (weighted) round robin the request is
    granted in rotation ``pos // weight[p]``, within a rotation ports go
    in index order and a port's ``weight`` grants stay consecutive —
    i.e. stable sort by ``(rotation, port, pos)``. Fixed priority is the
    degenerate key ``(0, port, pos)``.
    """
    pe = np.asarray(pe_id, dtype=np.int64)
    if pe.size and (pe.min() < 0 or pe.max() >= num_ports):
        raise ValueError("pe_id outside [0, num_ports)")
    w = _normalize_weights(num_ports, policy, weights)
    n = pe.shape[0]
    ones = np.ones(n, dtype=np.int64)
    pos = np.zeros(n, dtype=np.int64)
    for p in range(num_ports):          # cumcount per port (P ≤ 128)
        m = pe == p
        pos[m] = np.cumsum(ones[m]) - 1
    rotation = np.zeros(n, dtype=np.int64) if policy == "priority" \
        else pos // w[pe]
    perm = np.lexsort((pos, pe, rotation))
    return perm, ArbiterStats.from_grant_order(pe[perm], num_ports)


def per_port_order_preserved(
    pe_id: np.ndarray,
    addrs: np.ndarray,
    *,
    num_ports: int,
    channel_cfg: ChannelConfig = ChannelConfig(),
    timings: DRAMTimings = DDR4_2400,
    policy: str = "round_robin",
    weights: Sequence[int] | None = None,
) -> bool:
    """Acceptance predicate: after mapping + arbitration, does every
    port's substream enter every channel queue in arrival order? True by
    construction (FIFO pop per port); exported so the property tests and
    the benchmark's machine-readable record check the same thing."""
    pe = np.asarray(pe_id, dtype=np.int64).ravel()
    ch = AddressMap(channel_cfg, timings).channel_of(addrs)
    for k in range(channel_cfg.num_channels):
        sel = np.flatnonzero(ch == k)
        perm, _ = arbitrate_ports(pe[sel], num_ports=num_ports,
                                  policy=policy, weights=weights)
        granted = sel[perm]
        for p in range(num_ports):
            mine = granted[pe[granted] == p]
            if mine.size > 1 and not (np.diff(mine) > 0).all():
                return False
    return True


def arbiter_fill_cycles(num_ports: int) -> int:
    """Grant-path latency of a ``num_ports``-wide arbiter: a binary
    grant/mux tree is ``ceil(log2(P))`` stages deep. The tree is
    pipelined (one grant per cycle per channel once full), so only the
    fill is exposed — charged once per simulation, in FPGA cycles."""
    return int(math.ceil(math.log2(num_ports))) if num_ports > 1 else 0


# ---------------------------------------------------------------------------
# 3. Channel-parallel DRAM simulation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChannelSimResult:
    """Aggregate of per-channel open-row simulations.

    ``makespan_fpga_cycles`` is the wall-clock model: channels service
    their queues concurrently, so the trace completes when the slowest
    channel drains, plus the (pipelined) arbitration fill.
    ``busy_fpga_cycles`` is the summed occupancy (energy/utilization
    view). Counts aggregate over channels.
    """

    makespan_fpga_cycles: float
    busy_fpga_cycles: float
    arbitration_cycles: float
    per_channel: list[SimResult]
    requests_per_channel: list[int]
    port_stats: ArbiterStats | None = None

    @property
    def row_hits(self) -> int:
        return sum(r.row_hits for r in self.per_channel)

    @property
    def row_conflicts(self) -> int:
        return sum(r.row_conflicts for r in self.per_channel)

    @property
    def first_accesses(self) -> int:
        return sum(r.first_accesses for r in self.per_channel)

    @property
    def hit_rate(self) -> float:
        n = self.row_hits + self.row_conflicts + self.first_accesses
        return self.row_hits / max(1, n)

    @property
    def total_fpga_cycles(self) -> float:
        """Alias so a ChannelSimResult reads like a SimResult (the
        modeled completion time of the whole trace)."""
        return self.makespan_fpga_cycles

    def as_sim_result(self) -> SimResult:
        return SimResult(total_fpga_cycles=self.makespan_fpga_cycles,
                         row_hits=self.row_hits,
                         row_conflicts=self.row_conflicts,
                         first_accesses=self.first_accesses)


def _aggregate(per_channel: list[SimResult], counts: list[int],
               arb_cycles: float,
               port_stats: ArbiterStats | None = None) -> ChannelSimResult:
    busy = float(sum(r.total_fpga_cycles for r in per_channel))
    makespan = (max((r.total_fpga_cycles for r in per_channel),
                    default=0.0) + arb_cycles)
    return ChannelSimResult(
        makespan_fpga_cycles=makespan, busy_fpga_cycles=busy,
        arbitration_cycles=arb_cycles, per_channel=per_channel,
        requests_per_channel=counts, port_stats=port_stats)


def simulate_channels_seq(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    channel_cfg: ChannelConfig = ChannelConfig(),
    rw: np.ndarray | None = None,
    dram_sched: DRAMSchedConfig | None = None,
) -> ChannelSimResult:
    """Reference channel simulator — one python iteration per request,
    walking the global trace in arrival order against per-channel
    per-bank open-row state (and per-channel last-direction state for
    the tWTR/tRTW turnarounds). Kept as the oracle
    :func:`simulate_channels` is property-tested against.

    ``dram_sched`` swaps each channel's interface for the out-of-order
    command scheduler oracle
    (:func:`repro.core.timing.simulate_dram_sched_seq`): channels stay
    exactly independent (a reorder window spans only its own channel's
    queue), so the walk decomposes per channel.
    """
    amap = AddressMap(channel_cfg, timings)
    if dram_sched is not None and (dram_sched.effective_window > 1
                                   or dram_sched.t_refi):
        addrs = np.asarray(addrs, dtype=np.int64).ravel()
        ch = amap.channel_of(addrs)
        local = amap.local_addr(addrs)
        rw_arr = None if rw is None else np.asarray(rw, np.int32).ravel()
        per_channel, counts = [], []
        for k in range(channel_cfg.num_channels):
            sel = np.flatnonzero(ch == k)   # stable: keeps arrival order
            per_channel.append(simulate_dram_sched_seq(
                local[sel], timings, dram_sched,
                rw=None if rw_arr is None else rw_arr[sel]))
            counts.append(int(sel.shape[0]))
        return _aggregate(per_channel, counts, 0.0)
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    c = channel_cfg.num_channels
    ch = amap.channel_of(addrs)
    banks = timings.bank_of(amap.local_addr(addrs))
    rows = timings.row_of(amap.local_addr(addrs))
    rw_arr = None if rw is None else np.asarray(rw, np.int32).ravel()

    open_row: list[dict[int, int]] = [dict() for _ in range(c)]
    last_dir = [-1] * c
    n_first = [0] * c
    n_hit = [0] * c
    n_conflict = [0] * c
    n_req = [0] * c
    turn = [0] * c
    for i in range(addrs.shape[0]):
        k, b, r = int(ch[i]), int(banks[i]), int(rows[i])
        n_req[k] += 1
        state = open_row[k]
        if b not in state:
            n_first[k] += 1
        elif state[b] == r:
            n_hit[k] += 1
        else:
            n_conflict[k] += 1
        state[b] = r
        if rw_arr is not None:
            d = int(rw_arr[i])
            if last_dir[k] == 1 and d == 0:
                turn[k] += timings.t_wtr
            elif last_dir[k] == 0 and d == 1:
                turn[k] += timings.t_rtw
            last_dir[k] = d
    per_channel = []
    for k in range(c):
        dram_cycles = (
            n_first[k] * (timings.t_rcd + timings.t_cl)
            + n_hit[k] * timings.t_cl
            + n_conflict[k] * (timings.t_rp + timings.t_rcd + timings.t_cl)
            + n_req[k] * timings.t_burst + turn[k])
        per_channel.append(SimResult(
            total_fpga_cycles=dram_cycles * timings.clock_ratio,
            row_hits=n_hit[k], row_conflicts=n_conflict[k],
            first_accesses=n_first[k]))
    return _aggregate(per_channel, n_req, 0.0)


def simulate_channels(
    addrs: np.ndarray,
    timings: DRAMTimings = DDR4_2400,
    channel_cfg: ChannelConfig = ChannelConfig(),
    rw: np.ndarray | None = None,
    dram_sched: DRAMSchedConfig | None = None,
) -> ChannelSimResult:
    """Channel-parallel open-row simulation — bit-identical to
    :func:`simulate_channels_seq`.

    Channels are exactly independent after mapping (a request touches
    only its channel's bank state; turnarounds depend only on its
    channel's rw substream), so the trace is partitioned by channel —
    arrival order preserved within each channel by a stable selection —
    and every channel runs the vectorized
    :func:`~repro.core.timing.simulate_dram_access` (or, with
    ``dram_sched``, the out-of-order command scheduler
    :func:`~repro.core.timing.simulate_dram_sched`) on its *local*
    addresses.
    """
    amap = AddressMap(channel_cfg, timings)
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    c = channel_cfg.num_channels
    local = amap.local_addr(addrs)
    ch = amap.channel_of(addrs)
    rw_arr = None if rw is None else np.asarray(rw, np.int32).ravel()
    per_channel, counts = [], []
    for k in range(c):
        sel = np.flatnonzero(ch == k)       # stable: keeps arrival order
        sub_rw = None if rw_arr is None else rw_arr[sel]
        if dram_sched is not None:
            per_channel.append(simulate_dram_sched(
                local[sel], timings, dram_sched, rw=sub_rw))
        else:
            per_channel.append(simulate_dram_access(
                local[sel], timings, rw=sub_rw))
        counts.append(int(sel.shape[0]))
    return _aggregate(per_channel, counts, 0.0)


# ---------------------------------------------------------------------------
# Front-end pipelines: mapping (+ arbitration) (+ scheduling) → channels
# ---------------------------------------------------------------------------

def _run_channel(local_ch, rw_ch, *, sched_config, timings,
                 coalesce_writes, use_seq_oracle, dram_sched=None):
    """One channel's back half — optional scheduler front end, then the
    open-row simulation — with ``use_seq_oracle`` swapping every stage
    for its request-at-a-time sibling. Since the fast paths moved into
    ``repro.core.pipeline`` this runs only as the oracle composition
    (``use_seq_oracle=True``) the pipeline is property-tested against;
    the flag is kept so the two compositions stay diffable."""
    from repro.core import scheduler as sched

    if sched_config is not None:
        schedule = (sched.schedule_trace_rw_seq if use_seq_oracle
                    else sched.schedule_trace_rw)
        served, served_rw = schedule(local_ch, rw_ch, config=sched_config,
                                     timings=timings,
                                     coalesce_writes=coalesce_writes)
    else:
        served, served_rw = local_ch, rw_ch
    if use_seq_oracle:
        if dram_sched is not None and (dram_sched.effective_window > 1
                                       or dram_sched.t_refi):
            return simulate_dram_sched_seq(served, timings, dram_sched,
                                           rw=served_rw)
        return simulate_channels_seq(served, timings, ChannelConfig(),
                                     rw=served_rw).per_channel[0]
    if dram_sched is not None:
        return simulate_dram_sched(served, timings, dram_sched,
                                   rw=served_rw)
    return simulate_dram_access(served, timings, rw=served_rw)


def schedule_and_simulate_channels(
    addrs: np.ndarray,
    rw: np.ndarray | None = None,
    *,
    sched_config: SchedulerConfig,
    timings: DRAMTimings = DDR4_2400,
    channel_cfg: ChannelConfig = ChannelConfig(),
    coalesce_writes: bool = False,
    use_seq_oracle: bool = False,
    dram_sched: DRAMSchedConfig | None = None,
) -> ChannelSimResult:
    """Single-port multi-channel pipeline: map → per-channel scheduler
    (each channel owns a batch former + bitonic sorter, exactly like
    each channel owns a DRAM interface) → per-channel open-row
    simulation → makespan aggregate.

    The fast path is the staged pipeline (``repro.core.pipeline``:
    AddressMap → BatchScheduler → DRAMService) viewed through the
    legacy aggregate. ``use_seq_oracle`` keeps the original
    request-at-a-time composition (``schedule_trace_rw_seq`` +
    per-request classification) — the pre-refactor code the pipeline is
    property-tested bit-identical against. ``dram_sched`` gives every
    channel's interface the out-of-order command scheduler (oracle
    sibling on the seq path).
    """
    if not use_seq_oracle:
        from repro.core import pipeline as pipeline_mod
        stream = pipeline_mod.RequestStream.from_addrs(addrs, rw)
        ctx = pipeline_mod.PipelineContext(
            channels=channel_cfg, scheduler=sched_config, cache=None,
            timings=timings, dram_sched=dram_sched)
        return pipeline_mod.run_pipeline(
            stream, ctx, pipeline_mod.default_stages(
                ctx, cache=False, coalesce_writes=coalesce_writes)
        ).as_channel_result()
    amap = AddressMap(channel_cfg, timings)
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    rw_arr = np.zeros(addrs.shape[0], np.int32) if rw is None \
        else np.asarray(rw, np.int32).ravel()
    ch = amap.channel_of(addrs)
    local = amap.local_addr(addrs)
    per_channel, counts = [], []
    for k in range(channel_cfg.num_channels):
        sel = np.flatnonzero(ch == k)
        per_channel.append(_run_channel(
            local[sel], rw_arr[sel], sched_config=sched_config,
            timings=timings, coalesce_writes=coalesce_writes,
            use_seq_oracle=True, dram_sched=dram_sched))
        counts.append(int(sel.shape[0]))
    return _aggregate(per_channel, counts, 0.0)


def simulate_multiport_channels(
    pe_id: np.ndarray,
    addrs: np.ndarray,
    rw: np.ndarray | None = None,
    *,
    num_ports: int,
    policy: str = "round_robin",
    weights: Sequence[int] | None = None,
    timings: DRAMTimings = DDR4_2400,
    channel_cfg: ChannelConfig = ChannelConfig(),
    sched_config: SchedulerConfig | None = None,
    coalesce_writes: bool = False,
    use_seq_oracle: bool = False,
    dram_sched: DRAMSchedConfig | None = None,
) -> ChannelSimResult:
    """Full front end: per-PE streams → per-channel arbiter → optional
    per-channel scheduler → channel-parallel DRAM simulation.

    Each channel owns an arbiter instance that merges the port
    substreams destined for it (per-port FIFOs ⇒ per-port arrival order
    is preserved into every channel queue). The makespan charges the
    slowest channel plus the arbiter fill
    (:func:`arbiter_fill_cycles`). Port statistics aggregate over all
    channel arbiters: grants and stall slots sum, and ``fairness`` is
    the Jain index of the aggregated per-port grant counts.

    The fast path is the staged pipeline (``repro.core.pipeline``:
    AddressMap → PortArbiter → BatchScheduler → DRAMService) viewed
    through the legacy aggregate. ``use_seq_oracle`` keeps the original
    all-sequential composition (``arbitrate_ports_seq`` /
    ``schedule_trace_rw_seq`` / per-request channel walk) — the
    pre-refactor code the pipeline is property-tested bit-identical
    against.
    """
    if not use_seq_oracle:
        from repro.core import pipeline as pipeline_mod
        stream = pipeline_mod.RequestStream.from_addrs(addrs, rw,
                                                       pe_id=pe_id)
        ctx = pipeline_mod.PipelineContext(
            channels=channel_cfg, scheduler=sched_config, cache=None,
            timings=timings, dram_sched=dram_sched)
        return pipeline_mod.run_pipeline(
            stream, ctx, pipeline_mod.default_stages(
                ctx, ports=num_ports, arbiter_policy=policy,
                weights=weights, cache=False,
                coalesce_writes=coalesce_writes)
        ).as_channel_result()
    amap = AddressMap(channel_cfg, timings)
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    pe = np.asarray(pe_id, dtype=np.int64).ravel()
    if pe.shape != addrs.shape:
        raise ValueError("pe_id must have one entry per request")
    rw_arr = np.zeros(addrs.shape[0], np.int32) if rw is None \
        else np.asarray(rw, np.int32).ravel()
    ch = amap.channel_of(addrs)
    local = amap.local_addr(addrs)
    arbitrate = arbitrate_ports_seq

    per_channel, counts = [], []
    grants = np.zeros(num_ports, dtype=np.int64)
    stalls = np.zeros(num_ports, dtype=np.int64)
    for k in range(channel_cfg.num_channels):
        sel = np.flatnonzero(ch == k)
        perm, stats = arbitrate(pe[sel], num_ports=num_ports,
                                policy=policy, weights=weights)
        order = sel[perm]
        grants += stats.grants
        stalls += stats.stall_slots
        per_channel.append(_run_channel(
            local[order], rw_arr[order], sched_config=sched_config,
            timings=timings, coalesce_writes=coalesce_writes,
            use_seq_oracle=use_seq_oracle, dram_sched=dram_sched))
        counts.append(int(sel.shape[0]))
    port_stats = ArbiterStats(grants=grants, stall_slots=stalls,
                              fairness=_jain(grants))
    return _aggregate(per_channel, counts,
                      float(arbiter_fill_cycles(num_ports)),
                      port_stats=port_stats)


# ---------------------------------------------------------------------------
# Open-loop serving composition (arrival-aware front end)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingChannelResult(ChannelSimResult):
    """:class:`ChannelSimResult` plus the per-request latency arrays of
    an open-loop run — completion stamps aligned to the *input* trace
    order (arbiter fill included, like the makespan)."""

    completion_fpga_cycles: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.float64))
    service_fpga_cycles: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.float64))
    arrival_fpga_cycles: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.float64))
    idle_fpga_cycles: float = 0.0
    #: aggregated :class:`repro.core.faults.FaultStats` over channels
    #: (``None`` on fault-free runs).
    fault: "object | None" = None
    #: per-request dropped flags (input trace order; all-False without
    #: faults).
    dropped: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, bool))

    @property
    def sojourn_fpga_cycles(self) -> np.ndarray:
        return self.completion_fpga_cycles - self.arrival_fpga_cycles


def simulate_serving_channels(
    addrs: np.ndarray,
    arrival_fpga: np.ndarray | None = None,
    rw: np.ndarray | None = None,
    *,
    pe_id: np.ndarray | None = None,
    num_ports: int | None = None,
    policy: str = "round_robin",
    weights: Sequence[int] | None = None,
    timings: DRAMTimings = DDR4_2400,
    channel_cfg: ChannelConfig = ChannelConfig(),
    dram_sched: DRAMSchedConfig | None = None,
    use_seq_oracle: bool = False,
    faults=None,
    trace=None,
) -> ServingChannelResult:
    """Arrival-aware front end: map → per-channel coupled
    admission+service (:func:`repro.core.timing.simulate_arrivals`) →
    makespan/latency aggregate.

    Channels stay exactly independent after mapping (each owns its
    arbiter, reorder window and refresh counter), so the open-loop walk
    decomposes per channel like every closed-loop composition above.
    ``use_seq_oracle`` swaps every channel's engine for the
    request-at-a-time spec ``simulate_arrivals_seq`` — the two are
    bit-identical (property-tested), and with all-zero arrivals both
    degenerate to the closed-loop arbiter + scheduler results.

    ``faults`` (a :class:`repro.core.config.FaultConfig`) turns on the
    RAS layer: the address map re-homes failed channels' traffic onto
    survivors, each surviving channel runs the fault-injected service
    (:func:`repro.core.timing.simulate_faults`, keyed by its channel
    index so storms are independent per channel), and the per-channel
    :class:`~repro.core.faults.FaultStats` aggregate into ``fault``.
    ``faults=None`` (or an inactive config) is bit-identical to the
    fault-free walk.

    ``trace`` (a :class:`repro.core.telemetry.TraceRecorder`) opts into
    per-request lifecycle tracing: each channel's engine emits its
    event stream into ``trace.channel(k)``, with the stable selection
    indices as the request ids. ``trace=None`` is the untraced paths,
    bit-identical.
    """
    from repro.core.timing import simulate_arrivals, simulate_faults

    amap = AddressMap(channel_cfg, timings, faults)
    addrs = np.asarray(addrs, dtype=np.int64).ravel()
    n = addrs.shape[0]
    arr = np.zeros(n, np.float64) if arrival_fpga is None \
        else np.asarray(arrival_fpga, np.float64).ravel()
    rw_arr = None if rw is None else np.asarray(rw, np.int32).ravel()
    pe = None if pe_id is None else np.asarray(pe_id, np.int64).ravel()
    ch = amap.channel_of(addrs)
    local = amap.local_addr(addrs)
    engine = "sequential" if use_seq_oracle else "auto"
    multi = num_ports is not None and num_ports > 1

    per_channel, counts = [], []
    completion = np.zeros(n, np.float64)
    service = np.zeros(n, np.float64)
    idle = 0.0
    grants = np.zeros(num_ports or 1, np.int64)
    stalls = np.zeros(num_ports or 1, np.int64)
    fault_agg = None
    dropped = np.zeros(n, bool)
    for k in range(channel_cfg.num_channels):
        sel = np.flatnonzero(ch == k)       # stable: keeps trace order
        sub = dict(
            rw=None if rw_arr is None else rw_arr[sel],
            arrival_fpga=arr[sel],
            pe_id=None if pe is None else pe[sel],
            num_ports=num_ports, arb_policy=policy, weights=weights,
            engine=engine,
            trace=(None if trace is None
                   else trace.channel(k, req_ids=sel)))
        sched_k = dram_sched if dram_sched is not None \
            else DRAMSchedConfig()
        if faults is None:
            res = simulate_arrivals(local[sel], timings, sched_k, **sub)
        else:
            res = simulate_faults(local[sel], timings, sched_k,
                                  faults=faults, channel=k, **sub)
            dropped[sel] = res.dropped if res.dropped.size else False
            fault_agg = res.fault if fault_agg is None \
                else fault_agg.combine(res.fault)
        completion[sel] = res.completion_fpga_cycles
        service[sel] = res.service_dram_cycles * timings.clock_ratio
        idle += res.idle_dram_cycles * timings.clock_ratio
        if multi:
            st = ArbiterStats.from_grant_order(res.granted_port,
                                               num_ports)
            grants += st.grants
            stalls += st.stall_slots
        per_channel.append(res)
        counts.append(int(sel.shape[0]))
    fill = float(arbiter_fill_cycles(num_ports)) if multi else 0.0
    agg = _aggregate(per_channel, counts, fill,
                     port_stats=(ArbiterStats(grants=grants,
                                              stall_slots=stalls,
                                              fairness=_jain(grants))
                                 if multi else None))
    return ServingChannelResult(
        **dataclasses.asdict(agg) | {"per_channel": per_channel,
                                     "port_stats": agg.port_stats},
        completion_fpga_cycles=completion + fill,
        service_fpga_cycles=service,
        arrival_fpga_cycles=arr,
        idle_fpga_cycles=idle,
        fault=fault_agg,
        dropped=dropped)
