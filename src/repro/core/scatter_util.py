"""Masked row-scatter via a sacrificial padding row.

XLA scatter with duplicate or masked-out targets needs care: this helper
routes masked-out slots to a padding row appended to the table, scatters,
and slices the pad off — deterministic as long as the *kept* rows are
unique, which every caller guarantees (last-of-run / winner-stamp dedup,
or distinct (set, tag) pairs). One copy of the idiom, shared by the
controller scatter paths and the cache engine's flush.
"""

from __future__ import annotations

import jax.numpy as jnp


def masked_row_set(table: jnp.ndarray, rows: jnp.ndarray,
                   vals: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Write ``vals[i]`` to ``table[rows[i]]`` where ``keep[i]``; slots
    with ``keep[i] == False`` land on the padding row and are discarded.
    ``rows`` entries where ``keep`` holds must be unique and in range."""
    n_rows = table.shape[0]
    safe = jnp.where(keep, rows, n_rows)
    padded = jnp.concatenate(
        [table, jnp.zeros((1, table.shape[-1]), table.dtype)], axis=0)
    return padded.at[safe].set(vals.astype(table.dtype))[:n_rows]
