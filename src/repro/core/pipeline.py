"""Unified request-stream pipeline — the controller as ONE staged simulator.

The paper's controller is a single datapath: multi-port front end →
internal caching → request scheduler → DRAM interface, with DMA overlap.
This module composes the repo's stage primitives the same way: a
:class:`RequestStream` (pe_id, addr, rw, arrival order, per-request tags)
flows through :class:`Stage` objects —

    AddressMap → PortArbiter → CacheFilter → BatchScheduler
                             → DRAMService → DMAOverlap

— each emitting typed per-stage statistics into one
:class:`PipelineResult` (end-to-end makespan, per-stage cycle breakdown,
per-channel occupancy, cache hit rate, arbiter fairness). This is the
composition the headline Fig. 7 numbers come from: caching *and*
multi-channel scheduling together, not costed by independent oracles.

Stage contract (docs/ARCHITECTURE.md §7):

* a stage may **annotate** (AddressMap adds channel / local_addr),
  **permute** (PortArbiter, BatchScheduler), **drop** (CacheFilter
  removes served hits; the scheduler's write coalescing merges duplicate
  rows) or **insert** (CacheFilter emits victim write-backs) requests —
  it never changes what a request *means*;
* a stage charges only the cycles its hardware exposes
  (``StageStats.cycles``); overlap credits live in one place
  (:class:`DMAOverlapStage`), so the breakdown sums to the makespan;
* channels are independent after mapping, so every stage past the
  AddressMap operates per channel on ``local_addr`` (each channel owns
  an arbiter, a cache bank and a scheduler front end — the same
  partition argument as the set-parallel trace engine).

In the FPGA each PE's FLITs pass its port arbiter *before* the address
decode; in the model the AddressMap is a pure annotation (it reorders
nothing), so it runs first to hand every per-channel arbiter its queue —
the composed datapath is identical, and per-port FIFO order is preserved
into every channel queue either way.

The four legacy ``MemoryController.modeled_*`` entry points are thin
wrappers over stage subsets of this pipeline and are property-tested
bit-identical to their pre-refactor outputs
(``tests/core/test_pipeline.py``); ``autotune.tune`` scores full
pipeline results, so cache geometry × num_channels × mapping policy are
tuned jointly.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import cache_engine
from repro.core import channels as channels_mod
from repro.core import scheduler as scheduler_mod
from repro.core.config import (CacheConfig, ChannelConfig, DRAMSchedConfig,
                               FaultConfig, MemoryControllerConfig,
                               SchedulerConfig)
from repro.core.timing import (DRAMTimings, SimResult,
                               simulate_dram_access, simulate_dram_sched,
                               t_overlapped_schedule)

_INT64_MAX = np.iinfo(np.int64).max


# ---------------------------------------------------------------------------
# The carrier
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestStream:
    """Struct-of-arrays request stream — the single carrier every stage
    consumes and produces.

    ``addr`` is the flat physical byte address, ``rw`` the access type
    (0=read / 1=write), ``pe_id`` the originating port, ``seq`` the
    arrival-order stamp (the FLIT read-pointer; synthetic requests
    inherit the stamp of the request that caused them). ``channel`` /
    ``local_addr`` are AddressMap annotations; ``tags`` holds free-form
    per-request annotations (e.g. ``"writeback"`` marks the synthetic
    victim flushes the CacheFilter inserts).

    ``arrival_cycle`` is the open-loop arrival stamp in FPGA cycles:
    request i enters its port FIFO at that time and may not be granted
    or issued earlier. ``None`` (or all zeros) is the closed-loop
    degenerate case — every request pending from cycle 0 — and the
    pipeline then reproduces the pre-serving results bit-identically
    (property-tested).
    """

    addr: np.ndarray                      # (N,) int64
    rw: np.ndarray                        # (N,) int32
    pe_id: np.ndarray                     # (N,) int64
    seq: np.ndarray                       # (N,) int64
    channel: np.ndarray | None = None     # (N,) int64 — AddressMap
    local_addr: np.ndarray | None = None  # (N,) int64 — AddressMap
    arrival_cycle: np.ndarray | None = None  # (N,) float64 — FPGA cycles
    tags: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return int(self.addr.shape[0])

    @property
    def has_arrivals(self) -> bool:
        """True when some request arrives after cycle 0 (i.e. the
        stream is genuinely open-loop, not the closed-loop degeneracy)."""
        return (self.arrival_cycle is not None
                and bool(self.arrival_cycle.any()))

    def select(self, idx: np.ndarray) -> "RequestStream":
        """Sub-stream / permutation view (fancy-indexes every array)."""
        return RequestStream(
            addr=self.addr[idx], rw=self.rw[idx], pe_id=self.pe_id[idx],
            seq=self.seq[idx],
            channel=None if self.channel is None else self.channel[idx],
            local_addr=(None if self.local_addr is None
                        else self.local_addr[idx]),
            arrival_cycle=(None if self.arrival_cycle is None
                           else self.arrival_cycle[idx]),
            tags={k: v[idx] for k, v in self.tags.items()})

    @classmethod
    def from_rows(
        cls,
        row_ids,
        rw=None,
        *,
        row_bytes: int,
        pe_id=None,
        arrival_cycle=None,
    ) -> "RequestStream":
        """The single validated ingestion point for row-granular traces
        (every ``modeled_*`` entry point and ``simulate()`` build their
        stream here — the ``row_ids * row_bytes`` / dtype-coercion
        boilerplate lives nowhere else).
        """
        if row_bytes <= 0:
            raise ValueError(f"row_bytes={row_bytes} must be positive")
        row_ids = np.asarray(row_ids)
        if row_ids.dtype.kind not in "iu":
            raise TypeError(
                f"row_ids must be an integer array, got {row_ids.dtype}")
        row_ids = row_ids.ravel()
        n = row_ids.shape[0]
        if n and int(row_ids.min()) < 0:
            raise ValueError(
                f"row_ids contain negative ids (min={int(row_ids.min())}); "
                "physical row addresses must be non-negative")
        if n and int(row_ids.max()) > _INT64_MAX // row_bytes:
            raise ValueError(
                f"row id {int(row_ids.max())} * row_bytes {row_bytes} "
                "overflows the int64 address space")
        addr = row_ids.astype(np.int64) * row_bytes
        return cls.from_addrs(addr, rw, pe_id=pe_id,
                              arrival_cycle=arrival_cycle)

    @classmethod
    def from_addrs(cls, addrs, rw=None, *, pe_id=None,
                   arrival_cycle=None) -> "RequestStream":
        """Ingest a byte-address trace (the channels-layer entry)."""
        addr = np.asarray(addrs, dtype=np.int64).ravel()
        n = addr.shape[0]
        if rw is None:
            rw_arr = np.zeros(n, np.int32)
        else:
            rw_arr = np.asarray(rw, dtype=np.int32).ravel()
            if rw_arr.shape[0] != n:
                raise ValueError("rw must have one entry per request")
            if n and not np.isin(rw_arr, (0, 1)).all():
                raise ValueError("rw entries must be 0 (read) or 1 (write)")
        if pe_id is None:
            pe = np.zeros(n, np.int64)
        else:
            pe = np.asarray(pe_id, dtype=np.int64).ravel()
            if pe.shape[0] != n:
                raise ValueError("pe_id must have one entry per request")
        arr = None
        if arrival_cycle is not None:
            arr = np.asarray(arrival_cycle, dtype=np.float64).ravel()
            if arr.shape[0] != n:
                raise ValueError(
                    "arrival_cycle must have one entry per request")
            if n and (not np.isfinite(arr).all() or arr.min() < 0):
                raise ValueError(
                    "arrival_cycle entries must be finite and >= 0")
        return cls(addr=addr, rw=rw_arr, pe_id=pe,
                   seq=np.arange(n, dtype=np.int64), arrival_cycle=arr)


# ---------------------------------------------------------------------------
# Context, stats, result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineContext:
    """Static configuration plus the stage-to-stage blackboard."""

    channels: ChannelConfig
    scheduler: SchedulerConfig | None
    cache: CacheConfig | None
    timings: DRAMTimings
    ctrl_overhead_cycles: float = 0.0
    #: DRAM command scheduler (FR-FCFS + refresh); ``None`` keeps the
    #: strict-FIFO service model of the pre-scheduler pipeline.
    dram_sched: DRAMSchedConfig | None = None
    #: RAS / fault-injection config (``None`` or an inactive config is
    #: the perfectly-reliable device — bit-identical degeneracy).
    faults: "FaultConfig | None" = None
    #: Open-loop serving mode: ``None`` auto-enables when the stream
    #: carries non-zero arrival stamps; ``True`` forces the serving
    #: datapath even for all-zero arrivals (the degeneracy harness);
    #: ``False`` forces the closed-loop pipeline, ignoring stamps.
    open_loop: bool | None = None
    # blackboard (written by stages, read by later stages / the runner):
    requests_per_channel: list[int] | None = None   # AddressMap
    sched_batches: int = 0                          # BatchScheduler
    dram_makespan: float = 0.0                      # DRAMService
    # serving-mode blackboard (PortArbiter defers to DRAMService, which
    # runs the coupled admission+service model and reports back):
    arb_ports: int | None = None                    # PortArbiter
    arb_policy: str = "round_robin"                 # PortArbiter
    arb_weights: Sequence[int] | None = None        # PortArbiter
    serving_completion: np.ndarray | None = None    # DRAMService, by seq
    serving_service: np.ndarray | None = None       # DRAMService, by seq
    serving_arrival: np.ndarray | None = None       # DRAMService, by seq
    serving_pe: np.ndarray | None = None            # DRAMService, by seq
    serving_idle: float = 0.0                       # DRAMService
    serving_port_stats: "channels_mod.ArbiterStats | None" = None
    serving_dropped: np.ndarray | None = None       # DRAMService, by seq
    fault_stats: "object | None" = None             # DRAMService
    #: opt-in per-request lifecycle recorder
    #: (:class:`repro.core.telemetry.TraceRecorder`); ``None`` keeps
    #: every stage on its unchanged hot path (bit-identical results,
    #: property-tested). Duck-typed — the pipeline never imports
    #: telemetry unless a recorder is attached.
    trace: "object | None" = None

    @classmethod
    def from_config(cls, config: MemoryControllerConfig,
                    timings: DRAMTimings) -> "PipelineContext":
        return cls(channels=config.channels, scheduler=config.scheduler,
                   cache=config.cache, timings=timings,
                   ctrl_overhead_cycles=float(config.ctrl_overhead_cycles),
                   dram_sched=config.dram_sched, faults=config.faults)

    @property
    def num_channels(self) -> int:
        return self.channels.num_channels

    @property
    def fault_active(self) -> bool:
        """True when the RAS layer changes anything at all this run."""
        return self.faults is not None and self.faults.active

    def address_map(self) -> channels_mod.AddressMap:
        return channels_mod.AddressMap(self.channels, self.timings,
                                       self.faults)


@dataclasses.dataclass
class StageStats:
    """One stage's contribution to the pipeline breakdown."""

    name: str
    cycles: float          # exposed cycles this stage charges
    in_requests: int
    out_requests: int
    info: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ServingStats:
    """Per-request latency view of an open-loop run.

    All times are FPGA cycles in the *pipeline* time base: a request's
    completion includes every exposed pre-DRAM cycle (controller
    overhead, arbiter fill), so ``sojourn = completion - arrival`` is
    the full modeled residence time and ``makespan >= arrival + sojourn``
    holds for every request. ``service`` is the request's own DRAM
    issue cost (activation/CAS/precharge + burst + any turnaround it
    triggered); ``queueing = sojourn - service`` is everything it spent
    waiting — arrival gating, arbitration, reorder, refresh, and the
    shared fixed overheads.
    """

    arrival_fpga_cycles: np.ndarray      # (N,) request arrival stamps
    completion_fpga_cycles: np.ndarray   # (N,) modeled finish times
    service_fpga_cycles: np.ndarray      # (N,) own DRAM issue cost
    pe_id: np.ndarray                    # (N,) originating port
    p50_sojourn: float
    p95_sojourn: float
    p99_sojourn: float
    mean_sojourn: float
    worst_sojourn: float
    sustained_req_per_cycle: float       # N / makespan
    offered_req_per_cycle: float         # N / last arrival (0.0 for the
    #                                      closed-loop degeneracy)
    idle_fpga_cycles: float              # summed channel idle time
    per_port: dict = dataclasses.field(default_factory=dict)

    @property
    def sojourn_fpga_cycles(self) -> np.ndarray:
        return self.completion_fpga_cycles - self.arrival_fpga_cycles

    @property
    def queueing_fpga_cycles(self) -> np.ndarray:
        return self.sojourn_fpga_cycles - self.service_fpga_cycles

    @staticmethod
    def _percentiles(sojourn: np.ndarray) -> dict:
        if sojourn.size == 0:
            return dict(p50_sojourn=0.0, p95_sojourn=0.0, p99_sojourn=0.0,
                        mean_sojourn=0.0, worst_sojourn=0.0)
        return dict(
            p50_sojourn=float(np.percentile(sojourn, 50)),
            p95_sojourn=float(np.percentile(sojourn, 95)),
            p99_sojourn=float(np.percentile(sojourn, 99)),
            mean_sojourn=float(sojourn.mean()),
            worst_sojourn=float(sojourn.max()))

    @classmethod
    def from_arrays(cls, arrival, completion, service, pe_id,
                    makespan: float, idle: float,
                    open_loop: bool = True) -> "ServingStats":
        sojourn = completion - arrival
        per_port = {}
        for p in np.unique(pe_id):
            m = pe_id == p
            per_port[int(p)] = dict(
                n=int(m.sum()), **cls._percentiles(sojourn[m]))
        n = arrival.shape[0]
        last = float(arrival.max()) if n else 0.0
        # The offered-load guard keys on open-loop-ness, not on ``last``:
        # a nonempty closed-loop trace (all arrivals 0, e.g. the forced
        # open_loop=True degeneracy harness) offers no arrival process
        # at all — report 0.0, not n/0 = inf.
        return cls(
            arrival_fpga_cycles=arrival,
            completion_fpga_cycles=completion,
            service_fpga_cycles=service, pe_id=pe_id,
            sustained_req_per_cycle=n / makespan if makespan else 0.0,
            offered_req_per_cycle=(n / last if (open_loop and last)
                                   else 0.0),
            idle_fpga_cycles=idle, per_port=per_port,
            **cls._percentiles(sojourn))


@dataclasses.dataclass
class PipelineResult:
    """End-to-end result of one pipeline run.

    ``makespan_fpga_cycles`` is the full modeled completion time:
    controller overhead + every stage's exposed cycles (the breakdown in
    ``stages`` sums to it exactly). ``as_channel_result()`` /
    ``as_sim_result()`` are the *legacy views* — DRAM service +
    arbitration only, which is precisely what the pre-pipeline
    ``modeled_*`` entry points reported (and still do, bit-identically).
    """

    makespan_fpga_cycles: float
    stages: list[StageStats]
    per_channel: list[SimResult]
    requests_per_channel: list[int]
    dram_makespan_fpga_cycles: float
    arbitration_cycles: float
    n_requests: int
    cache_hit_rate: float | None = None
    port_stats: channels_mod.ArbiterStats | None = None
    #: per-request sojourn statistics — populated only by open-loop runs
    serving: ServingStats | None = None
    #: RAS observability — populated only when a fault config is active
    #: (``repro.core.faults.FaultStats`` aggregated over channels)
    fault: "object | None" = None
    #: per-request dropped flags indexed by ``seq`` — open-loop runs
    #: under an active fault config only (``None`` otherwise)
    dropped: np.ndarray | None = None

    def stage(self, name: str) -> StageStats | None:
        for s in self.stages:
            if s.name == name:
                return s
        return None

    def breakdown(self) -> dict[str, float]:
        """Cycle breakdown keyed by stage name (plus ctrl overhead) —
        sums to ``makespan_fpga_cycles``."""
        out = {"ctrl_overhead": (self.makespan_fpga_cycles
                                 - sum(s.cycles for s in self.stages))}
        for s in self.stages:
            out[s.name] = s.cycles
        return out

    def as_channel_result(self) -> channels_mod.ChannelSimResult:
        return channels_mod._aggregate(
            self.per_channel, self.requests_per_channel,
            self.arbitration_cycles, port_stats=self.port_stats)

    def as_sim_result(self) -> SimResult:
        return self.as_channel_result().as_sim_result()


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

def _open_loop_active(stream: RequestStream, ctx: PipelineContext) -> bool:
    """Resolve the serving-mode switch for this run (shared by the
    arbiter and DRAM-service stages so they can never disagree)."""
    if ctx.open_loop is not None:
        return bool(ctx.open_loop)
    return stream.has_arrivals


def _per_channel(stream: RequestStream, num_channels: int):
    """Stable per-channel selections (arrival order preserved within
    each channel — the invariant every stage relies on)."""
    if stream.channel is None:
        raise ValueError("stream has no channel annotation — the "
                         "AddressMap stage must run first")
    for k in range(num_channels):
        yield k, np.flatnonzero(stream.channel == k)


@dataclasses.dataclass
class AddressMapStage:
    """Pure annotation: decompose every address into (channel,
    local_addr) under the configured interleave policy. Reorders and
    drops nothing; records per-channel request counts (the occupancy
    denominator every later stage and the legacy results report)."""

    name: str = dataclasses.field(default="address_map", init=False)

    def run(self, stream: RequestStream, ctx: PipelineContext):
        amap = ctx.address_map()
        ch = amap.channel_of(stream.addr)
        local = amap.local_addr(stream.addr)
        counts = np.bincount(ch, minlength=ctx.num_channels) if len(stream) \
            else np.zeros(ctx.num_channels, np.int64)
        ctx.requests_per_channel = [int(c) for c in counts]
        out = dataclasses.replace(stream, channel=ch, local_addr=local)
        return out, StageStats(
            self.name, 0.0, len(stream), len(stream),
            {"policy": ctx.channels.policy,
             "num_channels": ctx.num_channels,
             "requests_per_channel": ctx.requests_per_channel})


@dataclasses.dataclass
class PortArbiterStage:
    """Per-channel multi-port arbitration: each channel's arbiter merges
    the per-``pe_id`` FIFO substreams destined for it (round_robin /
    priority / weighted). Charges the pipelined grant-tree fill once;
    reports aggregated per-port grants, stalls and Jain fairness."""

    num_ports: int
    policy: str = "round_robin"
    weights: Sequence[int] | None = None
    name: str = dataclasses.field(default="port_arbiter", init=False)

    def run(self, stream: RequestStream, ctx: PipelineContext):
        if _open_loop_active(stream, ctx):
            # Open loop: grant timing is coupled to service timing (a
            # port's head can only be granted once it has *arrived*, and
            # grants proceed at the DRAM's issue pace), so arbitration
            # cannot be a standalone permutation — the stage annotates
            # the context and defers the coupled admission loop to
            # DRAMService. The grant-tree fill is charged here as ever.
            channels_mod._normalize_weights(self.num_ports, self.policy,
                                            self.weights)   # validate now
            pe = stream.pe_id
            if len(stream) and (int(pe.min()) < 0
                                or int(pe.max()) >= self.num_ports):
                raise ValueError("pe_id outside [0, num_ports)")
            ctx.arb_ports = self.num_ports
            ctx.arb_policy = self.policy
            ctx.arb_weights = self.weights
            fill = float(channels_mod.arbiter_fill_cycles(self.num_ports))
            return stream, StageStats(
                self.name, fill, len(stream), len(stream),
                {"port_stats": None, "policy": self.policy,
                 "deferred_to": "dram_service"})
        order_parts = []
        grants = np.zeros(self.num_ports, np.int64)
        stalls = np.zeros(self.num_ports, np.int64)
        for _k, sel in _per_channel(stream, ctx.num_channels):
            perm, stats = channels_mod.arbitrate_ports(
                stream.pe_id[sel], num_ports=self.num_ports,
                policy=self.policy, weights=self.weights)
            order_parts.append(sel[perm])
            grants += stats.grants
            stalls += stats.stall_slots
            if ctx.trace is not None:
                seqs = stream.seq[sel][perm].tolist()
                pes = stream.pe_id[sel][perm].tolist()
                ctx.trace.stage_events.extend(
                    ("grant_slot", _k, slot, s, p)
                    for slot, (s, p) in enumerate(zip(seqs, pes)))
        order = (np.concatenate(order_parts) if order_parts
                 else np.empty(0, np.int64))
        port_stats = channels_mod.ArbiterStats(
            grants=grants, stall_slots=stalls,
            fairness=channels_mod._jain(grants))
        fill = float(channels_mod.arbiter_fill_cycles(self.num_ports))
        return stream.select(order), StageStats(
            self.name, fill, len(stream), len(stream),
            {"port_stats": port_stats, "policy": self.policy})


@dataclasses.dataclass
class CacheFilterStage:
    """Cache engine as a stream filter: hits are served at cache latency
    (one beat each) and *removed* from the downstream DRAM stream; the
    write policy is honored — write-through forwards write hits,
    write-back absorbs them and inserts victim write-backs (as WRITE
    requests, tagged ``"writeback"``) just before the evicting miss.

    The cache is banked per memory channel (each channel owns a bank
    with the full configured geometry, like each channel owns a
    scheduler front end), so filtering commutes with channel
    decomposition — property-tested. ``memo`` optionally caches the
    filtered output keyed by (cache, channels, timings): the autotuner
    shares one dict across its grid so the expensive trace scan runs
    once per cache×channel shape (callers must reuse a memo only with
    an identical input stream).
    """

    engine: str = "auto"
    memo: dict | None = None
    name: str = dataclasses.field(default="cache_filter", init=False)

    def run(self, stream: RequestStream, ctx: PipelineContext):
        if ctx.cache is None:
            raise ValueError("CacheFilterStage requires a cache config")
        key = (ctx.cache, ctx.channels, ctx.timings, ctx.faults)
        # A memo hit would skip the per-request scan the event stream
        # comes from — tracing runs bypass the memo entirely (read and
        # write) so the events are always emitted and never stale.
        memo = None if ctx.trace is not None else self.memo
        if memo is not None and key in memo:
            return memo[key]
        cache = ctx.cache
        amap = ctx.address_map()
        lb = cache.line_bytes
        parts: list[RequestStream] = []
        n_hits = 0
        n_wb = 0
        hits_per_channel: list[int] = []
        for k, sel in _per_channel(stream, ctx.num_channels):
            sub = stream.select(sel)
            res = cache_engine.filter_trace_rw(
                cache, sub.local_addr // lb, sub.rw, engine=self.engine)
            ch_hits = int(res.hits.sum())
            n_hits += ch_hits
            n_wb += res.n_writebacks
            hits_per_channel.append(ch_hits)
            if ctx.trace is not None:
                hits_l = res.hits.tolist()
                seqs = sub.seq.tolist()
                ctx.trace.stage_events.extend(
                    ("cache", k, s, "hit" if h else "miss")
                    for s, h in zip(seqs, hits_l))
            kept = sub.select(np.flatnonzero(res.keep))
            kept.tags["writeback"] = np.zeros(len(kept), bool)
            wb_src = sub.select(res.wb_pos)
            if ctx.trace is not None:
                ctx.trace.stage_events.extend(
                    ("cache_wb", k, int(s)) for s in wb_src.seq)
            wb_local = res.wb_line * lb
            wb = RequestStream(
                addr=amap.global_addr(np.full(res.n_writebacks, k,
                                              np.int64), wb_local),
                rw=np.ones(res.n_writebacks, np.int32),
                pe_id=wb_src.pe_id, seq=wb_src.seq,
                channel=np.full(res.n_writebacks, k, np.int64),
                local_addr=wb_local,
                tags={**{t: v for t, v in wb_src.tags.items()},
                      "writeback": np.ones(res.n_writebacks, bool)})
            # Merge: a write-back enters the stream immediately before
            # its evicting miss (position key ``2*pos`` vs ``2*pos+1``).
            keep_pos = np.flatnonzero(res.keep)
            merged = _concat_streams([kept, wb])
            order = np.argsort(
                np.concatenate([keep_pos * 2 + 1, res.wb_pos * 2]),
                kind="stable")
            parts.append(merged.select(order))
        out = _concat_streams(parts) if parts else stream
        n = len(stream)
        result = (out, StageStats(
            self.name, float(n_hits), n, len(out),
            {"hit_rate": n_hits / max(1, n), "n_hits": n_hits,
             "n_writebacks": n_wb, "write_policy": cache.write_policy,
             "hits_per_channel": hits_per_channel}))
        if memo is not None:
            memo[key] = result
        return result


def _concat_streams(streams: list[RequestStream]) -> RequestStream:
    tags_keys = set().union(*(s.tags.keys() for s in streams)) \
        if streams else set()
    def cat(get, dtype=None):
        arrs = [get(s) for s in streams]
        return np.concatenate(arrs) if arrs else np.empty(0, dtype)
    has_ch = all(s.channel is not None for s in streams)
    has_local = all(s.local_addr is not None for s in streams)
    # arrival is a default, not an annotation: a stream without stamps
    # is "all pending from 0", so mixing promotes the missing ones to 0
    has_arr = any(s.arrival_cycle is not None for s in streams)
    return RequestStream(
        addr=cat(lambda s: s.addr, np.int64),
        rw=cat(lambda s: s.rw, np.int32),
        pe_id=cat(lambda s: s.pe_id, np.int64),
        seq=cat(lambda s: s.seq, np.int64),
        channel=cat(lambda s: s.channel, np.int64) if has_ch else None,
        local_addr=(cat(lambda s: s.local_addr, np.int64)
                    if has_local else None),
        arrival_cycle=(cat(lambda s: (s.arrival_cycle
                                      if s.arrival_cycle is not None
                                      else np.zeros(len(s), np.float64)),
                           np.float64) if has_arr else None),
        tags={k: cat(lambda s: s.tags[k]) for k in tags_keys})


@dataclasses.dataclass
class BatchSchedulerStage:
    """Per-channel batch formation + stable row reorder (the dual-queue
    former and bitonic network of paper §IV). Emits the serviced DRAM
    command stream: FLIT identity is retired here (the reorder buffer
    unsorts responses), so downstream ``pe_id``/``seq`` are -1. Charges
    no cycles itself — the exposed (non-overlapped) scheduling cost is
    computed by :class:`DMAOverlapStage` once DRAM service is known."""

    coalesce_writes: bool = False
    name: str = dataclasses.field(default="batch_scheduler", init=False)

    def run(self, stream: RequestStream, ctx: PipelineContext):
        sch = ctx.scheduler
        if sch is None:
            raise ValueError("BatchSchedulerStage requires a scheduler "
                             "config")
        amap = ctx.address_map()
        parts: list[RequestStream] = []
        n_batches = 0
        for k, sel in _per_channel(stream, ctx.num_channels):
            served, served_rw = scheduler_mod.schedule_trace_rw(
                stream.local_addr[sel], stream.rw[sel], config=sch,
                timings=ctx.timings, coalesce_writes=self.coalesce_writes)
            n_batches += scheduler_mod.count_batches(stream.rw[sel],
                                                     config=sch)
            if ctx.trace is not None:
                seqs = stream.seq[sel].tolist()
                for bi, batch in enumerate(scheduler_mod.form_batches_typed(
                        stream.local_addr[sel], stream.rw[sel],
                        config=sch)):
                    ctx.trace.stage_events.extend(
                        ("batch", k, seqs[pos], bi)
                        for pos in batch.seq.tolist())
            m = served.shape[0]
            kf = np.full(m, k, np.int64)
            parts.append(RequestStream(
                addr=amap.global_addr(kf, served), rw=served_rw,
                pe_id=np.full(m, -1, np.int64),
                seq=np.full(m, -1, np.int64),
                channel=kf, local_addr=served))
        out = _concat_streams(parts) if parts else stream
        ctx.sched_batches = n_batches
        return out, StageStats(
            self.name, 0.0, len(stream), len(out),
            {"n_batches": n_batches, "batch_size": sch.batch_size,
             "coalesce_writes": self.coalesce_writes})


@dataclasses.dataclass
class DRAMServiceStage:
    """Channel-parallel DRAM service: each channel issues its stream
    against its own bank/row state (tWTR/tRTW turnarounds included) and
    the stage charges the *makespan* — the slowest channel — since
    channels drain concurrently.

    ``ctx.dram_sched`` selects the command scheduler each channel's
    interface runs: strict FIFO (``None`` / window 1 — the classic
    arrival-order classification, bit-identical to the pre-scheduler
    stage) or FR-FCFS with a bounded reorder window, starvation cap and
    refresh (:func:`repro.core.timing.simulate_dram_sched`). This is
    the first stage whose charged cycles depend on service *order*, not
    just stream contents — the golden-trace + property harness in
    ``tests/core/test_dram_sched.py`` / ``test_golden_pipeline.py``
    locks it down."""

    name: str = dataclasses.field(default="dram_service", init=False)

    def run(self, stream: RequestStream, ctx: PipelineContext):
        if _open_loop_active(stream, ctx):
            return self._run_serving(stream, ctx)
        if ctx.fault_active:
            return self._run_closed_faults(stream, ctx)
        sched = ctx.dram_sched
        # The default config degenerates to strict FIFO — skip the
        # scheduler wrapper entirely (it would recompute turnarounds
        # and allocate an unread service_order on the hot path; the
        # results are bit-identical either way, property-tested). A
        # tracing run takes the scheduler wrapper even then: the event
        # stream needs service_order, and the wrapper's window-1
        # degeneracy is bit-identical (only the result subtype widens).
        if sched is not None and sched.effective_window == 1 \
                and not sched.t_refi and ctx.trace is None:
            sched = None
        if sched is None and ctx.trace is not None:
            sched = DRAMSchedConfig()
        per_channel: list[SimResult] = []
        n_ref = 0
        for _k, sel in _per_channel(stream, ctx.num_channels):
            if sched is None:
                per_channel.append(simulate_dram_access(
                    stream.local_addr[sel], ctx.timings,
                    rw=stream.rw[sel]))
            else:
                ct = None if ctx.trace is None else \
                    ctx.trace.channel(_k, req_ids=stream.seq[sel])
                res = simulate_dram_sched(
                    stream.local_addr[sel], ctx.timings, sched,
                    rw=stream.rw[sel], trace=ct)
                n_ref += res.n_refreshes
                per_channel.append(res)
        makespan = max((r.total_fpga_cycles for r in per_channel),
                       default=0.0)
        ctx.dram_makespan = makespan
        busy = float(sum(r.total_fpga_cycles for r in per_channel))
        info = {"per_channel": per_channel, "busy_fpga_cycles": busy,
                "occupancy_per_channel": [r.total_fpga_cycles
                                          for r in per_channel]}
        if sched is not None:
            info.update(sched_policy=sched.policy,
                        reorder_window=sched.effective_window,
                        n_refreshes=n_ref)
        return stream, StageStats(
            self.name, makespan, len(stream), len(stream), info)

    def _run_closed_faults(self, stream: RequestStream,
                           ctx: PipelineContext):
        """Closed-loop service under an *active* fault config: each
        channel runs the fault-injected engine with every request
        pending from cycle 0 (the serving model's closed-loop
        degeneracy), so ECC correction stalls, replay bus traffic,
        outage windows and degradation land in the charged makespan.
        The fault-free branch above is untouched — an inactive config
        never reaches here (bit-identical degeneracy)."""
        from repro.core.timing import simulate_faults

        sched = ctx.dram_sched if ctx.dram_sched is not None \
            else DRAMSchedConfig()
        per_channel: list[SimResult] = []
        fault_agg = None
        n_ref = 0
        for k, sel in _per_channel(stream, ctx.num_channels):
            ct = None if ctx.trace is None else \
                ctx.trace.channel(k, req_ids=stream.seq[sel])
            res = simulate_faults(
                stream.local_addr[sel], ctx.timings, sched,
                rw=stream.rw[sel], faults=ctx.faults, channel=k,
                trace=ct)
            n_ref += res.n_refreshes
            fault_agg = res.fault if fault_agg is None \
                else fault_agg.combine(res.fault)
            per_channel.append(res)
        ctx.fault_stats = fault_agg
        makespan = max((r.total_fpga_cycles for r in per_channel),
                       default=0.0)
        ctx.dram_makespan = makespan
        busy = float(sum(r.total_fpga_cycles for r in per_channel))
        info = {"per_channel": per_channel, "busy_fpga_cycles": busy,
                "occupancy_per_channel": [r.total_fpga_cycles
                                          for r in per_channel],
                "sched_policy": sched.policy,
                "reorder_window": sched.effective_window,
                "n_refreshes": n_ref, "fault": fault_agg}
        return stream, StageStats(
            self.name, makespan, len(stream), len(stream), info)

    def _run_serving(self, stream: RequestStream, ctx: PipelineContext):
        """Open-loop service: each channel runs the coupled
        admission+scheduling model (:func:`repro.core.timing.
        simulate_arrivals`) — per-port FIFOs gated on arrival, the
        configured arbiter granting into the reorder window at issue
        pace, idle gaps advanced (with refresh absorption). Per-request
        completion stamps are scattered back by ``seq`` so the runner
        can report sojourn percentiles against the original stream.

        With an active fault config every channel runs the RAS engine
        (:func:`repro.core.timing.simulate_faults`) instead — same
        admission loop plus error injection / ECC / bounded replay /
        degradation — and the per-channel ``FaultStats`` are combined
        onto the context blackboard, dropped flags scattered by seq."""
        from repro.core.timing import simulate_arrivals, simulate_faults

        n = len(stream)
        if n and int(stream.seq.min()) < 0:
            raise ValueError(
                "open-loop serving needs per-request FLIT identity; the "
                "batch scheduler retires it — run the serving pipeline "
                "without BatchSchedulerStage")
        sched = ctx.dram_sched if ctx.dram_sched is not None \
            else DRAMSchedConfig()
        arr = stream.arrival_cycle if stream.arrival_cycle is not None \
            else np.zeros(n, np.float64)
        nports = ctx.arb_ports
        size = int(stream.seq.max()) + 1 if n else 0
        if size != n:
            raise ValueError(
                "open-loop serving requires a drop-free stream (one "
                "completion per ingested request) — disable the cache "
                "filter for serving runs")
        completion = np.zeros(size, np.float64)
        service = np.zeros(size, np.float64)
        arrival = np.zeros(size, np.float64)
        pe_by_seq = np.zeros(size, np.int64)
        per_channel: list[SimResult] = []
        n_ref = 0
        idle = 0.0
        grants = stalls = None
        if nports is not None and nports > 1:
            grants = np.zeros(nports, np.int64)
            stalls = np.zeros(nports, np.int64)
        fault_on = ctx.fault_active
        fault_agg = None
        dropped = np.zeros(size, bool) if fault_on else None
        for k, sel in _per_channel(stream, ctx.num_channels):
            sub = dict(
                rw=stream.rw[sel], arrival_fpga=arr[sel],
                pe_id=(stream.pe_id[sel] if nports is not None
                       and nports > 1 else None),
                num_ports=nports, arb_policy=ctx.arb_policy,
                weights=ctx.arb_weights,
                trace=(None if ctx.trace is None else
                       ctx.trace.channel(k, req_ids=stream.seq[sel])))
            if fault_on:
                res = simulate_faults(
                    stream.local_addr[sel], ctx.timings, sched,
                    faults=ctx.faults, channel=k, **sub)
                fault_agg = res.fault if fault_agg is None \
                    else fault_agg.combine(res.fault)
                dropped[stream.seq[sel]] = res.dropped
            else:
                res = simulate_arrivals(
                    stream.local_addr[sel], ctx.timings, sched, **sub)
            n_ref += res.n_refreshes
            idle += res.idle_dram_cycles * ctx.timings.clock_ratio
            seqs = stream.seq[sel]
            completion[seqs] = res.completion_fpga_cycles
            service[seqs] = (res.service_dram_cycles
                             * ctx.timings.clock_ratio)
            arrival[seqs] = arr[sel]
            pe_by_seq[seqs] = stream.pe_id[sel]
            if grants is not None:
                st = channels_mod.ArbiterStats.from_grant_order(
                    res.granted_port, nports)
                grants += st.grants
                stalls += st.stall_slots
            per_channel.append(res)
        makespan = max((r.total_fpga_cycles for r in per_channel),
                       default=0.0)
        ctx.dram_makespan = makespan
        ctx.serving_completion = completion
        ctx.serving_service = service
        ctx.serving_arrival = arrival
        ctx.serving_pe = pe_by_seq
        ctx.serving_idle = idle
        ctx.serving_dropped = dropped
        ctx.fault_stats = fault_agg
        if grants is not None:
            ctx.serving_port_stats = channels_mod.ArbiterStats(
                grants=grants, stall_slots=stalls,
                fairness=channels_mod._jain(grants))
        busy = float(sum(r.total_fpga_cycles for r in per_channel))
        info = {"per_channel": per_channel, "busy_fpga_cycles": busy,
                "occupancy_per_channel": [r.total_fpga_cycles
                                          for r in per_channel],
                "open_loop": True, "idle_fpga_cycles": idle,
                "sched_policy": sched.policy,
                "reorder_window": sched.effective_window,
                "n_refreshes": n_ref}
        if fault_on:
            info["fault"] = fault_agg
        return stream, StageStats(
            self.name, makespan, len(stream), len(stream), info)


@dataclasses.dataclass
class DMAOverlapStage:
    """Overlap credit: the DMA engine's double-buffered streaming lets
    batch k+1 form and sort while batch k streams from DRAM, so only
    the first batch's scheduling latency — plus any per-batch residual
    the DRAM service is too short to hide — is exposed
    (:func:`repro.core.timing.t_overlapped_schedule`). With the
    scheduler disabled (or an empty trace) it charges nothing."""

    name: str = dataclasses.field(default="dma_overlap", init=False)

    def run(self, stream: RequestStream, ctx: PipelineContext):
        sch = ctx.scheduler
        if sch is None or not sch.enabled or ctx.sched_batches == 0:
            exposed = 0.0
        else:
            exposed = t_overlapped_schedule(
                sch.batch_size, ctx.sched_batches, ctx.dram_makespan,
                sch.data_cond_cycles)
        return stream, StageStats(
            self.name, exposed, len(stream), len(stream),
            {"n_batches": ctx.sched_batches,
             "hidden_behind_dram": ctx.dram_makespan})


# ---------------------------------------------------------------------------
# Composition + runner
# ---------------------------------------------------------------------------

def default_stages(
    ctx: PipelineContext,
    *,
    ports: int | None = None,
    arbiter_policy: str = "round_robin",
    weights: Sequence[int] | None = None,
    cache: bool = True,
    coalesce_writes: bool = False,
    cache_memo: dict | None = None,
) -> list:
    """The full-controller stage list for ``ctx`` (disabled engines are
    omitted; the legacy ``modeled_*`` wrappers pass subsets of the same
    flags, so every modeled number in the repo is produced here)."""
    stages: list = [AddressMapStage()]
    if ports is not None:
        stages.append(PortArbiterStage(num_ports=ports,
                                       policy=arbiter_policy,
                                       weights=weights))
    if cache and ctx.cache is not None and ctx.cache.enabled:
        stages.append(CacheFilterStage(memo=cache_memo))
    if ctx.scheduler is not None and ctx.scheduler.enabled:
        stages.append(BatchSchedulerStage(coalesce_writes=coalesce_writes))
    stages.append(DRAMServiceStage())
    stages.append(DMAOverlapStage())
    return stages


def run_pipeline(stream: RequestStream, ctx: PipelineContext,
                 stages: Sequence) -> PipelineResult:
    """Push ``stream`` through ``stages`` and assemble the result."""
    n_in = len(stream)
    open_loop_in = stream.has_arrivals
    stats_list: list[StageStats] = []
    for stage in stages:
        stream, stats = stage.run(stream, ctx)
        stats_list.append(stats)
    total = ctx.ctrl_overhead_cycles + sum(s.cycles for s in stats_list)

    def _info(name, key, default=None):
        for s in stats_list:
            if s.name == name:
                return s.info.get(key, default)
        return default

    per_channel = _info("dram_service", "per_channel", [])
    arb = 0.0
    port_stats = None
    for s in stats_list:
        if s.name == "port_arbiter":
            arb = s.cycles
            port_stats = s.info["port_stats"]
    if ctx.serving_port_stats is not None:
        port_stats = ctx.serving_port_stats
    serving = None
    if ctx.serving_completion is not None:
        # Pre-DRAM exposed cycles (ctrl overhead + arbiter fill) shift
        # every completion uniformly; makespan == max completion exactly.
        pre = total - ctx.dram_makespan
        serving = ServingStats.from_arrays(
            ctx.serving_arrival, ctx.serving_completion + pre,
            ctx.serving_service, ctx.serving_pe,
            makespan=total, idle=ctx.serving_idle,
            open_loop=open_loop_in)
    if ctx.trace is not None:
        ctx.trace.finalize(ctx, total)
    return PipelineResult(
        makespan_fpga_cycles=total,
        stages=stats_list,
        per_channel=per_channel,
        requests_per_channel=(ctx.requests_per_channel
                              or [0] * ctx.num_channels),
        dram_makespan_fpga_cycles=ctx.dram_makespan,
        arbitration_cycles=arb,
        n_requests=n_in,
        cache_hit_rate=_info("cache_filter", "hit_rate"),
        port_stats=port_stats,
        serving=serving,
        fault=ctx.fault_stats,
        dropped=ctx.serving_dropped)
