"""Cache engine — reconfigurable set-associative LRU cache (paper §IV-A).

The FPGA implementation keeps tags/data in URAM and runs two interlocked
pipelines (4-stage PE pipeline for lookups, 3-stage MEM pipeline for fills)
sharing Tag RAM, Data RAM and LRU state. Here the same structure is a
functional state pytree — ``CacheState`` — threaded through a ``lax.scan``:
each scan step is one "pipeline beat" that performs the tag compare, the LRU
update, and (on miss) the MEM-pipeline fill of the victim way. MEM-pipeline
priority (fills stall lookups) is inherent in the sequential scan semantics.

This module is the *oracle* for the `repro.kernels.cache_lookup` Pallas
kernel and the measurement substrate for the Table III / Fig. 7 benchmarks.
Address mapping: line = addr // line_bytes, set = line % num_sets,
tag = line // num_sets.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scatter_util
from repro.core.config import CacheConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheState:
    """Tag RAM + Data RAM + LRU age matrix + dirty bits, as arrays.

    ``age`` holds the global access stamp of each way's last touch; LRU
    victim = argmin(age), with invalid ways pinned to age -1 so they are
    always chosen first. ``clock`` is the global stamp counter. ``dirty``
    marks ways whose Data RAM line is newer than DRAM (write-back policy);
    evicting a dirty way emits a victim write-back to the backing store.
    """

    tags: jnp.ndarray    # (sets, ways) int32
    valid: jnp.ndarray   # (sets, ways) bool
    age: jnp.ndarray     # (sets, ways) int32
    data: jnp.ndarray    # (sets, ways, line_elems) — cached lines
    clock: jnp.ndarray   # () int32
    dirty: jnp.ndarray   # (sets, ways) bool


def init_cache(
    config: CacheConfig, line_elems: int, dtype=jnp.float32
) -> CacheState:
    sets, ways = config.num_sets, config.associativity
    return CacheState(
        tags=jnp.zeros((sets, ways), jnp.int32),
        valid=jnp.zeros((sets, ways), bool),
        age=jnp.full((sets, ways), -1, jnp.int32),
        data=jnp.zeros((sets, ways, line_elems), dtype),
        clock=jnp.zeros((), jnp.int32),
        dirty=jnp.zeros((sets, ways), bool),
    )


def _split_addr(line_id: jnp.ndarray, num_sets: int):
    return line_id % num_sets, line_id // num_sets   # (set, tag)


def lookup(
    state: CacheState, line_id: jnp.ndarray, fill_line: jnp.ndarray,
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray]:
    """One *read-only* cache beat: probe ``line_id``; on miss install
    ``fill_line``.

    Returns (new_state, hit?, line_data). ``fill_line`` is the line the MEM
    pipeline would return from DRAM; on a hit it is ignored — the Data RAM
    copy is served (so a stale fill cannot clobber a dirty line).

    This beat has no write-back port: a miss that evicts a *dirty* way
    would lose the dirty line. Only feed it states with no dirty lines
    (pure read service) — mixed read/write traces go through
    :func:`access_rw` / :func:`simulate_trace_rw`, or :func:`flush` the
    state first.
    """
    num_sets = state.tags.shape[0]
    set_idx, tag = _split_addr(line_id, num_sets)

    way_tags = state.tags[set_idx]            # (ways,)
    way_valid = state.valid[set_idx]
    match = way_valid & (way_tags == tag)
    hit = jnp.any(match)
    hit_way = jnp.argmax(match)               # valid only when hit

    victim = jnp.argmin(state.age[set_idx])   # LRU (invalid age=-1 wins)
    way = jnp.where(hit, hit_way, victim)

    line_out = jnp.where(hit, state.data[set_idx, way], fill_line)

    clock = state.clock + 1
    new_state = CacheState(
        tags=state.tags.at[set_idx, way].set(tag),
        valid=state.valid.at[set_idx, way].set(True),
        age=state.age.at[set_idx, way].set(clock),
        data=state.data.at[set_idx, way].set(line_out),
        clock=clock,
        # read beat: a hit keeps the way's dirty bit (served from Data RAM),
        # a miss installs a fresh-from-DRAM line, which is clean.
        dirty=state.dirty.at[set_idx, way].set(
            hit & state.dirty[set_idx, way]),
    )
    return new_state, hit, line_out


def simulate_trace_seq(
    state: CacheState, line_ids: jnp.ndarray, table: jnp.ndarray,
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray]:
    """Reference implementation of :func:`simulate_trace`: one
    ``lax.scan`` beat per request, exactly the paper's shared-pipeline
    stall semantics. O(N) sequential steps — kept as the oracle the
    set-parallel engine is property-tested against, and as the fallback
    for traced inputs / pathologically set-skewed traces."""

    def step(st, lid):
        new_st, hit, line = lookup(st, lid, table[lid])
        return new_st, (hit, line)

    final, (hits, lines) = jax.lax.scan(step, state, line_ids)
    return final, hits, lines


def simulate_trace(
    state: CacheState, line_ids: jnp.ndarray, table: jnp.ndarray,
    *, engine: str = "auto",
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray]:
    """Service a *read* trace through the cache against backing ``table``.

    ``table[line_id]`` plays DRAM. Returns (final_state, hits (N,) bool,
    lines (N, line_elems)). Like :func:`lookup`, this path has no
    write-back port — flush dirty state first, or use
    :func:`simulate_trace_rw` for mixed traces.

    ``engine`` selects the execution strategy — never the semantics (the
    two are bit-identical, see ``trace_engine``):

    * ``"auto"`` (default) — set-parallel engine when the trace is
      concrete, long enough, and the starting state is dirty-free (this
      path's no-write-back-port contract); sequential scan otherwise.
    * ``"parallel"`` — force the set-parallel engine.
    * ``"sequential"`` — force the one-beat-per-request reference scan.
    """
    from repro.core import trace_engine

    if engine == "sequential":
        return simulate_trace_seq(state, line_ids, table)
    if engine == "parallel":
        return trace_engine.simulate_trace_parallel(state, line_ids, table)
    if engine != "auto":
        raise ValueError(f"unknown engine {engine!r}")
    if trace_engine.auto_parallel_ok(state, line_ids, table=table):
        return trace_engine.simulate_trace_parallel(state, line_ids, table)
    return simulate_trace_seq(state, line_ids, table)


# ---------------------------------------------------------------------------
# Write path (write-allocate; write-back or write-through per CacheConfig)
# ---------------------------------------------------------------------------

def _line_of(tag: jnp.ndarray, set_idx: jnp.ndarray, num_sets: int):
    return tag * num_sets + set_idx


def access_rw(
    state: CacheState,
    table: jnp.ndarray,
    line_id: jnp.ndarray,
    is_write: jnp.ndarray,
    write_line: jnp.ndarray,
    *,
    write_back: bool = True,
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One cache beat of a mixed read/write stream against backing ``table``.

    Write-allocate both ways; full-line writes (the controller's FLIT
    payload is one line). Under write-back a write only touches Data RAM
    and sets the dirty bit; DRAM sees the line when the way is evicted
    (victim flush — the MEM pipeline's write port). Under write-through
    every write also lands in ``table`` immediately and lines stay clean.

    Returns (new_state, new_table, hit?, line_out) where ``line_out`` is
    the value a read observes (reads see earlier writes — the same-address
    ordering the weak-consistency rule guarantees).
    """
    num_sets = state.tags.shape[0]
    n_rows = table.shape[0]
    set_idx, tag = _split_addr(line_id, num_sets)

    way_tags = state.tags[set_idx]
    way_valid = state.valid[set_idx]
    match = way_valid & (way_tags == tag)
    hit = jnp.any(match)
    hit_way = jnp.argmax(match)

    victim = jnp.argmin(state.age[set_idx])
    way = jnp.where(hit, hit_way, victim)

    # Victim write-back: on a miss that evicts a valid dirty way, its line
    # returns to DRAM before the fill (same set, different tag — the victim
    # line can never equal ``line_id``).
    victim_line = jnp.clip(
        _line_of(state.tags[set_idx, way], set_idx, num_sets), 0, n_rows - 1)
    evict = (~hit) & state.valid[set_idx, way] & state.dirty[set_idx, way]
    table = table.at[victim_line].set(
        jnp.where(evict, state.data[set_idx, way], table[victim_line]))

    fill = table[line_id]
    cached = jnp.where(hit, state.data[set_idx, way], fill)
    line_out = jnp.where(is_write, write_line, cached)
    new_dirty_bit = is_write if write_back else jnp.zeros((), bool)
    keep_dirty = hit & state.dirty[set_idx, way] & ~is_write

    if not write_back:
        table = table.at[line_id].set(
            jnp.where(is_write, write_line, table[line_id]))

    clock = state.clock + 1
    new_state = CacheState(
        tags=state.tags.at[set_idx, way].set(tag),
        valid=state.valid.at[set_idx, way].set(True),
        age=state.age.at[set_idx, way].set(clock),
        data=state.data.at[set_idx, way].set(line_out),
        clock=clock,
        dirty=state.dirty.at[set_idx, way].set(new_dirty_bit | keep_dirty),
    )
    return new_state, table, hit, line_out


def simulate_trace_rw_seq(
    state: CacheState,
    line_ids: jnp.ndarray,
    rw: jnp.ndarray,
    write_lines: jnp.ndarray,
    table: jnp.ndarray,
    *,
    config: CacheConfig,
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Reference implementation of :func:`simulate_trace_rw`: strict
    one-beat-at-a-time ``lax.scan`` over :func:`access_rw`. Kept as the
    oracle for the set-parallel engine and as the fallback path."""
    wb = config.write_policy == "write_back"

    def step(carry, req):
        st, tbl = carry
        lid, is_w, wline = req
        st, tbl, hit, line = access_rw(st, tbl, lid, is_w != 0, wline,
                                       write_back=wb)
        return (st, tbl), (hit, line)

    (final, table), (hits, lines) = jax.lax.scan(
        step, (state, table), (line_ids, rw, write_lines))
    return final, table, hits, lines


def simulate_trace_rw(
    state: CacheState,
    line_ids: jnp.ndarray,
    rw: jnp.ndarray,
    write_lines: jnp.ndarray,
    table: jnp.ndarray,
    *,
    config: CacheConfig,
    engine: str = "auto",
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Service a mixed read/write trace through the cache.

    ``rw[i]`` is 0 (read) / 1 (write); ``write_lines[i]`` is the payload of
    request i (ignored for reads). Returns (final_state, table', hits,
    lines) — call :func:`flush` on the final state to push residual dirty
    lines so ``table'`` matches the naive in-order write stream.

    ``engine``: ``"auto"`` / ``"parallel"`` / ``"sequential"`` — execution
    strategy only; results are bit-identical (see ``trace_engine``). The
    parallel engine additionally requires every line id to fall inside
    the table (``0 <= lid < table.shape[0]``) and uniform
    table/data/payload dtypes, so its vectorized value reconstruction is
    exact; ``"auto"`` checks this and falls back.
    """
    from repro.core import trace_engine

    wb = config.write_policy == "write_back"
    if engine == "sequential":
        return simulate_trace_rw_seq(state, line_ids, rw, write_lines,
                                     table, config=config)
    if engine == "parallel":
        return trace_engine.simulate_trace_rw_parallel(
            state, line_ids, rw, write_lines, table, write_back=wb)
    if engine != "auto":
        raise ValueError(f"unknown engine {engine!r}")
    if trace_engine.auto_parallel_ok(state, line_ids, rw=rw,
                                     write_lines=write_lines, table=table,
                                     rw_path=True):
        return trace_engine.simulate_trace_rw_parallel(
            state, line_ids, rw, write_lines, table, write_back=wb)
    return simulate_trace_rw_seq(state, line_ids, rw, write_lines, table,
                                 config=config)


def flush(state: CacheState, table: jnp.ndarray
          ) -> Tuple[CacheState, jnp.ndarray]:
    """Write every valid dirty line back to ``table``; clear dirty bits.

    Distinct (set, tag) pairs map to distinct lines, so the scatter has
    no duplicate targets among flushed ways; everything else is masked
    out of the write.
    """
    sets, ways = state.tags.shape
    set_grid = jnp.arange(sets, dtype=state.tags.dtype)[:, None]
    lines = _line_of(state.tags, jnp.broadcast_to(set_grid, (sets, ways)),
                     sets)
    mask = state.valid & state.dirty
    new_table = scatter_util.masked_row_set(
        table, jnp.clip(lines, 0, table.shape[0] - 1).reshape(-1),
        state.data.reshape(sets * ways, -1), mask.reshape(-1))
    return dataclasses.replace(
        state, dirty=jnp.zeros_like(state.dirty)), new_table


@dataclasses.dataclass
class FilterResult:
    """Outcome of running a line-id trace through the cache *filter* —
    the pipeline-stage view of the cache engine (no data movement).

    ``hits[i]`` — request i hit in the cache. ``keep[i]`` — request i is
    forwarded to the DRAM stream (misses always; write hits only under
    write-through). ``wb_pos``/``wb_line`` — victim write-backs emitted
    by evictions of dirty lines: a WRITE of line ``wb_line[j]`` enters
    the DRAM stream immediately *before* the evicting miss at trace
    position ``wb_pos[j]`` (write-back policy only; at most one per
    miss). Residual dirty lines at end of trace are *not* flushed — the
    filter models steady-state occupancy, not teardown.
    """

    hits: np.ndarray      # (N,) bool
    keep: np.ndarray      # (N,) bool
    wb_pos: np.ndarray    # (W,) int64, ascending
    wb_line: np.ndarray   # (W,) int64

    @property
    def hit_rate(self) -> float:
        return float(self.hits.mean()) if self.hits.size else 0.0

    @property
    def n_writebacks(self) -> int:
        return int(self.wb_pos.shape[0])


def _empty_filter_result(n: int) -> FilterResult:
    return FilterResult(hits=np.zeros(n, bool), keep=np.ones(n, bool),
                        wb_pos=np.empty(0, np.int64),
                        wb_line=np.empty(0, np.int64))


#: once at most this many sets still have pending beats, the lockstep
#: walk hands their residual (serial hot-set) subtraces to the dict
#: walk — below ~32 live rows the fixed per-iteration numpy dispatch
#: cost exceeds the ~1µs/beat of the dict.
TAIL_SETS = 32
#: below this trace length the dict walk is trivially fast and the
#: sort/pad setup of the lockstep path is not worth paying.
MIN_LOCKSTEP_TRACE = 4096


class _CompactLayout:
    """Skew-compacted set-parallel layout shared by the numpy lockstep
    walks (:func:`hit_rate_oracle`, :func:`filter_trace_rw`).

    Sets are ordered by descending beat count, so at lockstep depth
    ``j`` the live sets are exactly the prefix ``[:k_js[j]]`` — columns
    are contiguous slices instead of boolean-masked full-width rows, and
    total lockstep work is ``Σ_s min(count_s, d_cut)`` instead of
    ``depth · sets``. Depth is cut at ``d_cut``, the beat count of the
    (``TAIL_SETS``+1)-th hottest set: beyond it at most ``TAIL_SETS``
    serial chains survive, and those residual subtraces (``tail_slices``)
    go to the per-set dict walk, seeded from the lockstep arrays.
    """

    def __init__(self, lids: np.ndarray, sets: int):
        n = lids.shape[0]
        self.set_idx = lids % sets
        self.tag = lids // sets
        self.counts = np.bincount(self.set_idx, minlength=sets)
        counts_d = np.sort(self.counts)[::-1]
        self.d_cut = int(counts_d[TAIL_SETS]) if sets > TAIL_SETS else 0
        self.vec_beats = int(np.minimum(self.counts, self.d_cut).sum())
        self.n = n

    @property
    def worthwhile(self) -> bool:
        """Enough lockstep-coverable work to beat the dict walk (the
        dict tail runs at seq speed, so the combined path only loses
        when setup overhead dominates — i.e. when almost everything is
        tail anyway)."""
        return (self.n >= MIN_LOCKSTEP_TRACE
                and self.vec_beats >= self.n // 4)

    def build(self):
        """Materialize the padded ``(K, d_cut)`` layout (cost O(n +
        K·d_cut); only call when :attr:`worthwhile`)."""
        sets = self.counts.shape[0]
        perm = np.argsort(self.set_idx, kind="stable")
        starts = np.zeros(sets + 1, np.int64)
        np.cumsum(self.counts, out=starts[1:])
        sorder = np.argsort(-self.counts, kind="stable")
        counts_d = self.counts[sorder]
        self.K = K = int(np.searchsorted(-counts_d, 0, side="left"))
        self.sorder = sorder
        cap = np.minimum(counts_d[:K], self.d_cut)
        mask = np.arange(self.d_cut)[None, :] < cap[:, None]
        self.perm2 = np.concatenate(
            [perm[starts[s]:starts[s] + c]
             for s, c in zip(sorder[:K].tolist(), cap.tolist())]) \
            if K else np.empty(0, np.int64)
        self.mask = mask
        # live-prefix length per lockstep depth: #{counts_d > j}
        self.k_js = np.searchsorted(-counts_d[:K], -np.arange(self.d_cut),
                                    side="left")
        # residual serial chains: (row i, set s, global slice) triples
        n_tail = int(np.searchsorted(-counts_d, -self.d_cut, side="left"))
        self.tail_slices = [
            (i, int(sorder[i]),
             perm[starts[sorder[i]] + self.d_cut:
                  starts[sorder[i]] + counts_d[i]])
            for i in range(n_tail)]

    def pad(self, vals: np.ndarray, dtype) -> np.ndarray:
        out = np.zeros((self.K, self.d_cut), dtype)
        out[self.mask] = vals[self.perm2]
        return out


def filter_trace_rw_seq(
    config: CacheConfig, line_ids: np.ndarray, rw: np.ndarray | None = None,
) -> FilterResult:
    """Reference implementation of :func:`filter_trace_rw` — one python
    dict per set, one iteration per request (the :func:`hit_rate_oracle_seq`
    walk extended with dirty bits and victim write-backs). Kept as the
    oracle the lockstep version is property-tested against."""
    sets, ways = config.num_sets, config.associativity
    wb = config.write_policy == "write_back"
    lids = np.asarray(line_ids, dtype=np.int64).ravel()
    rw_arr = np.zeros(lids.shape[0], np.int32) if rw is None \
        else np.asarray(rw, dtype=np.int32).ravel()
    res = _empty_filter_result(lids.shape[0])
    wb_pos: list[int] = []
    wb_line: list[int] = []
    entries: list[dict[int, list]] = [dict() for _ in range(sets)]
    for i, lid in enumerate(lids):
        s, t = int(lid % sets), int(lid // sets)
        e = entries[s]
        w = int(rw_arr[i]) == 1
        if t in e:
            res.hits[i] = True
            rec = e[t]
            rec[0] = i
            if w:
                rec[1] = wb           # write hit: dirty under write-back,
                res.keep[i] = not wb  # forwarded under write-through
            else:
                res.keep[i] = False   # read hit served from Data RAM
        else:
            if len(e) >= ways:
                vt = min(e, key=lambda k: e[k][0])
                if e[vt][1]:
                    wb_pos.append(i)
                    wb_line.append(vt * sets + s)
                del e[vt]
            e[t] = [i, w and wb]      # write-allocate; full-line FLIT
    res.wb_pos = np.asarray(wb_pos, np.int64)
    res.wb_line = np.asarray(wb_line, np.int64)
    return res


def filter_trace_rw(
    config: CacheConfig, line_ids: np.ndarray, rw: np.ndarray | None = None,
    *, engine: str = "auto",
) -> FilterResult:
    """Cache filter for the staged pipeline: classify a mixed read/write
    line trace, *remove* requests the cache absorbs, and emit the victim
    write-backs the write-back policy adds to the DRAM stream.

    Semantics (identical to :func:`filter_trace_rw_seq`, property-tested):
    read hits are served on-chip and dropped from the stream; write hits
    are absorbed (dirty) under ``write_back`` and forwarded under
    ``write_through``; misses always go downstream (write-allocate — a
    full-line write needs no fill read); evicting a dirty way inserts a
    WRITE of the victim line just before the evicting miss.

    Vectorized exactly like :func:`hit_rate_oracle` — the skew-compacted
    lockstep walk (:class:`_CompactLayout`): sets advance ordered by
    descending beat count so each depth step touches only the contiguous
    live prefix, with ``(K, ways)`` tag/age/dirty arrays; global arrival
    indices keep LRU victims identical to the dict walk, and the few
    residual serial hot-set chains finish in the dict walk seeded from
    the lockstep state. Tiny or chain-dominated traces dispatch to the
    sequential oracle.
    """
    if engine not in ("auto", "parallel", "sequential"):
        raise ValueError(f"unknown engine {engine!r}")
    sets, ways = config.num_sets, config.associativity
    wb = config.write_policy == "write_back"
    lids = np.asarray(line_ids, dtype=np.int64).ravel()
    n = lids.shape[0]
    if n == 0:
        return _empty_filter_result(0)
    rw_arr = np.zeros(n, np.int32) if rw is None \
        else np.asarray(rw, dtype=np.int32).ravel()
    if engine == "sequential":
        return filter_trace_rw_seq(config, lids, rw_arr)
    lay = _CompactLayout(lids, sets)
    if engine == "auto" and not lay.worthwhile:   # skewed/tiny: dict wins
        return filter_trace_rw_seq(config, lids, rw_arr)
    lay.build()
    K = lay.K
    tag_pad = lay.pad(lay.tag, np.int64)
    idx_pad = lay.pad(np.arange(n, dtype=np.int64), np.int64)
    w_pad = lay.pad(rw_arr == 1, bool)
    set_of_row = lay.sorder[:K].astype(np.int64)

    tags_arr = np.zeros((K, ways), np.int64)
    valid = np.zeros((K, ways), bool)
    age = np.full((K, ways), -1, np.int64)
    dirty = np.zeros((K, ways), bool)
    res = _empty_filter_result(n)
    wb_pos_parts: list[np.ndarray] = []
    wb_line_parts: list[np.ndarray] = []
    rows = np.arange(K)
    for j in range(lay.d_cut):
        k = int(lay.k_js[j])          # live prefix: sets with count > j
        t = tag_pad[:k, j]
        match = valid[:k] & (tags_arr[:k] == t[:, None])
        hit = match.any(axis=1)
        way = np.where(hit, match.argmax(axis=1), age[:k].argmin(axis=1))
        r = rows[:k]
        evict = ~hit & valid[r, way] & dirty[r, way]
        if evict.any():
            es = np.flatnonzero(evict)
            wb_pos_parts.append(idx_pad[es, j])
            wb_line_parts.append(tags_arr[es, way[es]] * sets
                                 + set_of_row[es])
        gi = idx_pad[:k, j]
        wl = w_pad[:k, j]
        old_dirty = dirty[r, way]
        tags_arr[r, way] = t
        valid[r, way] = True
        age[r, way] = gi
        dirty[r, way] = np.where(hit, np.where(wl, wb, old_dirty),
                                 wl & wb)
        res.hits[gi] = hit
        res.keep[gi] = ~hit | (wl & (not wb))
    tag_l = lay.tag
    wb_pos_tail: list[int] = []
    wb_line_tail: list[int] = []
    for i, s, sl in lay.tail_slices:
        e = {int(tags_arr[i, w]): [int(age[i, w]), bool(dirty[i, w])]
             for w in range(ways) if valid[i, w]}
        for g, t, is_w in zip(sl.tolist(), tag_l[sl].tolist(),
                              (rw_arr[sl] == 1).tolist()):
            if t in e:
                res.hits[g] = True
                rec = e[t]
                rec[0] = g
                if is_w:
                    rec[1] = wb
                    res.keep[g] = not wb
                else:
                    res.keep[g] = False
            else:
                if len(e) >= ways:
                    vt = min(e, key=lambda kk: e[kk][0])
                    if e[vt][1]:
                        wb_pos_tail.append(g)
                        wb_line_tail.append(vt * sets + s)
                    del e[vt]
                e[t] = [g, is_w and wb]
    if wb_pos_tail:
        wb_pos_parts.append(np.asarray(wb_pos_tail, np.int64))
        wb_line_parts.append(np.asarray(wb_line_tail, np.int64))
    if wb_pos_parts:
        pos = np.concatenate(wb_pos_parts)
        line = np.concatenate(wb_line_parts)
        order = np.argsort(pos, kind="stable")   # one eviction per miss
        res.wb_pos, res.wb_line = pos[order], line[order]
    return res


def hit_rate_oracle_seq(
    config: CacheConfig, line_ids: np.ndarray
) -> Tuple[np.ndarray, float]:
    """Reference implementation of :func:`hit_rate_oracle` — one python
    dict per set, one iteration per request. Kept as the independent
    oracle the vectorized version is property-tested against."""
    sets, ways = config.num_sets, config.associativity
    tags = [dict() for _ in range(sets)]      # set -> {tag: last_use}
    hits = np.zeros(line_ids.shape[0], dtype=bool)
    for i, lid in enumerate(np.asarray(line_ids, dtype=np.int64)):
        s, t = int(lid % sets), int(lid // sets)
        entry = tags[s]
        if t in entry:
            hits[i] = True
        elif len(entry) >= ways:
            del entry[min(entry, key=entry.get)]
        entry[t] = i
    return hits, float(hits.mean()) if hits.size else 0.0


def hit_rate_oracle(
    config: CacheConfig, line_ids: np.ndarray
) -> Tuple[np.ndarray, float]:
    """Fast numpy LRU-cache reference (no data movement) — hit mask + rate.

    Used by benchmarks where only the hit/miss classification feeds the
    timing model (Eq. 2) and by hypothesis tests as an independent oracle.

    Set-parallel vectorization: all sets advance in lockstep over their
    per-set subtraces (padded to the longest), with numpy ``(sets, ways)``
    tag/age arrays replacing the per-set python dicts — ``max_per_set``
    python iterations instead of N. Ages are global arrival indices
    (unique), so LRU victims are identical to the sequential dict walk.

    The lockstep walk is *skew-compacted* (:class:`_CompactLayout`):
    sets advance ordered by descending beat count so each depth step
    touches only the contiguous prefix of still-live sets, and once at
    most ``TAIL_SETS`` serial hot-set chains remain their residual beats
    fall through to the dict walk seeded from the lockstep state — total
    cost is O(n) array work plus dict-speed tails, so the parallel path
    never loses to the sequential oracle beyond setup noise. Traces
    where almost everything is one serial chain (or tiny ones) dispatch
    straight to the identical sequential oracle.
    """
    sets, ways = config.num_sets, config.associativity
    lids = np.asarray(line_ids, dtype=np.int64).ravel()
    n = lids.shape[0]
    hits = np.zeros(n, dtype=bool)
    if n == 0:
        return hits, 0.0
    lay = _CompactLayout(lids, sets)
    if not lay.worthwhile:             # skewed / tiny: dict walk is faster
        return hit_rate_oracle_seq(config, lids)
    lay.build()
    K = lay.K
    tag_pad = lay.pad(lay.tag, np.int64)
    idx_pad = lay.pad(np.arange(n, dtype=np.int64), np.int64)

    tags_arr = np.zeros((K, ways), np.int64)
    valid = np.zeros((K, ways), bool)
    age = np.full((K, ways), -1, np.int64)   # empty ways always win LRU
    rows = np.arange(K)
    for j in range(lay.d_cut):
        k = int(lay.k_js[j])          # live prefix: sets with count > j
        t = tag_pad[:k, j]
        match = valid[:k] & (tags_arr[:k] == t[:, None])
        hit = match.any(axis=1)
        way = np.where(hit, match.argmax(axis=1), age[:k].argmin(axis=1))
        r = rows[:k]
        gi = idx_pad[:k, j]
        tags_arr[r, way] = t
        valid[r, way] = True
        age[r, way] = gi
        hits[gi] = hit
    tag_l = lay.tag
    for i, _s, sl in lay.tail_slices:
        entry = {int(tags_arr[i, w]): int(age[i, w])
                 for w in range(ways) if valid[i, w]}
        for g, t in zip(sl.tolist(), tag_l[sl].tolist()):
            if t in entry:
                hits[g] = True
            elif len(entry) >= ways:
                del entry[min(entry, key=entry.get)]
            entry[t] = g
    return hits, float(hits.mean())
