"""Cache engine — reconfigurable set-associative LRU cache (paper §IV-A).

The FPGA implementation keeps tags/data in URAM and runs two interlocked
pipelines (4-stage PE pipeline for lookups, 3-stage MEM pipeline for fills)
sharing Tag RAM, Data RAM and LRU state. Here the same structure is a
functional state pytree — ``CacheState`` — threaded through a ``lax.scan``:
each scan step is one "pipeline beat" that performs the tag compare, the LRU
update, and (on miss) the MEM-pipeline fill of the victim way. MEM-pipeline
priority (fills stall lookups) is inherent in the sequential scan semantics.

This module is the *oracle* for the `repro.kernels.cache_lookup` Pallas
kernel and the measurement substrate for the Table III / Fig. 7 benchmarks.
Address mapping: line = addr // line_bytes, set = line % num_sets,
tag = line // num_sets.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import CacheConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheState:
    """Tag RAM + Data RAM + LRU age matrix, as arrays.

    ``age`` holds the global access stamp of each way's last touch; LRU
    victim = argmin(age), with invalid ways pinned to age -1 so they are
    always chosen first. ``clock`` is the global stamp counter.
    """

    tags: jnp.ndarray    # (sets, ways) int32
    valid: jnp.ndarray   # (sets, ways) bool
    age: jnp.ndarray     # (sets, ways) int32
    data: jnp.ndarray    # (sets, ways, line_elems) — cached lines
    clock: jnp.ndarray   # () int32


def init_cache(
    config: CacheConfig, line_elems: int, dtype=jnp.float32
) -> CacheState:
    sets, ways = config.num_sets, config.associativity
    return CacheState(
        tags=jnp.zeros((sets, ways), jnp.int32),
        valid=jnp.zeros((sets, ways), bool),
        age=jnp.full((sets, ways), -1, jnp.int32),
        data=jnp.zeros((sets, ways, line_elems), dtype),
        clock=jnp.zeros((), jnp.int32),
    )


def _split_addr(line_id: jnp.ndarray, num_sets: int):
    return line_id % num_sets, line_id // num_sets   # (set, tag)


def lookup(
    state: CacheState, line_id: jnp.ndarray, fill_line: jnp.ndarray,
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray]:
    """One cache beat: probe ``line_id``; on miss install ``fill_line``.

    Returns (new_state, hit?, line_data). ``fill_line`` is the line the MEM
    pipeline would return from DRAM; on a hit it is ignored — the Data RAM
    copy is served (so a stale fill cannot clobber a dirty line).
    """
    num_sets = state.tags.shape[0]
    set_idx, tag = _split_addr(line_id, num_sets)

    way_tags = state.tags[set_idx]            # (ways,)
    way_valid = state.valid[set_idx]
    match = way_valid & (way_tags == tag)
    hit = jnp.any(match)
    hit_way = jnp.argmax(match)               # valid only when hit

    victim = jnp.argmin(state.age[set_idx])   # LRU (invalid age=-1 wins)
    way = jnp.where(hit, hit_way, victim)

    line_out = jnp.where(hit, state.data[set_idx, way], fill_line)

    clock = state.clock + 1
    new_state = CacheState(
        tags=state.tags.at[set_idx, way].set(tag),
        valid=state.valid.at[set_idx, way].set(True),
        age=state.age.at[set_idx, way].set(clock),
        data=state.data.at[set_idx, way].set(line_out),
        clock=clock,
    )
    return new_state, hit, line_out


def simulate_trace(
    state: CacheState, line_ids: jnp.ndarray, table: jnp.ndarray,
) -> Tuple[CacheState, jnp.ndarray, jnp.ndarray]:
    """Service a request trace through the cache against backing ``table``.

    ``table[line_id]`` plays DRAM. Returns (final_state, hits (N,) bool,
    lines (N, line_elems)). Sequential scan = the shared-pipeline stall
    semantics of the paper (one beat at a time through shared Tag/Data RAM).
    """

    def step(st, lid):
        new_st, hit, line = lookup(st, lid, table[lid])
        return new_st, (hit, line)

    final, (hits, lines) = jax.lax.scan(step, state, line_ids)
    return final, hits, lines


def hit_rate_oracle(
    config: CacheConfig, line_ids: np.ndarray
) -> Tuple[np.ndarray, float]:
    """Fast numpy LRU-cache reference (no data movement) — hit mask + rate.

    Used by benchmarks where only the hit/miss classification feeds the
    timing model (Eq. 2) and by hypothesis tests as an independent oracle.
    """
    sets, ways = config.num_sets, config.associativity
    tags = [dict() for _ in range(sets)]      # set -> {tag: last_use}
    hits = np.zeros(line_ids.shape[0], dtype=bool)
    for i, lid in enumerate(np.asarray(line_ids, dtype=np.int64)):
        s, t = int(lid % sets), int(lid // sets)
        entry = tags[s]
        if t in entry:
            hits[i] = True
        elif len(entry) >= ways:
            del entry[min(entry, key=entry.get)]
        entry[t] = i
    return hits, float(hits.mean()) if hits.size else 0.0
