"""Timing-model-driven parameter autotuner (the paper's TUNE column).

The paper tunes batch size, scheduler timeout, associativity and DMA
parallelism by hand against a target workload. We close the loop: given a
representative request trace and a resource (VMEM) budget, enumerate the
TUNE-class parameter grid, score each candidate with the analytic/simulated
timing model, and return the best feasible configuration. This is what
"programmable" buys over a fixed commercial IP: the controller is
re-specialized per application in seconds.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.config import (CacheConfig, ChannelConfig, DMAConfig,
                               DRAMSchedConfig, FaultConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.pipeline import (AddressMapStage, CacheFilterStage,
                                 PipelineContext, RequestStream,
                                 default_stages, run_pipeline)
from repro.core.scheduler import READ, WRITE
from repro.core.timing import (DRAMTimings, DDR4_2400, simulate_dram_sched,
                               t_overlapped_schedule)


@dataclasses.dataclass
class TuneResult:
    config: MemoryControllerConfig
    modeled_cycles: float
    candidates_evaluated: int
    table: list  # (config summary, cycles) per candidate, for reporting


def _score(
    cfg: MemoryControllerConfig,
    row_ids: np.ndarray,
    row_bytes: int,
    timings: DRAMTimings,
    memo: dict | None = None,
) -> float:
    """Modeled total access cycles for an irregular trace under ``cfg`` —
    the full staged pipeline's ``makespan_fpga_cycles``.

    Cache hits are served on-chip and *removed* from the DRAM stream
    (CacheFilter); misses flow through the per-channel schedulers to the
    channel-parallel DRAM service, so the DRAM term is the multi-channel
    makespan; only the non-overlapped scheduling residual is exposed
    (DMAOverlap). Scoring the composed pipeline is what lets ``tune``
    search cache geometry × num_channels × mapping policy *jointly*
    instead of by independent oracles. ``memo`` is the CacheFilter's
    shared cache, keyed by cache×channel shape (one expensive trace scan
    per shape across the whole grid).
    """
    stream = RequestStream.from_rows(row_ids, row_bytes=row_bytes)
    ctx = PipelineContext.from_config(cfg, timings)
    stages = default_stages(ctx, cache=True, cache_memo=memo)
    return run_pipeline(stream, ctx, stages).makespan_fpga_cycles


# ---------------------------------------------------------------------------
# Batched grid scorer (the vmap axis over stacked configs)
# ---------------------------------------------------------------------------
#
# ``tune``'s one-at-a-time path rebuilds the stream, re-plans the batch
# former (twice: once to schedule, once to count) and re-classifies the
# served stream for every grid point — all python-per-batch work on axes
# that are algebraically redundant:
#
#   * the score never reads ``cfg.dma`` (``PipelineContext.from_config``
#     drops it; DMA only constrains VMEM feasibility), so the dma axis is
#     a pure replication of scores;
#   * with all-zero arrivals (every closed-loop tune trace) the dual-queue
#     batch plan degenerates to strided chunking of each type's index
#     list — vectorizable, no python-per-batch walk;
#   * the strict-FIFO service classification of the *scheduled* stream
#     (sorted by (batch, row), then classified per bank in service order)
#     is one fused stable key sort by (bank, batch_rank, row): within a
#     bank, service order IS (batch_rank, row) order, so hit/first/
#     conflict counts fall out of adjacent-key comparisons. All counts
#     are integers and the cost polynomial is evaluated in the same
#     order, so the scores are bit-identical to the staged pipeline's.
#
# Non-degenerate command schedulers (window > 1 or refresh) drop to the
# real ``simulate_dram_sched`` per grid point — but on the vectorized
# served stream, and still with the dma axis hoisted.

def _const_batch_plan(rw_arr: np.ndarray, batch: int):
    """Vectorized dual-queue batch plan for a constant-arrival trace.

    Returns ``(n_events, rank_elem, types_by_rank)`` where ``rank_elem``
    maps each request to the service rank of its batch and
    ``types_by_rank`` is the per-batch request type in service order —
    identical ordering to ``scheduler._typed_batch_plan`` (timeouts
    cannot fire when every arrival stamp is equal, so batch boundaries
    are strided chunks of each type's positions; full batches key on
    their closing request's global index, partial flushes drain last,
    oldest head first).
    """
    m = rw_arr.shape[0]
    lims, phases, ties, types = [], [], [], []
    per_type_idx = []
    for t_order, t in enumerate((READ, WRITE)):
        idxs = np.flatnonzero(rw_arr == t)
        per_type_idx.append(idxs)
        mt = idxs.shape[0]
        n_full = mt // batch
        part = 1 if mt % batch else 0
        lim = np.empty(n_full + part, np.int64)
        ph = np.empty(n_full + part, np.int64)
        tie = np.empty(n_full + part, np.int64)
        lim[:n_full] = idxs[batch - 1::batch][:n_full]
        ph[:n_full] = 1
        tie[:n_full] = t_order
        if part:
            lim[n_full] = m
            ph[n_full] = 2
            tie[n_full] = idxs[n_full * batch]
        lims.append(lim)
        phases.append(ph)
        ties.append(tie)
        types.append(np.full(n_full + part, t, np.int32))
    lim_all = np.concatenate(lims)
    n_events = lim_all.shape[0]
    order = np.lexsort((np.concatenate(ties), np.concatenate(phases),
                        lim_all))
    ranks = np.empty(n_events, np.int64)
    ranks[order] = np.arange(n_events, dtype=np.int64)
    rank_elem = np.empty(m, np.int64)
    off = 0
    for idxs in per_type_idx:
        if idxs.size:
            rank_elem[idxs] = ranks[off + np.arange(idxs.size) // batch]
        off += idxs.size // batch + (1 if idxs.size % batch else 0)
    return n_events, rank_elem, np.concatenate(types)[order]


def _fifo_service_fpga_cycles(rows, banks, rank_elem, n_events,
                              types_by_rank, timings: DRAMTimings) -> float:
    """Strict-FIFO DRAM service cycles of the batch-scheduled stream —
    bit-identical to ``schedule_trace_rw`` + ``simulate_dram_access``
    without materializing the served permutation.

    One key sort by (bank, batch_rank, row) yields each bank's service
    sequence; row transitions within a bank classify hit/conflict,
    bank boundaries are first accesses, and bus turnarounds reduce to
    type flips between consecutive batches (single-type batches change
    direction only at batch seams). All counts are exact integers.
    """
    m = rows.shape[0]
    if m == 0:
        return 0.0
    row_span = int(rows.max()) + 1
    nb = int(timings.num_banks)
    if row_span * n_events * nb < (1 << 62):
        key = (banks * n_events + rank_elem) * row_span + rows
        key.sort()
        span = n_events * row_span
        b_s = key // span
        r_s = key % row_span
    else:
        perm = np.lexsort((rows, rank_elem, banks))
        b_s = banks[perm]
        r_s = rows[perm]
    same_b = b_s[1:] == b_s[:-1]
    n_hit = int((same_b & (r_s[1:] == r_s[:-1])).sum())
    n_first = m - int(same_b.sum())
    n_conflict = m - n_first - n_hit
    prev, cur = types_by_rank[:-1], types_by_rank[1:]
    turn = (int(((prev == WRITE) & (cur == READ)).sum()) * timings.t_wtr
            + int(((prev == READ) & (cur == WRITE)).sum()) * timings.t_rtw)
    dram_cycles = (
        n_first * (timings.t_rcd + timings.t_cl)
        + n_hit * timings.t_cl
        + n_conflict * (timings.t_rp + timings.t_rcd + timings.t_cl)
        + m * timings.t_burst
    ) + turn
    return dram_cycles * timings.clock_ratio


def _scheduled_stream(local, rw_arr, rows, rank_elem):
    """The batch-scheduled (served) stream — bit-identical to
    ``schedule_trace_rw`` via one stable sort on the fused
    (batch_rank, row) key (ties keep arrival order, the weak-consistency
    rule)."""
    m = local.shape[0]
    row_span = int(rows.max()) + 1 if m else 1
    if m and row_span < (1 << 62) // (rank_elem.max() + 2):
        perm = np.argsort(rank_elem * row_span + rows, kind="stable")
    else:
        perm = np.lexsort((np.arange(m), rows, rank_elem))
    return local[perm], rw_arr[perm]


def _batched_scores(
    row_ids: np.ndarray,
    row_bytes: int,
    timings: DRAMTimings,
    *,
    batch_sizes,
    cache_grid,
    chan_grid,
    sched_grid,
    starvation_cap: int,
    enable_cache: bool,
    filter_memo: dict,
) -> dict:
    """Stage-cycle sums for the whole (batch × cache × channels × sched)
    grid, keyed ``(batch, ways, lines, nc, policy, spol, win)`` — each
    entry bit-identical to the corresponding ``_score`` minus the
    (config-constant) control overhead. The dma axis never appears: the
    score is invariant in it."""
    stream0 = RequestStream.from_rows(row_ids, row_bytes=row_bytes)
    scores: dict = {}
    for ways, lines in cache_grid:
        if ways > lines:
            continue
        for nc, policy in chan_grid:
            ctx = PipelineContext(
                channels=ChannelConfig(num_channels=nc, policy=policy),
                scheduler=None,
                cache=CacheConfig(enabled=enable_cache, num_lines=lines,
                                  associativity=ways),
                timings=timings)
            mapped, _ = AddressMapStage().run(stream0, ctx)
            hits_cycles = 0.0
            if enable_cache:
                filtered, fstats = CacheFilterStage(
                    memo=filter_memo).run(mapped, ctx)
                hits_cycles = fstats.cycles
            else:
                filtered = mapped
            chans = []
            for _k in range(nc):
                sel = np.flatnonzero(filtered.channel == _k)
                local = filtered.local_addr[sel]
                chans.append((local, filtered.rw[sel],
                              timings.row_of(local),
                              timings.bank_of(local)))
            for batch in batch_sizes:
                plans = [_const_batch_plan(rw_c, batch) if local.size else
                         (0, None, None)
                         for local, rw_c, _r, _b in chans]
                for spol, win in sched_grid:
                    dsched = DRAMSchedConfig(policy=spol, reorder_window=win,
                                             starvation_cap=starvation_cap)
                    degenerate = (dsched.effective_window == 1
                                  and not dsched.t_refi)
                    totals = []
                    n_batches = 0
                    for (local, rw_c, rows, banks), \
                            (n_ev, rank_elem, types_r) in zip(chans, plans):
                        n_batches += n_ev
                        if local.size == 0:
                            totals.append(0.0)
                        elif degenerate:
                            totals.append(_fifo_service_fpga_cycles(
                                rows, banks, rank_elem, n_ev, types_r,
                                timings))
                        else:
                            served, served_rw = _scheduled_stream(
                                local, rw_c, rows, rank_elem)
                            totals.append(simulate_dram_sched(
                                served, timings, dsched,
                                rw=served_rw).total_fpga_cycles)
                    mk = max(totals, default=0.0)
                    ex = 0.0 if n_batches == 0 else t_overlapped_schedule(
                        batch, n_batches, mk,
                        SchedulerConfig(batch_size=batch).data_cond_cycles)
                    # replicate run_pipeline's left-to-right stage sum:
                    # addr_map, (cache), scheduler, dram, dma_overlap
                    s = 0 + 0.0
                    if enable_cache:
                        s = s + hits_cycles
                    s = s + 0.0
                    s = s + mk
                    s = s + ex
                    scores[(batch, ways, lines, nc, policy, spol, win)] = s
    return scores


def tune(
    row_ids: np.ndarray,
    row_bytes: int,
    *,
    vmem_budget_bytes: int = 8 << 20,
    batch_sizes: Sequence[int] = (4, 8, 16, 32, 64, 128, 256, 512),
    associativities: Sequence[int] = (1, 2, 4, 8),
    num_lines: Sequence[int] = (1024, 4096, 16384),
    dma_channels: Sequence[int] = (1, 2, 4, 8),
    num_channels: Sequence[int] = (1,),
    mapping_policies: Sequence[str] = ("row_interleave",),
    dram_sched_policies: Sequence[str] = ("fifo",),
    reorder_windows: Sequence[int] = (1,),
    starvation_cap: int = 16,
    enable_cache: bool = True,
    timings: DRAMTimings = DDR4_2400,
    engine: str = "batched",
) -> TuneResult:
    """Grid-search TUNE parameters for a trace under a VMEM budget.

    ``num_channels`` × ``mapping_policies`` extend the grid with the
    multi-channel front end's axes (``ChannelConfig``); the defaults keep
    the paper's single-interface search space. With one channel every
    mapping policy is the identity, so only the first policy is scored.

    ``dram_sched_policies`` × ``reorder_windows`` add the DRAM command
    scheduler's axes (``DRAMSchedConfig``): FIFO never reorders, so it
    is scored at one window only, and window 1 collapses every policy
    to FIFO — redundant grid points are deduplicated before scoring.

    ``engine`` selects the scorer: ``"batched"`` (default) evaluates the
    whole grid as one stacked computation (see ``_batched_scores`` — the
    dma axis is hoisted, the batch plan vectorized, and the strict-FIFO
    service term classified by one fused key sort per variant);
    ``"oracle"`` scores candidates one at a time through the staged
    pipeline. Both return bit-identical scores, tables and argmin
    (property-tested in ``tests/core/test_autotune.py``).
    """
    row_ids = np.asarray(row_ids)
    if engine not in ("batched", "oracle"):
        raise ValueError(f"unknown tune engine {engine!r} "
                         "(expected 'batched' or 'oracle')")
    best_cfg, best_cycles, table = None, float("inf"), []
    n_eval = 0
    cache_grid = (
        list(itertools.product(associativities, num_lines))
        if enable_cache else [(1, 256)])
    chan_grid = [(nc, pol) for nc in num_channels
                 for pol in (mapping_policies if nc > 1
                             else mapping_policies[:1])]
    sched_grid = sorted({
        ("fifo", 1) if (pol == "fifo" or win == 1) else (pol, win)
        for pol in dram_sched_policies for win in reorder_windows})
    # The cache-filtered stream — the expensive full-trace scan — depends
    # only on the cache shape and the channel mapping, not on batch/dma
    # axes: the CacheFilter stage memoizes it per (cache, channels) shape
    # across the whole grid via this shared dict.
    filter_memo: dict = {}
    scores = None
    if engine == "batched":
        scores = _batched_scores(
            row_ids, row_bytes, timings, batch_sizes=batch_sizes,
            cache_grid=cache_grid, chan_grid=chan_grid,
            sched_grid=sched_grid, starvation_cap=starvation_cap,
            enable_cache=enable_cache, filter_memo=filter_memo)

    for batch in batch_sizes:
        for ways, lines in cache_grid:
            if ways > lines:
                continue
            for ch in dma_channels:
                for nc, policy in chan_grid:
                    for spol, win in sched_grid:
                        cfg = MemoryControllerConfig(
                            scheduler=SchedulerConfig(batch_size=batch),
                            cache=CacheConfig(enabled=enable_cache,
                                              num_lines=lines,
                                              associativity=ways),
                            dma=DMAConfig(num_parallel_dma=ch),
                            channels=ChannelConfig(num_channels=nc,
                                                   policy=policy),
                            dram_sched=DRAMSchedConfig(
                                policy=spol, reorder_window=win,
                                starvation_cap=starvation_cap),
                        )
                        if cfg.vmem_footprint_bytes() > vmem_budget_bytes:
                            continue
                        n_eval += 1
                        if scores is not None:
                            cycles = float(cfg.ctrl_overhead_cycles) \
                                + scores[(batch, ways, lines, nc, policy,
                                          spol, win)]
                        else:
                            cycles = _score(cfg, row_ids, row_bytes,
                                            timings, memo=filter_memo)
                        table.append((
                            f"batch={batch} ways={ways} lines={lines} "
                            f"dma={ch} mem_ch={nc} map={policy} "
                            f"dsched={spol}:{win}",
                            cycles))
                        if cycles < best_cycles:
                            best_cfg, best_cycles = cfg, cycles
    if best_cfg is None:
        raise ValueError("no feasible configuration under the VMEM budget")
    return TuneResult(config=best_cfg, modeled_cycles=best_cycles,
                      candidates_evaluated=n_eval, table=table)


def sweep_serving_loads(
    config: MemoryControllerConfig,
    row_ids: np.ndarray,
    rw: np.ndarray | None,
    pe_id: np.ndarray | None,
    arrival_sweep: Sequence[np.ndarray],
    row_bytes: int,
    *,
    arbiter_policy: str = "round_robin",
    weights: Sequence[int] | None = None,
    faults: FaultConfig | None = None,
    timings: DRAMTimings = DDR4_2400,
) -> list:
    """Batched open-loop load sweep: one trace, many arrival processes.

    The ``perf_serving`` offered-load sweep re-ingests and re-validates
    the same trace once per load point when driven through
    ``MemoryController.simulate``; this evaluates the whole stacked
    sweep in one call — the request stream is built and validated once,
    and each load point swaps in its arrival stamps and runs the
    open-loop serving pipeline. Per-point :class:`PipelineResult`\\ s are
    bit-identical to the one-at-a-time path (property-tested in
    ``tests/core/test_autotune.py``).
    """
    base = RequestStream.from_rows(row_ids, rw, row_bytes=row_bytes,
                                   pe_id=pe_id)
    if len(base) == 0:
        raise ValueError("sweep_serving_loads got an empty trace")
    ports = config.num_pes if pe_id is not None else None
    results = []
    for arr in arrival_sweep:
        arr = np.asarray(arr, dtype=np.float64).ravel()
        if arr.shape[0] != len(base):
            raise ValueError("each arrival vector must have one entry "
                             "per request")
        if not np.isfinite(arr).all() or arr.min() < 0:
            raise ValueError(
                "arrival_cycle entries must be finite and >= 0")
        stream = dataclasses.replace(base, arrival_cycle=arr)
        ctx = PipelineContext.from_config(config, timings)
        ctx.scheduler = None
        ctx.open_loop = True
        if faults is not None:
            ctx.faults = faults
        stages = default_stages(ctx, ports=ports,
                                arbiter_policy=arbiter_policy,
                                weights=weights, cache=False)
        results.append(run_pipeline(stream, ctx, stages))
    return results


# ---------------------------------------------------------------------------
# SLO-constrained serving objective (open-loop, ARCHITECTURE §9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingTuneResult:
    config: MemoryControllerConfig
    arb_policy: str
    weights: tuple | None
    slo_p99_cycles: float        # achieved p99 of the SLO port
    makespan_cycles: float
    feasible: bool               # met the SLO target (if one was given)
    candidates_evaluated: int
    table: list                  # (summary, slo_p99, makespan) per candidate
    n_dropped: int = 0           # replay-exhausted drops of the winner


def _score_serving(cfg, row_ids, rw, pe_id, arrival, row_bytes, *,
                   num_ports, policy, weights, timings):
    """One serving candidate: open-loop pipeline, per-port sojourns."""
    stream = RequestStream.from_rows(row_ids, rw, row_bytes=row_bytes,
                                     pe_id=pe_id, arrival_cycle=arrival)
    ctx = PipelineContext.from_config(cfg, timings)
    ctx.scheduler = None
    ctx.open_loop = True
    stages = default_stages(ctx, ports=num_ports, arbiter_policy=policy,
                            weights=weights, cache=False)
    return run_pipeline(stream, ctx, stages)


def tune_serving(
    row_ids: np.ndarray,
    rw: np.ndarray | None,
    pe_id: np.ndarray,
    arrival_cycle: np.ndarray,
    row_bytes: int,
    *,
    num_ports: int,
    slo_port: int = 0,
    slo_p99_cycles: float | None = None,
    arb_policies: Sequence[str] = ("round_robin", "priority", "weighted"),
    weight_ratios: Sequence[int] = (2, 4, 8),
    dram_sched_policies: Sequence[str] = ("frfcfs", "frfcfs_cap"),
    reorder_windows: Sequence[int] = (16, 32),
    starvation_caps: Sequence[int] = (8, 16),
    faults: FaultConfig | None = None,
    max_replays_grid: Sequence[int] = (2, 4, 8),
    backoff_grid: Sequence[int] = (8, 32, 128),
    timings: DRAMTimings = DDR4_2400,
) -> ServingTuneResult:
    """Tune the QoS knobs for an open-loop multi-tenant trace.

    The objective is *constrained*: among candidates whose SLO port
    (``slo_port``) meets ``slo_p99_cycles`` p99 sojourn, pick the one
    with the best overall makespan (throughput); if none meets it — or
    no target is given — fall back to minimizing the SLO port's p99
    outright. ``weighted`` candidates favor the SLO port by each ratio
    in ``weight_ratios`` (other ports weight 1); ``frfcfs_cap``
    candidates sweep the starvation cap, the knob that bounds how long
    a reorder window may defer the SLO tenant's misses.

    Passing ``faults`` (an *active* :class:`FaultConfig`, i.e. an error
    storm to survive) adds the **retry-policy axis**: every arbitration
    × scheduler candidate is additionally swept over
    ``max_replays_grid`` × ``backoff_grid`` (replacing the seed
    config's ``max_replays`` / ``backoff_clocks``). Feasibility then
    also requires **zero replay-exhausted drops** — a dropped request
    has no real completion, so a config that meets the p99 target by
    giving up on requests is not meeting the SLO. Within that, the
    usual order applies: too few replays drops requests (infeasible),
    too many replays of a hard-failing cell burns bus time that the
    victim tenant's p99 pays for — the sweep finds the bounded middle.
    """
    row_ids = np.asarray(row_ids)
    arb_grid: list[tuple[str, tuple | None]] = []
    for pol in arb_policies:
        if pol == "weighted":
            for ratio in weight_ratios:
                w = [1] * num_ports
                w[slo_port] = int(ratio)
                arb_grid.append((pol, tuple(w)))
        else:
            arb_grid.append((pol, None))
    sched_grid = sorted({
        (pol, win, cap if pol == "frfcfs_cap" else 0)
        for pol in dram_sched_policies for win in reorder_windows
        for cap in (starvation_caps if pol == "frfcfs_cap" else (0,))})
    fault_grid: list[FaultConfig | None] = [None]
    if faults is not None and faults.active:
        fault_grid = [dataclasses.replace(faults, max_replays=mr,
                                          backoff_clocks=bo)
                      for mr in sorted(set(max_replays_grid))
                      for bo in sorted(set(backoff_grid))]

    best = None          # (feasible, key, result row)
    table = []
    n_eval = 0
    for (apol, w) in arb_grid:
        for (spol, win, cap) in sched_grid:
            for fc in fault_grid:
                cfg = MemoryControllerConfig(
                    dram_sched=DRAMSchedConfig(
                        policy=spol, reorder_window=win,
                        starvation_cap=cap or 16),
                    faults=fc)
                res = _score_serving(cfg, row_ids, rw, pe_id,
                                     arrival_cycle, row_bytes,
                                     num_ports=num_ports, policy=apol,
                                     weights=w, timings=timings)
                port = res.serving.per_port.get(slo_port)
                p99 = float(port["p99_sojourn"]) if port else 0.0
                mk = res.makespan_fpga_cycles
                drops = res.fault.n_dropped if res.fault is not None else 0
                n_eval += 1
                feasible = (slo_p99_cycles is None
                            or p99 <= slo_p99_cycles) and drops == 0
                table.append((f"arb={apol}{list(w) if w else ''} "
                              f"dsched={spol}:{win}"
                              + (f":cap{cap}" if cap else "")
                              + (f" retry={fc.max_replays}"
                                 f"/bo{fc.backoff_clocks}" if fc else ""),
                              p99, mk))
                # constrained order: feasible beats infeasible; within
                # feasible minimize makespan, within infeasible drops
                # dominate (a drop is an unserved request), then p99
                key = (0, mk, p99) if feasible else (1, drops, p99, mk)
                if best is None or key < best[0]:
                    best = (key, cfg, apol, w, p99, mk, feasible, drops)
    assert best is not None
    _, cfg, apol, w, p99, mk, feasible, drops = best
    return ServingTuneResult(
        config=cfg, arb_policy=apol, weights=w,
        slo_p99_cycles=p99, makespan_cycles=mk,
        feasible=feasible and slo_p99_cycles is not None,
        candidates_evaluated=n_eval, table=table, n_dropped=drops)
