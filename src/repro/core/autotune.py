"""Timing-model-driven parameter autotuner (the paper's TUNE column).

The paper tunes batch size, scheduler timeout, associativity and DMA
parallelism by hand against a target workload. We close the loop: given a
representative request trace and a resource (VMEM) budget, enumerate the
TUNE-class parameter grid, score each candidate with the analytic/simulated
timing model, and return the best feasible configuration. This is what
"programmable" buys over a fixed commercial IP: the controller is
re-specialized per application in seconds.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.config import (CacheConfig, ChannelConfig, DMAConfig,
                               DRAMSchedConfig, FaultConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.pipeline import (PipelineContext, RequestStream,
                                 default_stages, run_pipeline)
from repro.core.timing import DRAMTimings, DDR4_2400


@dataclasses.dataclass
class TuneResult:
    config: MemoryControllerConfig
    modeled_cycles: float
    candidates_evaluated: int
    table: list  # (config summary, cycles) per candidate, for reporting


def _score(
    cfg: MemoryControllerConfig,
    row_ids: np.ndarray,
    row_bytes: int,
    timings: DRAMTimings,
    memo: dict | None = None,
) -> float:
    """Modeled total access cycles for an irregular trace under ``cfg`` —
    the full staged pipeline's ``makespan_fpga_cycles``.

    Cache hits are served on-chip and *removed* from the DRAM stream
    (CacheFilter); misses flow through the per-channel schedulers to the
    channel-parallel DRAM service, so the DRAM term is the multi-channel
    makespan; only the non-overlapped scheduling residual is exposed
    (DMAOverlap). Scoring the composed pipeline is what lets ``tune``
    search cache geometry × num_channels × mapping policy *jointly*
    instead of by independent oracles. ``memo`` is the CacheFilter's
    shared cache, keyed by cache×channel shape (one expensive trace scan
    per shape across the whole grid).
    """
    stream = RequestStream.from_rows(row_ids, row_bytes=row_bytes)
    ctx = PipelineContext.from_config(cfg, timings)
    stages = default_stages(ctx, cache=True, cache_memo=memo)
    return run_pipeline(stream, ctx, stages).makespan_fpga_cycles


def tune(
    row_ids: np.ndarray,
    row_bytes: int,
    *,
    vmem_budget_bytes: int = 8 << 20,
    batch_sizes: Sequence[int] = (4, 8, 16, 32, 64, 128, 256, 512),
    associativities: Sequence[int] = (1, 2, 4, 8),
    num_lines: Sequence[int] = (1024, 4096, 16384),
    dma_channels: Sequence[int] = (1, 2, 4, 8),
    num_channels: Sequence[int] = (1,),
    mapping_policies: Sequence[str] = ("row_interleave",),
    dram_sched_policies: Sequence[str] = ("fifo",),
    reorder_windows: Sequence[int] = (1,),
    starvation_cap: int = 16,
    enable_cache: bool = True,
    timings: DRAMTimings = DDR4_2400,
) -> TuneResult:
    """Grid-search TUNE parameters for a trace under a VMEM budget.

    ``num_channels`` × ``mapping_policies`` extend the grid with the
    multi-channel front end's axes (``ChannelConfig``); the defaults keep
    the paper's single-interface search space. With one channel every
    mapping policy is the identity, so only the first policy is scored.

    ``dram_sched_policies`` × ``reorder_windows`` add the DRAM command
    scheduler's axes (``DRAMSchedConfig``): FIFO never reorders, so it
    is scored at one window only, and window 1 collapses every policy
    to FIFO — redundant grid points are deduplicated before scoring.
    """
    row_ids = np.asarray(row_ids)
    best_cfg, best_cycles, table = None, float("inf"), []
    n_eval = 0
    cache_grid = (
        list(itertools.product(associativities, num_lines))
        if enable_cache else [(1, 256)])
    chan_grid = [(nc, pol) for nc in num_channels
                 for pol in (mapping_policies if nc > 1
                             else mapping_policies[:1])]
    sched_grid = sorted({
        ("fifo", 1) if (pol == "fifo" or win == 1) else (pol, win)
        for pol in dram_sched_policies for win in reorder_windows})
    # The cache-filtered stream — the expensive full-trace scan — depends
    # only on the cache shape and the channel mapping, not on batch/dma
    # axes: the CacheFilter stage memoizes it per (cache, channels) shape
    # across the whole grid via this shared dict.
    filter_memo: dict = {}

    for batch in batch_sizes:
        for ways, lines in cache_grid:
            if ways > lines:
                continue
            for ch in dma_channels:
                for nc, policy in chan_grid:
                    for spol, win in sched_grid:
                        cfg = MemoryControllerConfig(
                            scheduler=SchedulerConfig(batch_size=batch),
                            cache=CacheConfig(enabled=enable_cache,
                                              num_lines=lines,
                                              associativity=ways),
                            dma=DMAConfig(num_parallel_dma=ch),
                            channels=ChannelConfig(num_channels=nc,
                                                   policy=policy),
                            dram_sched=DRAMSchedConfig(
                                policy=spol, reorder_window=win,
                                starvation_cap=starvation_cap),
                        )
                        if cfg.vmem_footprint_bytes() > vmem_budget_bytes:
                            continue
                        n_eval += 1
                        cycles = _score(cfg, row_ids, row_bytes, timings,
                                        memo=filter_memo)
                        table.append((
                            f"batch={batch} ways={ways} lines={lines} "
                            f"dma={ch} mem_ch={nc} map={policy} "
                            f"dsched={spol}:{win}",
                            cycles))
                        if cycles < best_cycles:
                            best_cfg, best_cycles = cfg, cycles
    if best_cfg is None:
        raise ValueError("no feasible configuration under the VMEM budget")
    return TuneResult(config=best_cfg, modeled_cycles=best_cycles,
                      candidates_evaluated=n_eval, table=table)


# ---------------------------------------------------------------------------
# SLO-constrained serving objective (open-loop, ARCHITECTURE §9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingTuneResult:
    config: MemoryControllerConfig
    arb_policy: str
    weights: tuple | None
    slo_p99_cycles: float        # achieved p99 of the SLO port
    makespan_cycles: float
    feasible: bool               # met the SLO target (if one was given)
    candidates_evaluated: int
    table: list                  # (summary, slo_p99, makespan) per candidate
    n_dropped: int = 0           # replay-exhausted drops of the winner


def _score_serving(cfg, row_ids, rw, pe_id, arrival, row_bytes, *,
                   num_ports, policy, weights, timings):
    """One serving candidate: open-loop pipeline, per-port sojourns."""
    stream = RequestStream.from_rows(row_ids, rw, row_bytes=row_bytes,
                                     pe_id=pe_id, arrival_cycle=arrival)
    ctx = PipelineContext.from_config(cfg, timings)
    ctx.scheduler = None
    ctx.open_loop = True
    stages = default_stages(ctx, ports=num_ports, arbiter_policy=policy,
                            weights=weights, cache=False)
    return run_pipeline(stream, ctx, stages)


def tune_serving(
    row_ids: np.ndarray,
    rw: np.ndarray | None,
    pe_id: np.ndarray,
    arrival_cycle: np.ndarray,
    row_bytes: int,
    *,
    num_ports: int,
    slo_port: int = 0,
    slo_p99_cycles: float | None = None,
    arb_policies: Sequence[str] = ("round_robin", "priority", "weighted"),
    weight_ratios: Sequence[int] = (2, 4, 8),
    dram_sched_policies: Sequence[str] = ("frfcfs", "frfcfs_cap"),
    reorder_windows: Sequence[int] = (16, 32),
    starvation_caps: Sequence[int] = (8, 16),
    faults: FaultConfig | None = None,
    max_replays_grid: Sequence[int] = (2, 4, 8),
    backoff_grid: Sequence[int] = (8, 32, 128),
    timings: DRAMTimings = DDR4_2400,
) -> ServingTuneResult:
    """Tune the QoS knobs for an open-loop multi-tenant trace.

    The objective is *constrained*: among candidates whose SLO port
    (``slo_port``) meets ``slo_p99_cycles`` p99 sojourn, pick the one
    with the best overall makespan (throughput); if none meets it — or
    no target is given — fall back to minimizing the SLO port's p99
    outright. ``weighted`` candidates favor the SLO port by each ratio
    in ``weight_ratios`` (other ports weight 1); ``frfcfs_cap``
    candidates sweep the starvation cap, the knob that bounds how long
    a reorder window may defer the SLO tenant's misses.

    Passing ``faults`` (an *active* :class:`FaultConfig`, i.e. an error
    storm to survive) adds the **retry-policy axis**: every arbitration
    × scheduler candidate is additionally swept over
    ``max_replays_grid`` × ``backoff_grid`` (replacing the seed
    config's ``max_replays`` / ``backoff_clocks``). Feasibility then
    also requires **zero replay-exhausted drops** — a dropped request
    has no real completion, so a config that meets the p99 target by
    giving up on requests is not meeting the SLO. Within that, the
    usual order applies: too few replays drops requests (infeasible),
    too many replays of a hard-failing cell burns bus time that the
    victim tenant's p99 pays for — the sweep finds the bounded middle.
    """
    row_ids = np.asarray(row_ids)
    arb_grid: list[tuple[str, tuple | None]] = []
    for pol in arb_policies:
        if pol == "weighted":
            for ratio in weight_ratios:
                w = [1] * num_ports
                w[slo_port] = int(ratio)
                arb_grid.append((pol, tuple(w)))
        else:
            arb_grid.append((pol, None))
    sched_grid = sorted({
        (pol, win, cap if pol == "frfcfs_cap" else 0)
        for pol in dram_sched_policies for win in reorder_windows
        for cap in (starvation_caps if pol == "frfcfs_cap" else (0,))})
    fault_grid: list[FaultConfig | None] = [None]
    if faults is not None and faults.active:
        fault_grid = [dataclasses.replace(faults, max_replays=mr,
                                          backoff_clocks=bo)
                      for mr in sorted(set(max_replays_grid))
                      for bo in sorted(set(backoff_grid))]

    best = None          # (feasible, key, result row)
    table = []
    n_eval = 0
    for (apol, w) in arb_grid:
        for (spol, win, cap) in sched_grid:
            for fc in fault_grid:
                cfg = MemoryControllerConfig(
                    dram_sched=DRAMSchedConfig(
                        policy=spol, reorder_window=win,
                        starvation_cap=cap or 16),
                    faults=fc)
                res = _score_serving(cfg, row_ids, rw, pe_id,
                                     arrival_cycle, row_bytes,
                                     num_ports=num_ports, policy=apol,
                                     weights=w, timings=timings)
                port = res.serving.per_port.get(slo_port)
                p99 = float(port["p99_sojourn"]) if port else 0.0
                mk = res.makespan_fpga_cycles
                drops = res.fault.n_dropped if res.fault is not None else 0
                n_eval += 1
                feasible = (slo_p99_cycles is None
                            or p99 <= slo_p99_cycles) and drops == 0
                table.append((f"arb={apol}{list(w) if w else ''} "
                              f"dsched={spol}:{win}"
                              + (f":cap{cap}" if cap else "")
                              + (f" retry={fc.max_replays}"
                                 f"/bo{fc.backoff_clocks}" if fc else ""),
                              p99, mk))
                # constrained order: feasible beats infeasible; within
                # feasible minimize makespan, within infeasible drops
                # dominate (a drop is an unserved request), then p99
                key = (0, mk, p99) if feasible else (1, drops, p99, mk)
                if best is None or key < best[0]:
                    best = (key, cfg, apol, w, p99, mk, feasible, drops)
    assert best is not None
    _, cfg, apol, w, p99, mk, feasible, drops = best
    return ServingTuneResult(
        config=cfg, arb_policy=apol, weights=w,
        slo_p99_cycles=p99, makespan_cycles=mk,
        feasible=feasible and slo_p99_cycles is not None,
        candidates_evaluated=n_eval, table=table, n_dropped=drops)
