"""Timing-model-driven parameter autotuner (the paper's TUNE column).

The paper tunes batch size, scheduler timeout, associativity and DMA
parallelism by hand against a target workload. We close the loop: given a
representative request trace and a resource (VMEM) budget, enumerate the
TUNE-class parameter grid, score each candidate with the analytic/simulated
timing model, and return the best feasible configuration. This is what
"programmable" buys over a fixed commercial IP: the controller is
re-specialized per application in seconds.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.cache_engine import hit_rate_oracle
from repro.core.channels import schedule_and_simulate_channels
from repro.core.config import (CacheConfig, ChannelConfig, DMAConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.timing import DRAMTimings, DDR4_2400, t_schedule


@dataclasses.dataclass
class TuneResult:
    config: MemoryControllerConfig
    modeled_cycles: float
    candidates_evaluated: int
    table: list  # (config summary, cycles) per candidate, for reporting


def _score(
    cfg: MemoryControllerConfig,
    row_ids: np.ndarray,
    row_bytes: int,
    timings: DRAMTimings,
    hits: np.ndarray | None = None,
) -> float:
    """Modeled total access cycles for an irregular trace under ``cfg``.

    Cache hits are served on-chip (1 cycle); misses flow through the
    scheduler to DRAM. Batch scheduling adds Eq. 1 latency per batch but
    only the *first* batch is exposed (subsequent batch formation overlaps
    DRAM service — paper Fig. 9 discussion). Misses are decomposed by the
    configured AddressMap and serviced channel-parallel: the DRAM term is
    the multi-channel *makespan* (slowest channel).
    """
    addrs = row_ids.astype(np.int64) * row_bytes
    if hits is None:        # precomputable per cache shape — see tune()
        if cfg.cache.enabled:
            hits, _ = hit_rate_oracle(cfg.cache,
                                      addrs // cfg.cache.line_bytes)
        else:
            hits = np.zeros(addrs.shape[0], dtype=bool)
    miss_addrs = addrs[~hits]

    dram = schedule_and_simulate_channels(
        miss_addrs, sched_config=cfg.scheduler, timings=timings,
        channel_cfg=cfg.channels)

    n_batches = max(1, -(-miss_addrs.shape[0] // cfg.scheduler.batch_size))
    first_batch = t_schedule(cfg.scheduler.batch_size) if \
        cfg.scheduler.enabled else 0.0
    # Residual (non-overlapped) scheduling cost per subsequent batch: the
    # sort stages not hidden behind DRAM service of the previous batch.
    resid = 0.0 if not cfg.scheduler.enabled else max(
        0.0, t_schedule(cfg.scheduler.batch_size)
        - dram.total_fpga_cycles / n_batches) * (n_batches - 1)
    return (cfg.ctrl_overhead_cycles + first_batch + resid
            + hits.sum() * 1.0 + dram.total_fpga_cycles)


def tune(
    row_ids: np.ndarray,
    row_bytes: int,
    *,
    vmem_budget_bytes: int = 8 << 20,
    batch_sizes: Sequence[int] = (4, 8, 16, 32, 64, 128, 256, 512),
    associativities: Sequence[int] = (1, 2, 4, 8),
    num_lines: Sequence[int] = (1024, 4096, 16384),
    dma_channels: Sequence[int] = (1, 2, 4, 8),
    num_channels: Sequence[int] = (1,),
    mapping_policies: Sequence[str] = ("row_interleave",),
    enable_cache: bool = True,
    timings: DRAMTimings = DDR4_2400,
) -> TuneResult:
    """Grid-search TUNE parameters for a trace under a VMEM budget.

    ``num_channels`` × ``mapping_policies`` extend the grid with the
    multi-channel front end's axes (``ChannelConfig``); the defaults keep
    the paper's single-interface search space. With one channel every
    mapping policy is the identity, so only the first policy is scored.
    """
    row_ids = np.asarray(row_ids)
    best_cfg, best_cycles, table = None, float("inf"), []
    n_eval = 0
    cache_grid = (
        list(itertools.product(associativities, num_lines))
        if enable_cache else [(1, 256)])
    chan_grid = [(nc, pol) for nc in num_channels
                 for pol in (mapping_policies if nc > 1
                             else mapping_policies[:1])]
    # The LRU hit mask — the expensive full-trace scan — depends only on
    # the cache shape, not on batch/dma/channel axes: compute it once per
    # (ways, lines) instead of once per grid point.
    hits_by_shape: dict[tuple[int, int], np.ndarray] = {}

    def _hits(cache_cfg: CacheConfig) -> np.ndarray:
        key = (cache_cfg.associativity, cache_cfg.num_lines)
        if key not in hits_by_shape:
            if cache_cfg.enabled:
                addrs = row_ids.astype(np.int64) * row_bytes
                hits_by_shape[key] = hit_rate_oracle(
                    cache_cfg, addrs // cache_cfg.line_bytes)[0]
            else:
                hits_by_shape[key] = np.zeros(row_ids.shape[0], bool)
        return hits_by_shape[key]

    for batch in batch_sizes:
        for ways, lines in cache_grid:
            if ways > lines:
                continue
            for ch in dma_channels:
                for nc, policy in chan_grid:
                    cfg = MemoryControllerConfig(
                        scheduler=SchedulerConfig(batch_size=batch),
                        cache=CacheConfig(enabled=enable_cache,
                                          num_lines=lines,
                                          associativity=ways),
                        dma=DMAConfig(num_parallel_dma=ch),
                        channels=ChannelConfig(num_channels=nc,
                                               policy=policy),
                    )
                    if cfg.vmem_footprint_bytes() > vmem_budget_bytes:
                        continue
                    n_eval += 1
                    cycles = _score(cfg, row_ids, row_bytes, timings,
                                    hits=_hits(cfg.cache))
                    table.append((
                        f"batch={batch} ways={ways} lines={lines} "
                        f"dma={ch} mem_ch={nc} map={policy}",
                        cycles))
                    if cycles < best_cycles:
                        best_cfg, best_cycles = cfg, cycles
    if best_cfg is None:
        raise ValueError("no feasible configuration under the VMEM budget")
    return TuneResult(config=best_cfg, modeled_cycles=best_cycles,
                      candidates_evaluated=n_eval, table=table)
