"""Memory-controller configuration — the paper's Table I as a validated config.

The paper exposes every controller knob as a synthesis-time HDL parameter.
Here the same knobs are resolved at *trace/compile time*: a
``MemoryControllerConfig`` is carried into jitted functions as static
structure, so changing a parameter re-specializes the compiled program the
way re-synthesis re-specializes the FPGA bitstream.

Dependency classes mirror Table I:
  PL   — platform (TPU generation / memory interface) constraints,
  RS   — resource (VMEM budget) constraints,
  SPEC — functional specification of the attached accelerator (model),
  TUNE — tunable; ``repro.core.autotune`` searches these.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


def _check_range(name: str, value: int, lo: int, hi: int) -> None:
    if not lo <= value <= hi:
        raise ValueError(
            f"{name}={value} outside supported range [{lo}, {hi}] "
            "(see Table I of the paper)"
        )


def _check_pow2(name: str, value: int) -> None:
    if value < 1 or value & (value - 1):
        raise ValueError(f"{name}={value} must be a power of two")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Memory scheduler parameters (Table I, 'Memory Scheduler')."""

    enabled: bool = True
    # Max requests reordered per batch. Paper range 4-128; Fig. 6 explores up
    # to 512 before resource use becomes impractical. [TUNE]
    batch_size: int = 64
    # Max cycles spent on batch formation before a partial batch is issued.
    # Prevents deadlock under low traffic. [TUNE]
    timeout_cycles: int = 16
    # Bypass scheduling when the incoming stream is already sequential or
    # traffic is low (paper §V-C).
    bypass_sequential: bool = True
    # Parallel<->serial data conditioning latency around the sorting network
    # (paper: < 2 cycles).
    data_cond_cycles: int = 2

    def __post_init__(self) -> None:
        _check_range("scheduler.batch_size", self.batch_size, 4, 512)
        _check_pow2("scheduler.batch_size", self.batch_size)
        _check_range("scheduler.timeout_cycles", self.timeout_cycles, 4, 40)


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Cache engine parameters (Table I, 'Cache')."""

    enabled: bool = True
    # Cache line width in *bits* to match the paper's table (256-1024 typical;
    # Table III explores to 4096).
    line_width_bits: int = 512
    num_lines: int = 4096
    # Degree of set-associativity. [TUNE]
    associativity: int = 4
    # Write policy for WRITE requests (write-allocate both ways):
    # "write_back" keeps dirty lines in Data RAM until eviction (victim
    # flush on the MEM pipeline), "write_through" mirrors every write to
    # DRAM immediately. [TUNE]
    write_policy: str = "write_back"

    def __post_init__(self) -> None:
        _check_range("cache.line_width_bits", self.line_width_bits, 256, 4096)
        _check_range("cache.num_lines", self.num_lines, 256, 32768)
        _check_range("cache.associativity", self.associativity, 1, 16)
        _check_pow2("cache.num_lines", self.num_lines)
        _check_pow2("cache.associativity", self.associativity)
        if self.associativity > self.num_lines:
            raise ValueError("associativity cannot exceed num_lines")
        if self.write_policy not in ("write_back", "write_through"):
            raise ValueError(
                f"cache.write_policy={self.write_policy!r} must be "
                "'write_back' or 'write_through'")

    @property
    def line_bytes(self) -> int:
        return self.line_width_bits // 8

    @property
    def num_sets(self) -> int:
        return self.num_lines // self.associativity

    @property
    def capacity_bytes(self) -> int:
        return self.num_lines * self.line_bytes


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Memory-channel / address-mapping parameters (Table-I-style front end).

    The paper's controller is synthesized against one memory interface;
    HBM-class parts expose several independent channels behind the same
    address space. These knobs pick how the flat physical address is
    decomposed into (channel, bank, row) — the choice that the Memory
    Controller Wall study (arXiv:1910.06726) shows dominates sustained
    bandwidth on FPGA memory interfaces. [PL+TUNE]
    """

    #: independent DRAM channels simulated in parallel (1 = the paper's
    #: single-interface design; 8 covers HBM2 stack halves).
    num_channels: int = 1
    #: block-interleave granularity in bytes — consecutive blocks of this
    #: size round-robin across channels (ignored by "row_interleave",
    #: which interleaves at DRAM-row granularity).
    interleave_bytes: int = 256
    #: channel-select policy:
    #:   "row_interleave"   — consecutive DRAM rows rotate channels,
    #:   "block_interleave" — consecutive interleave_bytes blocks rotate,
    #:   "xor"              — block index XOR-folded with higher address
    #:                        bits (breaks power-of-two stride camping).
    policy: str = "row_interleave"

    _POLICIES = ("row_interleave", "block_interleave", "xor")

    def __post_init__(self) -> None:
        _check_range("channels.num_channels", self.num_channels, 1, 16)
        _check_pow2("channels.num_channels", self.num_channels)
        _check_range("channels.interleave_bytes", self.interleave_bytes,
                     64, 1 << 20)
        _check_pow2("channels.interleave_bytes", self.interleave_bytes)
        if self.policy not in self._POLICIES:
            raise ValueError(
                f"channels.policy={self.policy!r} must be one of "
                f"{self._POLICIES}")


@dataclasses.dataclass(frozen=True)
class DRAMSchedConfig:
    """DRAM command-scheduler parameters (the controller's back end).

    The front-end batch scheduler reorders *requests* before they reach
    the memory interface; this config governs how the interface itself
    issues *DRAM commands* out of its pending queue — the reordering
    class "The Memory Controller Wall" (arXiv:1910.06726) shows
    separates naive interface IPs from real controllers. [TUNE]

    ``policy``:
      "fifo"        — strict arrival order (the pre-scheduler model);
      "frfcfs"      — first-ready, first-come-first-served: within a
                      ``reorder_window`` lookahead, the oldest request
                      that hits an already-open row is issued first;
                      misses are issued oldest-first when no pending
                      request is row-ready;
      "frfcfs_cap"  — FR-FCFS with a starvation cap: once
                      ``starvation_cap`` younger requests have been
                      issued past a waiting request, it is forced out
                      next (bounds per-request slip; property-tested).

    ``t_rfc`` / ``t_refi`` model refresh (in DRAM command clocks):
    every ``t_refi`` cycles of service a channel stalls for ``t_rfc``
    and all its banks precharge (open rows close). ``t_refi=0``
    disables refresh (the pre-refresh model).
    """

    policy: str = "fifo"
    #: lookahead window (pending DRAM commands eligible for promotion).
    #: 1 degenerates to FIFO regardless of policy.
    reorder_window: int = 1
    #: max younger issues past a waiting request before it is forced
    #: (only consulted by "frfcfs_cap").
    starvation_cap: int = 16
    #: refresh cycle time (stall per refresh), DRAM clocks.
    t_rfc: int = 0
    #: average refresh interval, DRAM clocks; 0 disables refresh.
    t_refi: int = 0

    _POLICIES = ("fifo", "frfcfs", "frfcfs_cap")

    def __post_init__(self) -> None:
        if self.policy not in self._POLICIES:
            raise ValueError(
                f"dram_sched.policy={self.policy!r} must be one of "
                f"{self._POLICIES}")
        _check_range("dram_sched.reorder_window", self.reorder_window,
                     1, 512)
        _check_range("dram_sched.starvation_cap", self.starvation_cap,
                     1, 1 << 20)
        if self.t_rfc < 0 or self.t_refi < 0:
            raise ValueError("dram_sched t_rfc/t_refi must be >= 0")
        if self.t_refi and self.t_rfc >= self.t_refi:
            raise ValueError(
                f"dram_sched.t_rfc={self.t_rfc} must be strictly less "
                f"than t_refi={self.t_refi}: the channel would refresh "
                "longer than it services")

    @property
    def effective_window(self) -> int:
        """The window actually applied: FIFO never reorders."""
        return 1 if self.policy == "fifo" else self.reorder_window


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """RAS / fault-injection parameters (the controller's reliability
    back end — ARCHITECTURE.md §10).

    Real DDR4/HBM parts ship ECC, write-CRC retry and refresh-rate
    escalation because the controller must keep serving through faults;
    this config drives a *deterministic, seeded* fault model on the DRAM
    service stream plus the controller's response policies. All
    injection is a pure function of ``(seed, channel, request index,
    attempt)`` — re-running a trace reproduces the same storm
    bit-for-bit.

    Injection knobs:
      ``transient_ber``      — per-access transient error probability;
      ``weak_row_fraction``  — fraction of DRAM rows that are weak
                               (chosen by a seeded hash of the row id);
      ``weak_row_ber``       — *additional* per-access error
                               probability on weak rows (hot spots);
      ``outage_windows``     — ``(channel, start, end)`` intervals in
                               DRAM clocks during which that channel
                               cannot issue (transient outage: pending
                               work stalls, nothing is dropped);
      ``failed_channels``    — channels failed for the whole run; the
                               ``AddressMap`` re-maps their traffic to
                               the surviving channels.

    Error-handling knobs:
      ``ecc``                — "secded" detects every injected error
                               and corrects the non-DUE ones at
                               ``ecc_correction_clocks`` per corrected
                               access; "none" makes read errors silent;
      ``due_fraction``       — fraction of detected errors that exceed
                               SECDED correction (reads only) and must
                               be replayed;
      ``write_crc``          — when True, errored writes fail the link
                               CRC and replay; when False they are
                               silent corruption;
      ``max_replays``        — bound on replays per request; a request
                               whose last allowed attempt still errors
                               is counted *dropped* (surfaced in
                               ``FaultStats``, never silently lost);
      ``backoff_clocks``     — base replay backoff in DRAM clocks,
                               doubling per failed attempt
                               (``backoff << (attempt-1)``); 0 replays
                               immediately (the naive policy).

    Degradation knobs:
      ``row_retire_threshold``     — errors charged to one row before
                                     it is retired to a spare (0 off);
      ``max_retired_rows``         — spare rows per channel;
      ``refresh_escalate_threshold`` — injected errors per escalation
                                     level: each level halves the
                                     effective ``t_refi`` (0 off);
      ``refresh_escalate_max``     — cap on escalation levels.
    """

    seed: int = 0
    transient_ber: float = 0.0
    weak_row_fraction: float = 0.0
    weak_row_ber: float = 0.0
    due_fraction: float = 0.0
    ecc: str = "secded"
    ecc_correction_clocks: int = 4
    write_crc: bool = True
    max_replays: int = 4
    backoff_clocks: int = 16
    row_retire_threshold: int = 0
    max_retired_rows: int = 64
    refresh_escalate_threshold: int = 0
    refresh_escalate_max: int = 3
    failed_channels: tuple = ()
    outage_windows: tuple = ()

    _ECC = ("none", "secded")

    def __post_init__(self) -> None:
        for name in ("transient_ber", "weak_row_fraction", "weak_row_ber",
                     "due_fraction"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"faults.{name}={v} must be in [0, 1]")
        if self.ecc not in self._ECC:
            raise ValueError(
                f"faults.ecc={self.ecc!r} must be one of {self._ECC}")
        _check_range("faults.ecc_correction_clocks",
                     self.ecc_correction_clocks, 0, 1 << 10)
        _check_range("faults.max_replays", self.max_replays, 0, 64)
        _check_range("faults.backoff_clocks", self.backoff_clocks,
                     0, 1 << 20)
        _check_range("faults.row_retire_threshold",
                     self.row_retire_threshold, 0, 1 << 20)
        _check_range("faults.max_retired_rows", self.max_retired_rows,
                     0, 1 << 16)
        _check_range("faults.refresh_escalate_threshold",
                     self.refresh_escalate_threshold, 0, 1 << 30)
        _check_range("faults.refresh_escalate_max",
                     self.refresh_escalate_max, 0, 8)
        if self.seed < 0:
            raise ValueError("faults.seed must be >= 0")
        for ch in self.failed_channels:
            if not isinstance(ch, int) or ch < 0:
                raise ValueError(
                    "faults.failed_channels must be non-negative channel "
                    "indices")
        if len(set(self.failed_channels)) != len(self.failed_channels):
            raise ValueError("faults.failed_channels has duplicates")
        for win in self.outage_windows:
            if (len(win) != 3 or any(int(x) != x for x in win)
                    or win[0] < 0 or win[1] < 0 or win[2] <= win[1]):
                raise ValueError(
                    f"faults.outage_windows entry {win!r} must be "
                    "(channel, start, end) with 0 <= start < end in "
                    "DRAM clocks")

    @property
    def injects(self) -> bool:
        """True when the service stream can see any injected event
        (errors or transient outage stalls)."""
        return bool(self.transient_ber > 0.0
                    or (self.weak_row_fraction > 0.0
                        and self.weak_row_ber > 0.0)
                    or self.outage_windows)

    @property
    def active(self) -> bool:
        """True when the fault layer changes *anything* about the run;
        False degenerates bit-identically to the fault-free pipeline."""
        return self.injects or bool(self.failed_channels)

    def backoff_for(self, attempt: int) -> int:
        """Backoff in DRAM clocks before replay number ``attempt``
        (1-based), doubling per failed attempt."""
        return self.backoff_clocks << max(0, attempt - 1)

    def outage_windows_for(self, channel: int) -> list[tuple[int, int]]:
        """Sorted ``(start, end)`` outage intervals for one channel."""
        return sorted((int(s), int(e)) for ch, s, e in self.outage_windows
                      if int(ch) == channel)


@dataclasses.dataclass(frozen=True)
class DMAConfig:
    """DMA engine parameters (Table I, 'Direct Memory Access')."""

    enabled: bool = True
    # Largest single bulk transaction (256B - 256KB).
    max_transaction_bytes: int = 16384
    # Number of parallel DMA buffers/channels (1-8). On TPU this is the
    # depth of in-flight async HBM copies. [SPEC+TUNE]
    num_parallel_dma: int = 4
    # Staging buffer per channel; on TPU this is VMEM occupied per channel.
    buffer_bytes: int = 16384

    def __post_init__(self) -> None:
        _check_range("dma.max_transaction_bytes", self.max_transaction_bytes,
                     256, 256 * 1024)
        _check_range("dma.num_parallel_dma", self.num_parallel_dma, 1, 8)
        _check_range("dma.buffer_bytes", self.buffer_bytes, 256, 1 << 20)


@dataclasses.dataclass(frozen=True)
class MemoryControllerConfig:
    """Top-level controller config (paper Table I, 'Overall Design')."""

    # --- platform (PL) ---
    # External memory interface width. DDR4 on U250 is 64B (512b); TPU v5e
    # HBM transactions are modeled at 512B bursts.
    mem_if_data_width_bytes: int = 512
    mem_if_addr_width: int = 31
    # --- application spec (SPEC) ---
    app_io_data_width_bytes: int = 64
    app_addr_width: int = 32
    num_pes: int = 8
    # --- engines ---
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    dma: DMAConfig = dataclasses.field(default_factory=DMAConfig)
    channels: ChannelConfig = dataclasses.field(default_factory=ChannelConfig)
    dram_sched: DRAMSchedConfig = dataclasses.field(
        default_factory=DRAMSchedConfig)
    #: RAS / fault-injection model; ``None`` (or an all-zero-rate
    #: config) is the perfectly-reliable device and degenerates
    #: bit-identically to the fault-free pipeline.
    faults: Optional[FaultConfig] = None
    # FLIT generation + path-selection latency budget (paper: <= 10 cycles).
    ctrl_overhead_cycles: int = 10

    def __post_init__(self) -> None:
        _check_range("mem_if_data_width_bytes", self.mem_if_data_width_bytes,
                     64, 512)
        _check_range("mem_if_addr_width", self.mem_if_addr_width, 20, 36)
        _check_range("app_io_data_width_bytes", self.app_io_data_width_bytes,
                     1, 512)
        _check_range("app_addr_width", self.app_addr_width, 20, 40)
        _check_range("num_pes", self.num_pes, 1, 128)
        _check_range("ctrl_overhead_cycles", self.ctrl_overhead_cycles, 0, 10)
        if not (self.scheduler.enabled or self.cache.enabled
                or self.dma.enabled):
            raise ValueError(
                "at least one engine (scheduler/cache/dma) must be enabled")
        if self.faults is not None:
            nch = self.channels.num_channels
            bad = [c for c in self.faults.failed_channels if c >= nch]
            if bad:
                raise ValueError(
                    f"faults.failed_channels {bad} outside "
                    f"[0, num_channels={nch})")
            if len(self.faults.failed_channels) >= nch:
                raise ValueError(
                    "faults.failed_channels would fail every channel — "
                    "at least one must survive")
            bad = [w for w in self.faults.outage_windows if w[0] >= nch]
            if bad:
                raise ValueError(
                    f"faults.outage_windows channels {bad} outside "
                    f"[0, num_channels={nch})")

    # ---- derived resource model (paper §V-B analogue) --------------------
    def vmem_footprint_bytes(self) -> int:
        """On-chip (VMEM) bytes claimed by the configured engines.

        FPGA URAM/BRAM consumption (Table III / Fig. 5 / Fig. 6) maps to the
        VMEM working set on TPU. Used by benchmarks and by the autotuner's
        resource constraint.
        """
        total = 0
        if self.cache.enabled:
            # data + tags (tag ~ 4B/line) + LRU age (4B/line)
            total += self.cache.capacity_bytes + 8 * self.cache.num_lines
        if self.dma.enabled:
            # double-buffered staging per channel
            total += 2 * self.dma.num_parallel_dma * self.dma.buffer_bytes
        if self.scheduler.enabled:
            # key/value pairs being sorted, double-buffered input queues —
            # replicated per memory channel (each channel owns a scheduler
            # front end; one channel is the paper's single-interface case).
            n = self.scheduler.batch_size
            total += self.channels.num_channels * (
                2 * n * 8 + 2 * n * self.app_io_data_width_bytes)
        # DRAM command scheduler: each channel holds a reorder CAM of
        # pending commands (addr tag + bank/row decode + age counter,
        # ~16B per entry). A 1-deep window is the plain FIFO head.
        total += (self.channels.num_channels
                  * self.dram_sched.effective_window * 16)
        if self.faults is not None and self.faults.active:
            # RAS state per channel: replay CAM (bounded by the reorder
            # window, addr tag + attempt counter + ready stamp ~ 24B),
            # the row-retirement indirection CAM (row tag + spare id,
            # 16B per retirable row) and an error-counter CAM of the
            # same depth.
            total += self.channels.num_channels * (
                self.dram_sched.effective_window * 24
                + self.faults.max_retired_rows * 24)
        return total

    def describe(self) -> str:
        lines = [
            "MemoryControllerConfig:",
            f"  mem-if {self.mem_if_data_width_bytes}B / "
            f"addr {self.mem_if_addr_width}b, "
            f"app-io {self.app_io_data_width_bytes}B, PEs={self.num_pes}",
            f"  scheduler: enabled={self.scheduler.enabled} "
            f"batch={self.scheduler.batch_size} "
            f"timeout={self.scheduler.timeout_cycles}",
            f"  cache: enabled={self.cache.enabled} "
            f"line={self.cache.line_width_bits}b x {self.cache.num_lines} "
            f"ways={self.cache.associativity} "
            f"({self.cache.capacity_bytes / 1024:.0f} KiB)",
            f"  dma: enabled={self.dma.enabled} "
            f"channels={self.dma.num_parallel_dma} "
            f"txn<={self.dma.max_transaction_bytes}B",
            f"  mem channels: {self.channels.num_channels} "
            f"({self.channels.policy}, "
            f"interleave={self.channels.interleave_bytes}B)",
            f"  dram sched: {self.dram_sched.policy} "
            f"window={self.dram_sched.effective_window} "
            f"cap={self.dram_sched.starvation_cap} "
            f"refresh={'off' if not self.dram_sched.t_refi else f'{self.dram_sched.t_rfc}/{self.dram_sched.t_refi}'}",
            f"  vmem footprint ~ {self.vmem_footprint_bytes() / 1024:.1f} KiB",
        ]
        if self.faults is not None:
            f = self.faults
            lines.insert(-1, (
                f"  faults: ber={f.transient_ber:g} "
                f"weak={f.weak_row_fraction:g}@{f.weak_row_ber:g} "
                f"ecc={f.ecc} replays<={f.max_replays} "
                f"backoff={f.backoff_clocks} "
                f"failed_ch={list(f.failed_channels)} "
                f"outages={len(f.outage_windows)}"))
        return "\n".join(lines)


def scheduler_sort_stages(batch_size: int) -> int:
    """Bitonic network stage count for a batch of N: log2(N)(log2(N)+1)/2."""
    logn = int(math.log2(batch_size))
    return logn * (logn + 1) // 2


# Paper Table IV — the configuration used for the GCN/CNN evaluation.
PAPER_EVAL_CONFIG = MemoryControllerConfig(
    cache=CacheConfig(line_width_bits=512, num_lines=4096, associativity=4),
    dma=DMAConfig(buffer_bytes=16 * 1024, num_parallel_dma=4),
    scheduler=SchedulerConfig(batch_size=64, timeout_cycles=16),
)

# The headline *combined* configuration: Table IV's cache + scheduler
# engines composed with the 4-channel front end — the setting where the
# paper's access-time wins come from the composition of the stages
# rather than any stage alone (the `simulate()` pipeline's default
# benchmark target; `benchmarks/perf_pipeline.py`).
PAPER_COMBINED_CONFIG = MemoryControllerConfig(
    cache=CacheConfig(line_width_bits=512, num_lines=4096, associativity=4),
    dma=DMAConfig(buffer_bytes=16 * 1024, num_parallel_dma=4),
    scheduler=SchedulerConfig(batch_size=64, timeout_cycles=16),
    channels=ChannelConfig(num_channels=4, policy="row_interleave"),
)
