"""Sharded, atomic, async checkpointing with elastic restore.

Design (orbax-like, self-contained):

* **Layout** — one ``.npy`` per pytree leaf under ``<dir>/step_<N>.tmp/``,
  plus ``manifest.json`` (tree paths, shapes, dtypes, step). The directory
  is atomically renamed to ``step_<N>/`` after all leaves + manifest are
  durable, so a crash mid-save can never produce a directory that
  ``latest_step`` would pick up.
* **Async** — ``save`` snapshots leaves to host RAM synchronously (cheap),
  then writes on a daemon thread; ``wait()`` joins. Training continues
  during the write (the checkpoint-stall the paper's DMA engine hides for
  accelerators, applied to the training loop itself).
* **Elastic restore** — leaves are loaded as numpy and ``device_put`` with
  the *target* mesh's NamedSharding: restoring onto a different mesh shape
  (fewer hosts after a failure, more after scale-up) re-shards
  transparently.
* **Retention** — keep the last ``keep`` checkpoints; GC runs post-rename.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")

# numpy can't natively serialize bf16/fp8 — store as a same-width unsigned
# view and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _encode(arr: np.ndarray):
    for name, (logical, carrier) in _EXOTIC.items():
        if arr.dtype == logical:
            return arr.view(carrier), name
    return arr, str(arr.dtype)


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][0])
    return arr


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    *, blocking: bool = True) -> threading.Thread:
    """Write ``tree`` under ``directory/step_<step>``; atomic via rename."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)

    # Snapshot to host RAM now so training may mutate buffers afterwards.
    leaves = {k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()}

    def write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for key, arr in leaves.items():
            fname = key.replace("/", "__") + ".npy"
            carrier, dtype_name = _encode(arr)
            np.save(os.path.join(tmp, fname), carrier)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype_name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, target_tree: Any,
                    *, mesh=None, specs=None) -> Any:
    """Restore into the structure of ``target_tree``.

    With ``mesh``+``specs`` the leaves are placed with NamedSharding —
    loading onto a different mesh than the one that saved re-shards
    automatically (elastic restart).
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    keys = list(_flatten_with_paths(target_tree).keys())
    missing = [k for k in keys if k not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]} ...")

    spec_leaves = (_flatten_with_paths(specs) if specs is not None else {})

    loaded = {}
    for key in keys:
        meta = manifest["leaves"][key]
        arr = _decode(np.load(os.path.join(path, meta["file"])),
                      meta["dtype"])
        if mesh is not None and key in spec_leaves:
            sharding = jax.sharding.NamedSharding(mesh, spec_leaves[key])
            loaded[key] = jax.device_put(arr, sharding)
        else:
            loaded[key] = jax.numpy.asarray(arr)

    flat, treedef = jax.tree_util.tree_flatten(target_tree)
    ordered = [loaded[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, ordered)


@dataclasses.dataclass
class CheckpointManager:
    """Save-every-K orchestration with retention and async writes."""

    directory: str
    save_every: int = 100
    keep: int = 3
    _pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any) -> bool:
        if step % self.save_every:
            return False
        self.wait()
        self._pending = save_checkpoint(self.directory, step, tree,
                                        blocking=False)
        return True

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        self._gc()

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(m.group(1)) for m in
            (_STEP_RE.match(n) for n in os.listdir(self.directory)) if m)
        for s in steps[:-self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def restore_latest(self, target_tree: Any, *, mesh=None, specs=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, target_tree,
                                     mesh=mesh, specs=specs)
