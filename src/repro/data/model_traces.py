"""Per-architecture workload zoo: captured model traces (ARCHITECTURE §13).

One function — :func:`capture_model_trace` — runs each registry
architecture's *smoke* configuration through a fixed exercise script with
a :class:`~repro.core.capture.TraceCapture` active, and returns the
``(pe_id, row_id, rw, bytes, arrival)`` request stream the model actually
emitted. The script covers every controller-routed traffic class:

* **forward/train** — embedding gathers (``mc_embed``), MoE expert
  dispatch+combine (multi-port: expert = PE), audio/vision frontend
  streaming reads;
* **embedding-gradient update** — the irregular WRITE stream
  (``mc_scatter``, mode="add");
* **prefill + decode steps** — 1-D decode-token gathers (now routed
  through the controller), KV-page bulk-write appends
  (``mc_kv_append``), SSM state rewrites (mamba).

Capture runs execute eagerly with ``scan_layers=False`` (the supported
unrolled layer walk) so the hooks see concrete values; any residual
traced op is skipped and counted, and the zoo asserts the count is zero.

The replay contract is closed-loop: ``TraceCapture.replay_arrays`` folds
ports onto ``config.num_pes`` and drops the logical arrival clock, so
``MemoryController.simulate`` keeps its cache + batch-scheduler stages
(nonzero arrivals would flip it into open-loop serving mode).

Pinned traces (one representative per model family) live as JSON under
``tests/goldens/traces/`` — regenerable with
``scripts/regen_goldens.py --traces`` — and feed the golden harness
(``tests/core/golden_cases.py``) plus the per-family benchmark matrix
(``benchmarks/perf_model_traces.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import os

import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.core.capture import TraceCapture

# Fixed zoo shape: big enough that scheduler batches, cache working sets
# and multi-port contention are non-degenerate; small enough that all 10
# architectures capture in seconds on CPU.
CAPTURE_BATCH = 4
CAPTURE_SEQ = 64
CAPTURE_DECODE_STEPS = 8
TRACE_SEED = 0
# Replay granularity: the capture is row-indexed; every row is priced at
# the goldens' canonical 4 KiB stride (per-request true transfer sizes
# stay available in ``TraceCapture.rows()['nbytes']``).
REPLAY_ROW_BYTES = 4096

# One pinned golden trace per model family (family -> registry id).
FAMILY_REPRESENTATIVE = {
    "dense": "yi_34b",
    "moe": "mixtral_8x7b",
    "ssm": "mamba2_2p7b",
    "hybrid": "jamba_v0p1_52b",
    "encoder": "hubert_xlarge",
    "vlm": "internvl2_76b",
}

TRACE_DIR = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)),
    "..", "..", "..", "tests", "goldens", "traces"))


def arch_families() -> dict:
    """registry id -> family string, for all 10 architectures."""
    return {a: registry.get_arch(a, smoke=True).family
            for a in registry.ARCH_IDS}


def pinned_trace_path(arch: str) -> str:
    return os.path.join(TRACE_DIR, f"{registry.canonical(arch)}.json")


def capture_model_trace(arch: str, *, seed: int = TRACE_SEED,
                        batch: int = CAPTURE_BATCH, seq: int = CAPTURE_SEQ,
                        decode_steps: int = CAPTURE_DECODE_STEPS
                        ) -> TraceCapture:
    """Run the fixed exercise script for ``arch`` (smoke config) under an
    active recorder; returns the captured trace.

    Deterministic for fixed ``(arch, seed, batch, seq, decode_steps)``
    within a process/platform: params and data are seeded, decode feeds
    back argmax tokens. Raises if any hooked op was skipped under tracing
    (the zoo must observe *all* traffic) or if the capture is empty.
    """
    import jax
    import jax.numpy as jnp

    from repro.data.synthetic import make_batch
    from repro.models.lm import build_lm

    cfg = registry.get_arch(arch, smoke=True)
    # Eager unrolled layer walk, no remat (jax.checkpoint traces its
    # body), so capture hooks see concrete values.
    cfg = dataclasses.replace(cfg, scan_layers=False, remat=False)
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(seed))
    shape = ShapeConfig(f"capture_{seq}x{batch}", seq, batch, "train")
    data = make_batch(cfg, shape, step=0, seed=seed)

    with TraceCapture() as cap:
        lm.forward(params, data)
        if "tokens" in data:
            tokens = jnp.asarray(data["tokens"])
            table = params["embed"]["table"]
            grad_rows = jnp.ones((*tokens.shape, table.shape[-1]),
                                 table.dtype)
            lm.embedding_grad_update(params, tokens, grad_rows)
        if cfg.family != "encoder":
            serve = {k: v for k, v in data.items()
                     if k not in ("labels", "loss_mask")}
            logits, cache, cur = lm.prefill(params, serve,
                                            max_len=seq + decode_steps)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for _ in range(decode_steps):
                logits, cache = lm.decode_step(params, tok, cache, cur)
                cur = cur + 1
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if cap.n_skipped_traced:
        raise RuntimeError(
            f"{arch}: {cap.n_skipped_traced} capture hook(s) saw traced "
            "values — the zoo must run eagerly (scan_layers=False)")
    if len(cap) == 0:
        raise RuntimeError(f"{arch}: captured trace is empty")
    return cap


@functools.lru_cache(maxsize=None)
def cached_capture(arch: str, seed: int = TRACE_SEED,
                   batch: int = CAPTURE_BATCH, seq: int = CAPTURE_SEQ,
                   decode_steps: int = CAPTURE_DECODE_STEPS) -> TraceCapture:
    """Memoized :func:`capture_model_trace` — tests and benchmarks share
    one capture per configuration. Treat the result as read-only."""
    return capture_model_trace(arch, seed=seed, batch=batch, seq=seq,
                               decode_steps=decode_steps)


def load_pinned_trace(arch: str) -> TraceCapture:
    """The checked-in golden trace for ``arch`` (family representative)."""
    return TraceCapture.load(pinned_trace_path(arch))


def write_pinned_traces(verbose: bool = True) -> list:
    """(Re)capture and write the per-family pinned traces; returns the
    written paths (``scripts/regen_goldens.py --traces``)."""
    os.makedirs(TRACE_DIR, exist_ok=True)
    paths = []
    for family, arch in sorted(FAMILY_REPRESENTATIVE.items()):
        cap = capture_model_trace(arch)
        path = pinned_trace_path(arch)
        cap.save(path)
        paths.append(path)
        if verbose:
            counts = ", ".join(f"{k}={v}" for k, v in
                               sorted(cap.op_counts().items()))
            print(f"wrote {path}  [{family}] n={len(cap)} ({counts})")
    return paths


def summarize(cap: TraceCapture) -> dict:
    """Machine-readable shape of a captured trace (benchmark payloads)."""
    r = cap.rows()
    return {
        "n_requests": int(r["row_id"].size),
        "n_ops": int(cap.n_ops),
        "n_ports": int(cap.n_ports),
        "n_rows_total": int(cap.n_rows_total),
        "write_fraction": float(r["rw"].mean()) if r["rw"].size else 0.0,
        "total_bytes": int(r["nbytes"].sum()),
        "unique_rows": int(np.unique(r["row_id"]).size),
        "op_counts": cap.op_counts(),
    }
