"""Data pipeline substrate."""

from repro.data.synthetic import SyntheticDataset, make_batch, batch_specs

__all__ = ["SyntheticDataset", "make_batch", "batch_specs"]
