"""Deterministic synthetic data pipeline with stateless resume.

Every batch is a pure function of ``(seed, step)`` — no iterator state
exists, so checkpoints never store data-pipeline cursors and a restarted
(or re-sharded) job regenerates exactly the batch it crashed on. This is
the fault-tolerance property MaxText-class systems get from deterministic
input pipelines, in its simplest sound form.

Token streams are Zipf-distributed (vocabulary locality like real corpora
— which is what gives the memory controller's cache engine and scheduler
realistic hit rates, mirroring the paper's "reflective of real-world
access patterns" methodology). Audio/vision frontends produce Gaussian
frame/patch embeddings per the assignment's stub contract.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def _rng(seed: int, step: int, role: int) -> np.random.Generator:
    # SeedSequence gives independent streams per (seed, step, role)
    return np.random.default_rng(np.random.SeedSequence((seed, step, role)))


def zipf_tokens(rng: np.random.Generator, shape, vocab: int,
                alpha: float = 1.1) -> np.ndarray:
    """Zipf-like token draw bounded to [0, vocab)."""
    z = rng.zipf(alpha, size=shape).astype(np.int64)
    return ((z - 1) % vocab).astype(np.int32)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, *, step: int,
               seed: int = 0, batch_override: int | None = None
               ) -> Dict[str, np.ndarray]:
    """Materialize the global batch for ``step`` (host-RAM numpy)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    out: Dict[str, np.ndarray] = {}
    if cfg.modality == "audio":
        out["frames"] = _rng(seed, step, 0).standard_normal(
            (B, S, cfg.frontend_dim), dtype=np.float32)
        out["labels"] = zipf_tokens(_rng(seed, step, 1), (B, S),
                                    cfg.vocab_size)
    elif cfg.modality == "vision_text":
        st = S - cfg.num_vision_tokens
        out["vision_embeds"] = _rng(seed, step, 0).standard_normal(
            (B, cfg.num_vision_tokens, cfg.frontend_dim), dtype=np.float32)
        toks = zipf_tokens(_rng(seed, step, 1), (B, st + 1), cfg.vocab_size)
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    else:
        toks = zipf_tokens(_rng(seed, step, 1), (B, S + 1), cfg.vocab_size)
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    return out


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, rules,
                *, batch_override: int | None = None):
    """ShapeDtypeStructs + PartitionSpecs for a training batch — the
    dry-run's ``input_specs()`` for train cells."""
    from jax.sharding import PartitionSpec as P
    B = batch_override or shape.global_batch
    S = shape.seq_len
    bspec = rules.spec("batch", "seq")
    b3 = rules.spec("batch", "seq", None)
    shapes, specs = {}, {}
    if cfg.modality == "audio":
        shapes["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                                jnp.bfloat16)
        shapes["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs = {"frames": b3, "labels": bspec}
    elif cfg.modality == "vision_text":
        st = S - cfg.num_vision_tokens
        shapes["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_vision_tokens, cfg.frontend_dim), jnp.bfloat16)
        shapes["tokens"] = jax.ShapeDtypeStruct((B, st), jnp.int32)
        shapes["labels"] = jax.ShapeDtypeStruct((B, st), jnp.int32)
        specs = {"vision_embeds": b3, "tokens": bspec, "labels": bspec}
    else:
        shapes["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        shapes["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs = {"tokens": bspec, "labels": bspec}
    return shapes, specs


@dataclasses.dataclass
class SyntheticDataset:
    """Step-indexed iterator facade with host sharding.

    In a multi-host launch each host materializes only its slice of the
    global batch (``host_index/host_count``); single-host runs see the full
    batch. ``state_dict`` is just the step counter — resume is exact.
    """

    cfg: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    batch_override: int | None = None

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        full = make_batch(self.cfg, self.shape, step=step, seed=self.seed,
                          batch_override=self.batch_override)
        if self.host_count == 1:
            return full
        B = next(iter(full.values())).shape[0]
        per = B // self.host_count
        lo = self.host_index * per
        return {k: v[lo:lo + per] for k, v in full.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
