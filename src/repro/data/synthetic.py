"""Deterministic synthetic data pipeline with stateless resume.

Every batch is a pure function of ``(seed, step)`` — no iterator state
exists, so checkpoints never store data-pipeline cursors and a restarted
(or re-sharded) job regenerates exactly the batch it crashed on. This is
the fault-tolerance property MaxText-class systems get from deterministic
input pipelines, in its simplest sound form.

Token streams are Zipf-distributed (vocabulary locality like real corpora
— which is what gives the memory controller's cache engine and scheduler
realistic hit rates, mirroring the paper's "reflective of real-world
access patterns" methodology). Audio/vision frontends produce Gaussian
frame/patch embeddings per the assignment's stub contract.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def _rng(seed: int, step: int, role: int) -> np.random.Generator:
    # SeedSequence gives independent streams per (seed, step, role)
    return np.random.default_rng(np.random.SeedSequence((seed, step, role)))


def zipf_tokens(rng: np.random.Generator, shape, vocab: int,
                alpha: float = 1.1) -> np.ndarray:
    """Zipf-like token draw bounded to [0, vocab)."""
    z = rng.zipf(alpha, size=shape).astype(np.int64)
    return ((z - 1) % vocab).astype(np.int32)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, *, step: int,
               seed: int = 0, batch_override: int | None = None
               ) -> Dict[str, np.ndarray]:
    """Materialize the global batch for ``step`` (host-RAM numpy)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    out: Dict[str, np.ndarray] = {}
    if cfg.modality == "audio":
        out["frames"] = _rng(seed, step, 0).standard_normal(
            (B, S, cfg.frontend_dim), dtype=np.float32)
        out["labels"] = zipf_tokens(_rng(seed, step, 1), (B, S),
                                    cfg.vocab_size)
    elif cfg.modality == "vision_text":
        st = S - cfg.num_vision_tokens
        out["vision_embeds"] = _rng(seed, step, 0).standard_normal(
            (B, cfg.num_vision_tokens, cfg.frontend_dim), dtype=np.float32)
        toks = zipf_tokens(_rng(seed, step, 1), (B, st + 1), cfg.vocab_size)
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    else:
        toks = zipf_tokens(_rng(seed, step, 1), (B, S + 1), cfg.vocab_size)
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    return out


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, rules,
                *, batch_override: int | None = None):
    """ShapeDtypeStructs + PartitionSpecs for a training batch — the
    dry-run's ``input_specs()`` for train cells."""
    from jax.sharding import PartitionSpec as P
    B = batch_override or shape.global_batch
    S = shape.seq_len
    bspec = rules.spec("batch", "seq")
    b3 = rules.spec("batch", "seq", None)
    shapes, specs = {}, {}
    if cfg.modality == "audio":
        shapes["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                                jnp.bfloat16)
        shapes["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs = {"frames": b3, "labels": bspec}
    elif cfg.modality == "vision_text":
        st = S - cfg.num_vision_tokens
        shapes["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_vision_tokens, cfg.frontend_dim), jnp.bfloat16)
        shapes["tokens"] = jax.ShapeDtypeStruct((B, st), jnp.int32)
        shapes["labels"] = jax.ShapeDtypeStruct((B, st), jnp.int32)
        specs = {"vision_embeds": b3, "tokens": bspec, "labels": bspec}
    else:
        shapes["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        shapes["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs = {"tokens": bspec, "labels": bspec}
    return shapes, specs


@dataclasses.dataclass
class SyntheticDataset:
    """Step-indexed iterator facade with host sharding.

    In a multi-host launch each host materializes only its slice of the
    global batch (``host_index/host_count``); single-host runs see the full
    batch. ``state_dict`` is just the step counter — resume is exact.
    """

    cfg: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    batch_override: int | None = None

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        full = make_batch(self.cfg, self.shape, step=step, seed=self.seed,
                          batch_override=self.batch_override)
        if self.host_count == 1:
            return full
        B = next(iter(full.values())).shape[0]
        per = B // self.host_count
        lo = self.host_index * per
        return {k: v[lo:lo + per] for k, v in full.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


# ---------------------------------------------------------------------------
# Open-loop arrival processes (serving workloads, ARCHITECTURE §9)
# ---------------------------------------------------------------------------
# All draws go through the bit-generator primitives (``rng.random`` /
# ``rng.integers``) with the shaping done in plain arithmetic — numpy
# guarantees stream stability for the bit generators, so the pinned
# serving goldens cannot drift between numpy releases (same rule as
# ``tests/core/golden_cases.py``). Times are FPGA cycles, float64.

def _exp_gaps(rng: np.random.Generator, n: int, rate: float) -> np.ndarray:
    """Exponential inter-arrival gaps of mean ``1/rate`` via inverse
    CDF on uniform draws (no ``Generator.exponential``)."""
    u = rng.random(n)
    return -np.log1p(-u) / rate


def poisson_arrivals(rng: np.random.Generator, n: int,
                     rate: float) -> np.ndarray:
    """Memoryless open-loop arrivals: ``n`` stamps at ``rate`` requests
    per FPGA cycle (the M/·/1 baseline every queueing result starts
    from)."""
    if rate <= 0:
        raise ValueError(f"rate={rate} must be positive")
    return np.cumsum(_exp_gaps(rng, n, rate))


def bursty_arrivals(rng: np.random.Generator, n: int, rate: float,
                    *, burst_len: int = 16,
                    burst_factor: float = 8.0) -> np.ndarray:
    """Markov-modulated bursts: runs of ``burst_len`` requests arrive
    ``burst_factor``× faster than ``rate``, separated by compensating
    idle gaps so the *long-run* offered load is still ``rate`` — the
    adversarial pattern that fills reorder windows and port FIFOs
    faster than the mean-rate analysis predicts."""
    if rate <= 0 or burst_factor <= 1 or burst_len < 1:
        raise ValueError("need rate > 0, burst_factor > 1, burst_len >= 1")
    gaps = _exp_gaps(rng, n, rate * burst_factor)
    starts = np.arange(n) % burst_len == 0
    # each burst owes (burst_len/rate) mean time but spends only
    # (burst_len/(rate*bf)) inside the burst — the idle gap carries
    # the difference, keeping the long-run rate exact
    idle_mean = burst_len / rate - burst_len / (rate * burst_factor)
    n_bursts = int(starts.sum())
    idle = np.zeros(n)
    idle[starts] = _exp_gaps(rng, n_bursts, 1.0 / idle_mean)
    return np.cumsum(gaps + idle)


def diurnal_arrivals(rng: np.random.Generator, n: int, rate: float,
                     *, cycles: float = 4.0,
                     depth: float = 0.8) -> np.ndarray:
    """Slowly-modulated load: the instantaneous rate swings
    ``rate * (1 ± depth)`` sinusoidally over ``cycles`` full periods of
    the trace — peak-hour pressure and trough idle in one stream."""
    if rate <= 0 or not 0 <= depth < 1:
        raise ValueError("need rate > 0 and 0 <= depth < 1")
    phase = 2.0 * np.pi * cycles * np.arange(n) / max(1, n)
    # E[1/(1 + d sin)] = 1/sqrt(1 - d^2): pre-scale so the *long-run*
    # rate is exactly ``rate`` despite the harmonic-mean penalty
    inst = (rate / np.sqrt(1.0 - depth * depth)
            * (1.0 + depth * np.sin(phase)))
    return np.cumsum(_exp_gaps(rng, n, 1.0)[:n] / inst)


def hog_victim_workload(rng: np.random.Generator, *,
                        n_victim: int, n_hog: int,
                        victim_rate: float, hog_rate: float,
                        n_rows: int = 8192, victim_burst: int = 8,
                        victim_port: int = 0, hog_port: int = 1):
    """Two-tenant isolation workload (Memory-Controller-Wall style):

    * tenant ``victim_port`` — a latency-SLO service whose *queries*
      arrive Poisson but touch ``victim_burst`` Zipf-popular pages at
      once (one query = one burst of same-stamp reads; long-run rate is
      still ``victim_rate`` requests/cycle);
    * tenant ``hog_port`` — a bandwidth hog streaming sequential rows
      (with write-backs) at ``hog_rate``, typically >> victim_rate.

    Returns ``(row_ids, rw, pe_id, arrival_cycle)`` merged in arrival
    order (stable sort — ties keep victim-first determinism), ready for
    ``MemoryController.simulate(..., arrival_cycle=...)``.
    """
    # victim: Zipf-shaped popularity (inverse-CDF, as golden_cases.py)
    u = np.clip(rng.random(n_victim), 1e-12, 1.0)
    v_rows = (np.floor(np.minimum(u ** (-1.0 / 0.2), 2.0 ** 62))
              .astype(np.int64) - 1) % n_rows
    v_rw = np.zeros(n_victim, np.int32)
    if victim_burst < 1:
        raise ValueError(f"victim_burst={victim_burst} must be >= 1")
    n_q = -(-n_victim // victim_burst)
    q_arr = poisson_arrivals(rng, n_q, victim_rate / victim_burst)
    v_arr = np.repeat(q_arr, victim_burst)[:n_victim]
    # hog: sequential sweep with jitter, 1-in-4 write
    h_rows = ((np.arange(n_hog) // 2 + rng.integers(0, 4, n_hog))
              % n_rows).astype(np.int64)
    h_rw = (np.arange(n_hog) % 4 == 3).astype(np.int32)
    h_arr = bursty_arrivals(rng, n_hog, hog_rate)
    rows = np.concatenate([v_rows, h_rows])
    rw = np.concatenate([v_rw, h_rw])
    pe = np.concatenate([np.full(n_victim, victim_port, np.int64),
                         np.full(n_hog, hog_port, np.int64)])
    arr = np.concatenate([v_arr, h_arr])
    order = np.argsort(arr, kind="stable")
    return rows[order], rw[order], pe[order], arr[order]
