"""Elastic mesh planning: recompute the mesh when the fleet changes.

Policy: the model (TP) axis is topology-locked — its size is preserved
across rescales so weight shardings and compiled kernels stay aligned with
ICI neighborhoods. Capacity changes are absorbed by the data axis (and the
pod axis in multi-pod jobs): lose a host → data axis shrinks to the largest
multiple that fits, global batch per step is preserved by increasing the
per-device batch or (if not divisible) by gradient accumulation. Restore is
handled by the checkpoint layer (leaves re-shard on device_put).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    grad_accum: int           # extra accumulation to preserve global batch
    dropped_devices: int

    def describe(self) -> str:
        return (f"mesh {dict(zip(self.axis_names, self.old_shape))} -> "
                f"{dict(zip(self.axis_names, self.new_shape))}, "
                f"grad_accum x{self.grad_accum}, "
                f"dropped {self.dropped_devices} devices")


def elastic_mesh_shape(num_devices: int, model_parallel: int,
                       *, pods: int = 1) -> Tuple[int, ...]:
    """Largest (pod, data, model) mesh fitting ``num_devices``."""
    if model_parallel > num_devices:
        raise ValueError("not enough devices for the model axis; "
                         "elastic policy cannot shrink TP")
    per_pod = num_devices // pods
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError("not enough devices per pod for one data shard")
    return (pods, data, model_parallel) if pods > 1 else (
        data, model_parallel)


def plan_rescale(old_shape: Tuple[int, ...], axis_names: Tuple[str, ...],
                 available_devices: int,
                 global_batch: int) -> RescalePlan:
    """Plan the post-failure mesh. Preserves TP; shrinks pods first (a
    dead pod's chips are gone wholesale), then the data axis."""
    sizes = dict(zip(axis_names, old_shape))
    model = sizes.get("model", 1)
    pods = sizes.get("pod", 1)
    full_pod = sizes.get("data", 1) * model
    # a pod is only kept if its full chip complement survives
    pods = max(1, min(pods, available_devices // max(1, full_pod)))
    new_shape = elastic_mesh_shape(available_devices, model, pods=pods)
    new_sizes = dict(zip(("pod", "data", "model") if pods > 1
                         else ("data", "model"), new_shape))
    old_dp = sizes.get("pod", 1) * sizes.get("data", 1)
    new_dp = new_sizes.get("pod", 1) * new_sizes.get("data", 1)
    # keep the global batch: accumulate if the new DP doesn't divide it
    grad_accum = max(1, -(-old_dp // new_dp))
    used = new_sizes.get("pod", 1) * new_sizes.get("data", 1) * model
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return RescalePlan(old_shape=old_shape, new_shape=new_shape,
                       axis_names=names, grad_accum=grad_accum,
                       dropped_devices=available_devices - used)


def make_mesh_from_plan(plan: RescalePlan):
    return jax.make_mesh(plan.new_shape, plan.axis_names)
