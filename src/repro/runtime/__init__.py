"""Runtime substrate: straggler watchdog + elastic mesh management."""

from repro.runtime.elastic import elastic_mesh_shape, plan_rescale
from repro.runtime.watchdog import StepWatchdog

__all__ = ["StepWatchdog", "elastic_mesh_shape", "plan_rescale"]
