"""Straggler detection: per-step wall-time monitoring.

SPMD steps are lockstep, so one slow host slows the fleet; the watchdog
tracks a robust (median/MAD) step-time baseline and raises a structured
``StragglerAlert`` when recent steps breach it persistently. The training
driver responds per policy: log, checkpoint-and-rescale (drop the slow
host via the elastic planner), or abort for the scheduler to replace the
node. Hook points are callbacks so the policy is deployment-specific.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Optional


@dataclasses.dataclass
class StragglerAlert:
    step: int
    step_time_s: float
    baseline_s: float
    ratio: float


class StepWatchdog:
    """Call ``start()``/``stop(step)`` around each step."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 patience: int = 3,
                 on_alert: Optional[Callable[[StragglerAlert], None]] = None):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self.on_alert = on_alert
        self.times: Deque[float] = deque(maxlen=window)
        self._t0: Optional[float] = None
        self._breaches = 0
        self.alerts: list[StragglerAlert] = []

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> Optional[StragglerAlert]:
        assert self._t0 is not None, "stop() without start()"
        dt = time.monotonic() - self._t0
        self._t0 = None
        alert = None
        if len(self.times) >= max(5, self.window // 5):
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.threshold * med:
                self._breaches += 1
                if self._breaches >= self.patience:
                    alert = StragglerAlert(step=step, step_time_s=dt,
                                           baseline_s=med,
                                           ratio=dt / med)
                    self.alerts.append(alert)
                    if self.on_alert:
                        self.on_alert(alert)
                    self._breaches = 0
            else:
                self._breaches = 0
        self.times.append(dt)
        return alert

    @property
    def median_step_s(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]
