"""Equivalence tests for the §Perf variants: sort vs cumsum dispatch,
grouped vs global schedulers, chunked vs naive CE, serving layout rule."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import blocks
from repro.models.lm import build_lm
from repro.models.sharding import make_rules, serving_weight_overrides


def _moe_setup(key, capacity_factor=8.0):
    cfg = get_arch("mixtral_8x7b", smoke=True)
    cfg = dataclasses.replace(
        cfg, param_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor))
    lm = build_lm(cfg)
    params = lm.init(key)
    pos = next(k for k, v in params["layers"].items() if "moe" in v)
    p = jax.tree.map(lambda t: t[0], params["layers"][pos]["moe"])
    return cfg, p


@pytest.mark.parametrize("capacity_factor", [8.0, 0.3])
def test_sort_dispatch_bitwise_matches_cumsum(capacity_factor, key):
    """The paper's sort scheduler preserves sequential-arrival slot
    assignment exactly (stability ⇒ same-address order), including which
    requests get dropped at starved capacity."""
    cfg, p = _moe_setup(key, capacity_factor)
    rules = make_rules(None)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    a, _ = blocks.moe_ffn(p, x, cfg, rules, None, dispatch="sort")
    b, _ = blocks.moe_ffn(p, x, cfg, rules, None, dispatch="cumsum")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_grouped_scheduler_matches_global_when_not_dropping(groups, key):
    """Per-group capacity changes *drop* behaviour only; with ample
    capacity group-local scheduling is value-identical to the global
    scheduler."""
    cfg, p = _moe_setup(key, capacity_factor=8.0)
    rules = make_rules(None)
    x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
    want, _ = blocks.moe_ffn(p, x, cfg, rules, None, num_groups=1)
    got, _ = blocks.moe_ffn(p, x, cfg, rules, None, num_groups=groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_chunked_ce_matches_naive_values_and_grads(key):
    cfg = dataclasses.replace(get_arch("h2o-danube-1.8b", smoke=True),
                              param_dtype="float32")
    lm = build_lm(cfg)
    lm8 = build_lm(dataclasses.replace(cfg, loss_chunks=8))
    params = lm.init(key)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1, _ = lm.loss(params, batch)
    l2, _ = lm8.loss(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
    g1 = jax.grad(lambda p: lm.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: lm8.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_chunked_ce_handles_ragged_tail(key):
    cfg = dataclasses.replace(get_arch("yi-34b", smoke=True),
                              param_dtype="float32", loss_chunks=5)
    lm = build_lm(cfg)
    params = lm.init(key)
    toks = jax.random.randint(key, (2, 33), 0, cfg.vocab_size)  # 33 % 5 != 0
    loss, _ = lm.loss(params, {"tokens": toks,
                               "labels": jnp.roll(toks, -1, 1)})
    assert np.isfinite(float(loss))


def test_serving_weight_rule_is_batch_and_arch_conditional():
    mesh = None
    assert serving_weight_overrides(get_arch("yi-34b"), 128, mesh) == {}

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    dense = get_arch("granite-34b")
    moe = get_arch("mixtral-8x7b")
    fm = FakeMesh()
    assert serving_weight_overrides(dense, 128, fm) == {"w_fsdp": None}
    assert serving_weight_overrides(dense, 1, fm) == {}      # long_500k
    assert serving_weight_overrides(moe, 128, fm) == {}      # MoE serve


def test_ep_strategy_validates_applicability():
    with pytest.raises(ValueError, match="needs a mesh"):
        build_lm(get_arch("mixtral-8x7b", smoke=True),
                 mesh=None, moe_strategy="ep")

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # 8 experts / 16-way axis: not EP-able; shared experts: not EP-able
    with pytest.raises(ValueError, match="EP dispatch"):
        build_lm(get_arch("mixtral-8x7b"), mesh=FakeMesh(),
                 moe_strategy="ep")
    with pytest.raises(ValueError, match="EP dispatch"):
        build_lm(get_arch("qwen2-moe-a2.7b"), mesh=FakeMesh(),
                 moe_strategy="ep")