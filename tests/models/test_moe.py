"""MoE dispatch (the scheduler instance) against a naive dense-mixture
oracle, plus capacity/drop semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import blocks
from repro.models import layers
from repro.models.sharding import make_rules


def _setup(key, capacity_factor=8.0, arch="mixtral_8x7b"):
    cfg = get_arch(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, param_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor))
    from repro.models.lm import build_lm
    lm = build_lm(cfg)
    params = lm.init(key)
    # first MoE position in the stack
    pos = next(k for k, v in params["layers"].items() if "moe" in v)
    p = jax.tree.map(lambda t: t[0], params["layers"][pos]["moe"])
    return cfg, p


def _naive_moe(p, x, cfg):
    """Dense mixture oracle: route every token to its top-k experts with
    no capacity limit, computed expert-by-expert."""
    m = cfg.moe
    B, S, D = x.shape
    flat = layers.rms_norm(x, p["ln"]).reshape(-1, D)
    logits = flat @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(flat)
    for e in range(m.num_experts):
        h = jax.nn.silu(flat @ p["w_gate"][e]) * (flat @ p["w_up"][e])
        y_e = h @ p["w_down"][e]
        for k in range(m.top_k):
            w = jnp.where(top_e[:, k] == e, top_p[:, k], 0.0)
            out = out + y_e * w[:, None]
    if m.num_shared_experts:
        out = out + (jax.nn.silu(flat @ p["shared_gate"])
                     * (flat @ p["shared_up"])) @ p["shared_down"]
    return out.reshape(B, S, D)


@pytest.mark.parametrize("arch", ["mixtral_8x7b", "qwen2_moe_a2p7b"])
def test_moe_matches_dense_mixture(arch, key):
    cfg, p = _setup(key, capacity_factor=8.0, arch=arch)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    rules = make_rules(None)
    got, aux = blocks.moe_ffn(p, x, cfg, rules, None)
    want = _naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    assert float(aux["load_balance"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz


def test_no_drop_mode_is_exact_at_any_capacity_factor(key):
    cfg, p = _setup(key, capacity_factor=0.1)   # tiny capacity
    x = jax.random.normal(key, (1, 8, cfg.d_model), jnp.float32)
    rules = make_rules(None)
    got, _ = blocks.moe_ffn(p, x, cfg, rules, None, no_drop=True)
    want = _naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_capacity_drops_reduce_output_norm(key):
    """With a starved capacity factor, some assignments are dropped, so the
    routed contribution shrinks (drop semantics, not an error)."""
    cfg_hi, p = _setup(key, capacity_factor=8.0)
    cfg_lo = dataclasses.replace(
        cfg_hi, moe=dataclasses.replace(cfg_hi.moe, capacity_factor=0.25))
    x = jax.random.normal(key, (2, 32, cfg_hi.d_model), jnp.float32)
    rules = make_rules(None)
    hi, _ = blocks.moe_ffn(p, x, cfg_hi, rules, None)
    lo, _ = blocks.moe_ffn(p, x, cfg_lo, rules, None)
    assert float(jnp.linalg.norm(lo)) < float(jnp.linalg.norm(hi))


def test_same_address_stability_in_dispatch(key):
    """Two identical tokens must receive identical outputs (the controller's
    same-address consistency rule carried into the MoE scheduler)."""
    cfg, p = _setup(key)
    x1 = jax.random.normal(key, (1, 4, cfg.d_model), jnp.float32)
    x = jnp.concatenate([x1, x1], axis=1)     # duplicated request stream
    rules = make_rules(None)
    out, _ = blocks.moe_ffn(p, x, cfg, rules, None, no_drop=True)
    np.testing.assert_allclose(np.asarray(out[:, :4]),
                               np.asarray(out[:, 4:]), atol=1e-5)
