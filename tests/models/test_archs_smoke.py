"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates its REDUCED same-family config and runs
one forward + one train step on CPU, asserting output shapes and finite
values. Full configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_arch, supported_shapes
from repro.models import build_lm
from repro.optim.adamw import OptimizerConfig, adamw_update, init_opt_state

B, S = 2, 32


def make_batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 4)
    if cfg.modality == "audio":
        return {
            "frames": jax.random.normal(ks[0], (batch, seq,
                                                 cfg.frontend_dim),
                                        jnp.bfloat16),
            "labels": jax.random.randint(ks[1], (batch, seq), 0,
                                         cfg.vocab_size)}
    if cfg.modality == "vision_text":
        st = seq - cfg.num_vision_tokens
        return {
            "vision_embeds": jax.random.normal(
                ks[0], (batch, cfg.num_vision_tokens, cfg.frontend_dim),
                jnp.bfloat16),
            "tokens": jax.random.randint(ks[1], (batch, st), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(ks[2], (batch, st), 0,
                                         cfg.vocab_size)}
    return {"tokens": jax.random.randint(ks[1], (batch, seq), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(ks[2], (batch, seq), 0,
                                         cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch, key):
    cfg = get_arch(arch, smoke=True)
    lm = build_lm(cfg)
    params = lm.init(key)
    batch = make_batch(cfg, key)
    logits, aux = lm.forward(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch, key):
    cfg = get_arch(arch, smoke=True)
    lm = build_lm(cfg)
    params = lm.init(key)
    batch = make_batch(cfg, key)
    opt = init_opt_state(params)

    def step(p, o, b):
        (loss, m), g = jax.value_and_grad(lm.loss, has_aux=True)(p, b)
        p, o, om = adamw_update(g, o, p, OptimizerConfig(warmup_steps=1))
        return p, o, loss

    p2, o2, loss = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(loss))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_if_supported(arch, key):
    cfg = get_arch(arch, smoke=True)
    if cfg.family == "encoder":
        pytest.skip("encoder-only: no decode step (per assignment)")
    lm = build_lm(cfg)
    params = lm.init(key)
    batch = make_batch(cfg, key)
    batch.pop("labels")
    logits, cache, cur = lm.prefill(params, batch, max_len=S + 8)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = lm.decode_step(params, tok, cache, cur)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_supported_shapes_matrix():
    """The assignment's skip rules, encoded."""
    cells = {a: supported_shapes(get_arch(a)) for a in ARCH_IDS}
    assert "long_500k" in cells["mamba2_2p7b"]          # SSM
    assert "long_500k" in cells["jamba_v0p1_52b"]       # hybrid
    assert "long_500k" in cells["h2o_danube_1p8b"]      # SWA
    assert "long_500k" in cells["mixtral_8x7b"]         # SWA
    assert "long_500k" not in cells["yi_34b"]           # full attention
    assert "long_500k" not in cells["internvl2_76b"]
    assert "decode_32k" not in cells["hubert_xlarge"]   # encoder-only
    total = sum(len(v) for v in cells.values())
    assert total == 33          # 40 assigned cells minus documented skips


def test_exact_assigned_configs():
    """Spot-check the full (non-smoke) configs against the assignment."""
    yi = get_arch("yi-34b")
    assert (yi.num_layers, yi.d_model, yi.num_heads, yi.num_kv_heads,
            yi.d_ff, yi.vocab_size) == (60, 7168, 56, 8, 20480, 64000)
    q = get_arch("qwen2-moe-a2.7b")
    assert (q.moe.num_experts, q.moe.top_k,
            q.moe.num_shared_experts) == (60, 4, 4)
    j = get_arch("jamba-v0.1-52b")
    assert (j.moe.num_experts, j.moe.top_k) == (16, 2)
    assert j.attn_every == 8 and j.moe_every == 2
    m = get_arch("mamba2-2.7b")
    assert (m.num_layers, m.d_model, m.ssm.d_state) == (64, 2560, 128)
    g = get_arch("granite-34b")
    assert (g.num_layers, g.num_kv_heads) == (88, 1)
    h = get_arch("hubert-xlarge")
    assert (h.num_layers, h.d_model, h.vocab_size) == (48, 1280, 504)
    v = get_arch("internvl2-76b")
    assert (v.num_layers, v.d_model, v.num_heads) == (80, 8192, 64)
    x = get_arch("mixtral-8x7b")
    assert (x.moe.num_experts, x.moe.top_k, x.attn_window) == (8, 2, 4096)
    i = get_arch("internlm2-20b")
    assert (i.num_layers, i.d_ff, i.vocab_size) == (48, 16384, 92544)
    d = get_arch("h2o-danube-1.8b")
    assert (d.num_layers, d.d_model, d.d_ff) == (24, 2560, 6912)
