"""Cross-path consistency: decode-with-cache == cache-free forward,
chunked SSD == stepwise recurrence, flash == naive attention, ring-buffer
SWA cache == dense windowed attention."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.core.config import MemoryControllerConfig, SchedulerConfig
from repro.models import build_lm
from repro.models.layers import (decode_attention, flash_attention,
                                 mc_embed, mc_scatter)

DECODABLE = [a for a in ARCH_IDS if a != "hubert_xlarge"]


def _f32(cfg):
    reps = {"param_dtype": "float32"}
    if cfg.moe is not None:
        reps["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, **reps)


@pytest.mark.parametrize("arch", DECODABLE)
def test_decode_matches_full_forward(arch, key):
    cfg = _f32(get_arch(arch, smoke=True))
    lm = build_lm(cfg)
    params = lm.init(key)
    B, S = 2, 32
    if cfg.modality == "vision_text":
        st = S + 1 - cfg.num_vision_tokens
        vis = jax.random.normal(jax.random.key(7),
                                (B, cfg.num_vision_tokens,
                                 cfg.frontend_dim), jnp.float32)
        toks = jax.random.randint(key, (B, st), 0, cfg.vocab_size)
        full = {"tokens": toks, "vision_embeds": vis}
        pre = {"tokens": toks[:, :-1], "vision_embeds": vis}
        last = toks[:, -1]
    else:
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :S]}
        last = toks[:, S]
    want = lm.forward(params, full)[0][:, -1, :cfg.vocab_size]
    _, cache, cur = lm.prefill(params, pre, max_len=S + 8)
    got, _ = lm.decode_step(params, last, cache, cur)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["mamba2_2p7b", "h2o_danube_1p8b",
                                  "mixtral_8x7b"])
def test_multi_step_decode_matches_full(arch, key):
    """Decode 4 tokens sequentially; each must match the cache-free model."""
    cfg = _f32(get_arch(arch, smoke=True))
    lm = build_lm(cfg)
    params = lm.init(key)
    B, S, K = 2, 24, 4
    toks = jax.random.randint(key, (B, S + K), 0, cfg.vocab_size)
    _, cache, cur = lm.prefill(params, {"tokens": toks[:, :S]},
                               max_len=S + K + 8)
    for t in range(K):
        want = lm.forward(
            params, {"tokens": toks[:, :S + t + 1]})[0][:, -1,
                                                        :cfg.vocab_size]
        got, cache = lm.decode_step(params, toks[:, S + t], cache, cur)
        cur = cur + 1
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-3, rtol=1e-3,
                                   err_msg=f"token {t}")


def _naive_attention(q, k, v, causal=True, window=None):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k) / np.sqrt(hd)
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool) if not causal else pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v)
    return o.reshape(B, S, H, hd)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("blocks", [(8, 16), (64, 64), (16, 128)])
def test_flash_matches_naive(causal, blocks, key):
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = flash_attention(q, k, v, causal=causal, q_block=blocks[0],
                          kv_block=blocks[1])
    want = _naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [4, 16, 64])
def test_flash_swa_matches_naive(window, key):
    B, S, H, KV, hd = 1, 48, 4, 4, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_block=16, kv_block=16)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_last_row_of_full(key):
    B, S, H, KV, hd = 2, 33, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = _naive_attention(q, k, v, causal=True)[:, -1]
    got = decode_attention(q[:, -1], k, v,
                           jnp.ones((B, S), bool))
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_ssd_chunk_size_invariance(key):
    """Chunked SSD must give identical results for any chunk size."""
    from repro.models import blocks as blk
    cfg = _f32(get_arch("mamba2_2p7b", smoke=True))
    lm = build_lm(cfg)
    params = lm.init(key)
    p = jax.tree.map(lambda t: t[0], params["layers"]["pos0"]["mamba"])
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    outs = []
    for chunk in (4, 8, 16, 32):
        c = dataclasses.replace(cfg,
                                ssm=dataclasses.replace(cfg.ssm,
                                                        chunk=chunk))
        out, _ = blk.mamba_forward(p, x, c, lm.rules, None)
        outs.append(np.asarray(out, np.float32))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("sched", [True, False])
def test_mc_scatter_matches_naive_update(sched, key, rng):
    """Embedding-gradient scatter through the controller == table.at[].add,
    with or without the scheduler (value-semantics contract)."""
    mc = MemoryControllerConfig(scheduler=SchedulerConfig(enabled=sched))
    table = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, 96, (2, 24)), jnp.int32)
    grads = jnp.asarray(rng.standard_normal((2, 24, 16)), jnp.float32)
    out = mc_scatter(table, tokens, grads, mc, mode="add")
    naive = table.at[tokens.reshape(-1)].add(grads.reshape(-1, 16))
    np.testing.assert_allclose(np.asarray(out), np.asarray(naive),
                               rtol=1e-4, atol=1e-5)
    # round trip with the read path: an updated row is what mc_embed sees
    re_read = mc_embed(out, tokens, mc)
    np.testing.assert_allclose(np.asarray(re_read), np.asarray(out[tokens]),
                               rtol=1e-6)


def test_lm_embedding_grad_update(key, rng):
    cfg = _f32(get_arch("yi_34b", smoke=True))
    lm = build_lm(cfg)
    params = lm.init(key)
    V = params["embed"]["table"].shape[0]
    tokens = jnp.asarray(rng.integers(0, V, (2, 8)), jnp.int32)
    grads = jnp.asarray(
        rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    new_params = lm.embedding_grad_update(params, tokens, grads, lr=0.5)
    table = params["embed"]["table"]
    expect = table.at[tokens.reshape(-1)].add(
        (-0.5 * grads.reshape(-1, cfg.d_model)).astype(table.dtype))
    np.testing.assert_allclose(np.asarray(new_params["embed"]["table"]),
                               np.asarray(expect), rtol=1e-4, atol=1e-5)
    # only the embedding leaf changed
    assert new_params["lm_head"] is params["lm_head"]
