"""Registry audit through the workload zoo (PR 10, satellite 3).

Every architecture in ``configs/registry.py`` must (a) load in both
smoke and full form, (b) produce a non-empty captured trace at the zoo's
smoke exercise shape with no hook skipped under tracing, and (c) yield a
trace that round-trips ``RequestStream.from_rows`` validation and folds
onto the paper controller's ports. Captures are shared process-wide via
``cached_capture`` so the parametrized audit pays each model once.
"""

import numpy as np
import pytest

from repro.configs import registry
from repro.core.config import PAPER_COMBINED_CONFIG
from repro.data import model_traces as mt

EXPECTED_OPS = {
    # every family must emit its signature traffic class (ARCHITECTURE §13)
    "dense": {"embed_gather", "embed_scatter"},
    "moe": {"embed_gather", "moe_dispatch", "moe_combine"},
    "ssm": {"embed_gather", "ssm_state_update"},
    "hybrid": {"embed_gather", "moe_dispatch", "ssm_state_update"},
    "encoder": {"audio_frames"},
    "vlm": {"embed_gather", "vision_patches"},
}


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_config_loads_smoke_and_full(arch):
    smoke = registry.get_arch(arch, smoke=True)
    full = registry.get_arch(arch)
    assert smoke.family == full.family
    assert smoke.num_layers <= full.num_layers


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_capture_nonempty_and_validates(arch):
    cap = mt.cached_capture(arch)
    assert len(cap) > 0 and cap.n_skipped_traced == 0
    r = cap.rows()
    # in-bounds rows, binary rw, positive sizes, monotone logical clock
    assert r["row_id"].min() >= 0
    assert r["row_id"].max() < cap.n_rows_total
    assert set(np.unique(r["rw"])) <= {0, 1}
    assert (r["nbytes"] > 0).all()
    assert (np.diff(r["arrival_cycle"]) >= 0).all()
    # RequestStream round-trip: the single validated ingestion point
    # accepts the trace at the canonical replay stride
    stream = cap.as_request_stream(row_bytes=mt.REPLAY_ROW_BYTES,
                                   num_ports=PAPER_COMBINED_CONFIG.num_pes)
    assert len(stream) == len(cap)
    # and the fold honors the controller's port count
    pe, rows, rw = cap.replay_arrays(PAPER_COMBINED_CONFIG.num_pes)
    assert pe.max() < PAPER_COMBINED_CONFIG.num_pes
    assert rows.size == rw.size == len(cap)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_capture_contains_family_signature_ops(arch):
    fam = registry.get_arch(arch, smoke=True).family
    counts = mt.cached_capture(arch).op_counts()
    missing = EXPECTED_OPS[fam] - set(counts)
    assert not missing, (f"{arch} ({fam}): expected traffic classes "
                        f"missing from capture: {sorted(missing)}; "
                        f"got {sorted(counts)}")


def test_family_map_covers_all_archs():
    fams = mt.arch_families()
    assert set(fams) == set(registry.ARCH_IDS)
    # every family has a pinned representative, and it is a registry id
    assert set(mt.FAMILY_REPRESENTATIVE) == set(fams.values())
    for fam, arch in mt.FAMILY_REPRESENTATIVE.items():
        assert fams[arch] == fam


def test_pinned_traces_exist_for_every_family():
    import os
    for arch in mt.FAMILY_REPRESENTATIVE.values():
        assert os.path.exists(mt.pinned_trace_path(arch)), (
            f"missing pinned trace for {arch} — run "
            "scripts/regen_goldens.py --traces")
