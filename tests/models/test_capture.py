"""Trace-capture contract tests (PR 10).

Three properties pin the ``repro.core.capture`` seam:

* **value identity** — every hooked wrapper (``mc_embed`` including the
  fixed 1-D/scalar decode path, ``mc_scatter``, ``mc_kv_append``) computes
  bit-identical values with capture off, on, and at every token rank;
* **routing** — 1-D token streams go *through* the scheduler model (the
  old silent ``jnp.take`` fallback is gone): the lowered jaxpr of the
  scheduler-enabled path contains the batch sort, the disabled path not;
* **fidelity** — a capture is deterministic for fixed seed/shape, its
  JSON round-trip is exact, and replaying it through ``simulate()``
  reproduces the capture-time result bit-for-bit.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.capture import TraceCapture, active_capture
from repro.core.config import (MemoryControllerConfig, PAPER_COMBINED_CONFIG,
                               SchedulerConfig)
from repro.core.controller import MemoryController
from repro.models import layers

MC_ON = PAPER_COMBINED_CONFIG
MC_SCHED_OFF = dataclasses.replace(
    PAPER_COMBINED_CONFIG, scheduler=SchedulerConfig(enabled=False))


def _table(key, n=64, d=8):
    return jax.random.normal(key, (n, d), jnp.float32)


# ---------------------------------------------------------------------------
# Satellite 1: mc_embed 1-D/scalar routing fix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(), (1,), (7,), (3, 5), (2, 3, 4)])
@pytest.mark.parametrize("mc", [MC_ON, MC_SCHED_OFF],
                         ids=["sched_on", "sched_off"])
def test_mc_embed_value_identity_all_ranks(key, shape, mc):
    table = _table(key)
    tokens = jax.random.randint(jax.random.key(1), shape, 0,
                                table.shape[0], jnp.int32)
    out = layers.mc_embed(table, tokens, mc)
    ref = jnp.take(table, tokens, axis=0)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_mc_embed_1d_routes_through_scheduler(key):
    """Routing regression for the old silent ``jnp.take`` fallback: the
    scheduler-enabled 1-D path must contain the stable batch sort, the
    disabled path must not."""
    table = _table(key)
    tokens = jnp.arange(8, dtype=jnp.int32)

    def has_sort(mc):
        jaxpr = jax.make_jaxpr(
            lambda t, i: layers.mc_embed(t, i, mc))(table, tokens)
        # argsort lowers behind pjit calls; str() prints nested jaxprs,
        # and the sort *primitive* prints as "= sort[..." (plain "sort"
        # would false-positive on gather's indices_are_sorted param)
        return "= sort[" in str(jaxpr)

    assert has_sort(MC_ON)
    assert not has_sort(MC_SCHED_OFF)


def test_mc_embed_1d_is_one_capture_op(key):
    """The decode stream is a single scheduler batch on one port."""
    table = _table(key)
    tokens = jnp.asarray([5, 3, 3, 9], jnp.int32)
    with TraceCapture() as cap:
        layers.mc_embed(table, tokens, MC_ON)
    r = cap.rows()
    assert cap.n_ops == 1
    np.testing.assert_array_equal(r["pe_id"], 0)
    np.testing.assert_array_equal(r["rw"], 0)
    np.testing.assert_array_equal(r["row_id"], [5, 3, 3, 9])


def test_mc_embed_2d_one_port_per_sequence(key):
    table = _table(key)
    tokens = jax.random.randint(key, (3, 4), 0, table.shape[0], jnp.int32)
    with TraceCapture() as cap:
        layers.mc_embed(table, tokens, MC_ON)
    pe = cap.rows()["pe_id"]
    np.testing.assert_array_equal(pe, np.repeat(np.arange(3), 4))


def test_mc_scatter_shares_embed_region(key):
    """READ and WRITE embedding traffic land on the same rows: a gather
    then a grad-scatter of the same tokens produces identical row ids
    with rw 0 then 1."""
    table = _table(key)
    tokens = jnp.asarray([[1, 2, 2, 40]], jnp.int32)
    vals = jnp.ones((*tokens.shape, table.shape[-1]), table.dtype)
    with TraceCapture() as cap:
        layers.mc_embed(table, tokens, MC_ON)
        out = layers.mc_scatter(table, tokens, vals, MC_ON, mode="add")
    r = cap.rows()
    n = tokens.size
    np.testing.assert_array_equal(r["row_id"][:n], r["row_id"][n:])
    assert set(r["rw"][:n]) == {0} and set(r["rw"][n:]) == {1}
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(table.at[tokens].add(vals)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Satellite 2: mc_kv_append reports the bulk-write class
# ---------------------------------------------------------------------------

def test_mc_kv_append_records_bulk_write(key):
    buf = jnp.zeros((2, 16, 4, 8), jnp.float32)          # (B, pages, KV, hd)
    new = jax.random.normal(key, (2, 1, 4, 8), jnp.float32)
    with TraceCapture() as cap:
        out = layers.mc_kv_append(buf, new, 5, MC_ON, axis=1)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(jax.lax.dynamic_update_slice_in_dim(buf, new, 5, 1)))
    r = cap.rows()
    op = "kv_append_dma" if MC_ON.dma.enabled else "kv_append"
    assert cap.op_counts() == {op: 1}
    np.testing.assert_array_equal(r["rw"], 1)            # bulk WRITE class
    np.testing.assert_array_equal(r["row_id"], [5])
    assert r["nbytes"][0] == 2 * 4 * 8 * 4               # page bytes


def test_mc_kv_append_clamps_like_dynamic_update_slice(key):
    """Where the data plane clamps an out-of-range slot, the record must
    land on the same clamped page instead of raising."""
    buf = jnp.zeros((1, 8, 2, 4), jnp.float32)
    new = jnp.ones((1, 1, 2, 4), jnp.float32)
    with TraceCapture() as cap:
        layers.mc_kv_append(buf, new, 99, MC_ON, axis=1)
    np.testing.assert_array_equal(cap.rows()["row_id"], [7])


def test_captured_decode_step_contains_kv_bulk_writes():
    """A real captured decode step (the zoo's dense representative)
    carries KV-page bulk-write records."""
    from repro.data import model_traces as mt
    cap = mt.cached_capture("yi_34b")
    counts = cap.op_counts()
    kv_ops = [k for k in counts if k.startswith("kv_append")]
    assert kv_ops and sum(counts[k] for k in kv_ops) > 0
    r = cap.rows()
    kv_ids = [i for i, lbl in enumerate(cap.op_labels)
              if lbl.startswith("kv_append")]
    kv_mask = np.isin(r["op"], kv_ids)
    assert kv_mask.any()
    np.testing.assert_array_equal(r["rw"][kv_mask], 1)


# ---------------------------------------------------------------------------
# Capture-off bit-identity + tracer skipping
# ---------------------------------------------------------------------------

def test_no_active_capture_outside_context(key):
    table = _table(key)
    tokens = jnp.asarray([1, 2], jnp.int32)
    assert active_capture() is None
    with TraceCapture() as cap:
        assert active_capture() is cap
        with TraceCapture() as inner:
            assert active_capture() is inner
        assert active_capture() is cap
    assert active_capture() is None
    # and the hooked paths record nothing once closed
    layers.mc_embed(table, tokens, MC_ON)
    assert len(cap) == 0


def test_capture_on_off_bit_identical(key):
    """Recording never changes values: the same wrapper calls with and
    without an active recorder agree bit-for-bit."""
    table = _table(key)
    tokens = jax.random.randint(key, (2, 6), 0, table.shape[0], jnp.int32)
    vals = jax.random.normal(jax.random.key(2),
                             (*tokens.shape, table.shape[-1]), jnp.float32)
    buf = jnp.zeros((2, 8, 2, 4), jnp.float32)
    new = jax.random.normal(jax.random.key(3), (2, 1, 2, 4), jnp.float32)

    def run():
        return (layers.mc_embed(table, tokens, MC_ON),
                layers.mc_scatter(table, tokens, vals, MC_ON),
                layers.mc_kv_append(buf, new, 3, MC_ON, axis=1))

    off = run()
    with TraceCapture():
        on = run()
    for a, b in zip(off, on):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_jit_traced_ops_are_skipped_and_counted(key):
    table = _table(key)
    with TraceCapture() as cap:
        out = jax.jit(lambda t, i: layers.mc_embed(t, i, MC_ON))(
            table, jnp.asarray([1, 2, 3], jnp.int32))
    assert len(cap) == 0 and cap.n_skipped_traced >= 1
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(table[jnp.asarray([1, 2, 3])]))


# ---------------------------------------------------------------------------
# Controller-side hooks (MemoryController.capture — self-only, no ambient)
# ---------------------------------------------------------------------------

def test_controller_capture_field_records_gather_scatter(key):
    table = _table(key)
    idx = jnp.asarray([3, 3, 7], jnp.int32)
    cap = TraceCapture()
    mc = MemoryController(MC_ON, capture=cap)
    mc.gather(table, idx)
    mc.scatter(table, idx, jnp.ones((3, table.shape[-1])), mode="add")
    counts = cap.op_counts()
    assert counts.get("gather", 0) + counts.get("cached_gather", 0) == 3
    assert counts.get("scatter") == 3
    r = cap.rows()
    assert set(r["rw"].tolist()) == {0, 1}


def test_controller_capture_is_not_ambient(key):
    """mc_scatter delegates to MemoryController.scatter; the controller
    must not also report to the ambient recorder or every scatter would
    be double-counted."""
    table = _table(key)
    tokens = jnp.asarray([[4, 9]], jnp.int32)
    vals = jnp.ones((*tokens.shape, table.shape[-1]), table.dtype)
    with TraceCapture() as cap:
        layers.mc_scatter(table, tokens, vals, MC_ON)
    assert cap.op_counts() == {"embed_scatter": 2}


# ---------------------------------------------------------------------------
# Satellite 4: capture → replay fidelity
# ---------------------------------------------------------------------------

def _tiny_capture(key):
    table = _table(key)
    tokens = jax.random.randint(key, (2, 16), 0, table.shape[0], jnp.int32)
    buf = jnp.zeros((2, 8, 2, 4), jnp.float32)
    new = jnp.ones((2, 1, 2, 4), jnp.float32)
    with TraceCapture() as cap:
        layers.mc_embed(table, tokens, MC_ON)
        layers.mc_scatter(table, tokens,
                          jnp.ones((*tokens.shape, table.shape[-1])), MC_ON)
        layers.mc_kv_append(buf, new, 2, MC_ON, axis=1)
    return cap


def test_capture_deterministic_for_fixed_seed(key):
    a, b = _tiny_capture(key), _tiny_capture(key)
    ra, rb = a.rows(), b.rows()
    assert a.op_labels == b.op_labels
    for k in ra:
        np.testing.assert_array_equal(ra[k], rb[k])


def test_capture_json_roundtrip_exact(tmp_path, key):
    cap = _tiny_capture(key)
    path = str(tmp_path / "trace.json")
    cap.save(path)
    back = TraceCapture.load(path)
    assert back.to_dict() == cap.to_dict()
    ra, rb = cap.rows(), back.rows()
    for k in ra:
        np.testing.assert_array_equal(ra[k], rb[k])
        assert ra[k].dtype == rb[k].dtype


def test_replay_reproduces_capture_time_simulation(tmp_path, key):
    """simulate() over the saved-and-reloaded trace is bit-identical to
    simulate() over the live capture (and deterministic run-to-run)."""
    cap = _tiny_capture(key)
    path = str(tmp_path / "trace.json")
    cap.save(path)

    def run(c):
        pe, rows, rw = c.replay_arrays(MC_ON.num_pes)
        return MemoryController(MC_ON).simulate(pe, rows, rw, 4096)

    live, again, reloaded = run(cap), run(cap), run(TraceCapture.load(path))
    for other in (again, reloaded):
        assert other.makespan_fpga_cycles == live.makespan_fpga_cycles
        assert other.cache_hit_rate == live.cache_hit_rate
        assert other.breakdown() == live.breakdown()


def test_replay_arrays_fold_and_closed_loop(key):
    cap = _tiny_capture(key)
    pe, rows, rw = cap.replay_arrays(2)
    assert pe.max() < 2 and len(rows) == len(cap) == len(rw)
    stream = cap.as_request_stream(num_ports=MC_ON.num_pes)
    assert len(stream) == len(cap)


def test_moe_capture_spreads_across_ports():
    """MoE expert dispatch is a genuine multi-port trace: expert = PE,
    so a mixtral capture must populate >= 2 distinct pe_ids."""
    from repro.data import model_traces as mt
    cap = mt.cached_capture("mixtral_8x7b")
    counts = cap.op_counts()
    assert counts.get("moe_dispatch", 0) > 0
    assert counts.get("moe_combine", 0) == counts["moe_dispatch"]
    r = cap.rows()
    moe_ids = [i for i, lbl in enumerate(cap.op_labels)
               if lbl.startswith("moe_")]
    pe = r["pe_id"][np.isin(r["op"], moe_ids)]
    assert np.unique(pe).size >= 2


def test_region_stacking_and_shape_guard():
    cap = TraceCapture()
    b0 = cap.region("a", 10, 64)
    b1 = cap.region("b", 5, 128)
    assert (b0, b1) == (0, 10) and cap.n_rows_total == 15
    assert cap.region("a", 10, 64) == 0          # idempotent lookup
    with pytest.raises(ValueError, match="different shape"):
        cap.region("a", 11, 64)
    with pytest.raises(ValueError, match="outside"):
        cap.record("op", "a", 10, 64, np.asarray([10]))
