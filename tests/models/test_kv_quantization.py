"""int8 KV-cache serving: accuracy, dtype/footprint, ring-buffer interop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_lm
from repro.models.blocks import (QuantAttnCache, dequantize_kv,
                                 quantize_kv)


def test_quantize_roundtrip_error_bound(key):
    x = jax.random.normal(key, (2, 16, 4, 32)) * 3.0
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    assert q.dtype == jnp.int8
    # per-head max error bounded by half a quantization step
    step = np.asarray(s)[..., None]
    assert (np.abs(np.asarray(back - x)) <= step / 2 + 1e-6).all()


def _decode_rel_err(arch, key):
    cfg = dataclasses.replace(get_arch(arch, smoke=True),
                              param_dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    lm_full = build_lm(cfg)
    lm_q = build_lm(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    params = lm_full.init(key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    want = lm_full.forward(params, {"tokens": toks})[0][:, -1,
                                                        :cfg.vocab_size]
    _, cache, cur = lm_q.prefill(params, {"tokens": toks[:, :S]},
                                 max_len=S + 8)
    got, cache2 = lm_q.decode_step(params, toks[:, S], cache, cur)
    attn_entries = [v["attn"] for v in cache2.values() if "attn" in v]
    assert attn_entries and all(isinstance(c, QuantAttnCache)
                                for c in attn_entries)
    return float(jnp.max(jnp.abs(got - want))
                 / (jnp.max(jnp.abs(want)) + 1e-9))


@pytest.mark.parametrize("arch", ["yi_34b", "granite_34b",
                                  "h2o_danube_1p8b", "jamba_v0p1_52b"])
def test_int8_decode_accuracy(arch, key):
    assert _decode_rel_err(arch, key) < 0.05


def test_int8_cache_halves_footprint():
    cfg = get_arch("granite-34b", smoke=True)
    lm = build_lm(cfg)
    lm_q = build_lm(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    full = lm.init_cache(4, 64, abstract=True)
    quant = lm_q.init_cache(4, 64, abstract=True)

    def nbytes(tree):
        return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree))

    # int8 k/v (half of bf16) + per-head f32 scales. The smoke config's
    # head_dim=16 makes scales 25% overhead (4B per 16 int8); at the real
    # head_dim=128 the ratio is (1 + 4/128)/2 ~ 0.52.
    assert nbytes(quant) < 0.66 * nbytes(full)


def test_int8_multi_step_decode_stable(key):
    """Quantization error must not compound over decode steps."""
    cfg = dataclasses.replace(get_arch("h2o_danube_1p8b", smoke=True),
                              param_dtype="float32",
                              kv_cache_dtype="int8")
    lm = build_lm(cfg)
    cfg_f = dataclasses.replace(cfg, kv_cache_dtype="param")
    lm_f = build_lm(cfg_f)
    params = lm.init(key)
    B, S, K = 2, 24, 6
    toks = jax.random.randint(key, (B, S + K), 0, cfg.vocab_size)
    _, cq, cur = lm.prefill(params, {"tokens": toks[:, :S]},
                            max_len=S + K + 8)
    _, cf, _ = lm_f.prefill(params, {"tokens": toks[:, :S]},
                            max_len=S + K + 8)
    for t in range(K):
        gq, cq = lm.decode_step(params, toks[:, S + t], cq, cur)
        gf, cf = lm_f.decode_step(params, toks[:, S + t], cf, cur)
        cur = cur + 1
        rel = float(jnp.max(jnp.abs(gq - gf))
                    / (jnp.max(jnp.abs(gf)) + 1e-9))
        assert rel < 0.05, (t, rel)
