"""Sorted-gather kernel vs plain-gather oracle across shapes/dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sorted_gather import ops, ref
from repro.kernels.sorted_gather.kernel import gather_rows


@pytest.mark.parametrize("rows,d", [(8, 8), (64, 16), (128, 128), (300, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_gather_matches_ref(rows, d, dtype, rng):
    table = jnp.asarray(rng.standard_normal((rows, d)) * 10, dtype)
    idx = jnp.asarray(rng.integers(0, rows, 50), jnp.int32)
    out = ops.sorted_gather(table, idx)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.gather_ref(table, idx)))


@pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 3, 4)])
def test_multidim_indices(shape, rng):
    table = jnp.asarray(rng.standard_normal((40, 12)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 40, shape), jnp.int32)
    out = ops.sorted_gather(table, idx)
    assert out.shape == (*shape, 12)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[idx]))


def test_bitonic_and_xla_paths_agree(rng):
    table = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, 37), jnp.int32)
    a = ops.sorted_gather(table, idx, use_bitonic=True)
    b = ops.sorted_gather(table, idx, use_bitonic=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_duplicate_heavy_stream(rng):
    """Duplicates (the scheduler's row-hit case) must gather correctly."""
    table = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    idx = jnp.asarray([3] * 20 + [1, 3, 1, 3] + [15] * 5, jnp.int32)
    out = ops.sorted_gather(table, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[idx]))


def test_raw_kernel_requires_sorted_for_dedup_but_any_order_correct(rng):
    table = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 32, 24), jnp.int32)  # unsorted
    out = gather_rows(table, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[idx]))
