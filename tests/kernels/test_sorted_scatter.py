"""Sorted-scatter kernel vs in-order write-stream oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.sorted_scatter import ops, ref
from repro.kernels.sorted_scatter.kernel import scatter_rows


@pytest.mark.parametrize("rows,d", [(8, 8), (64, 16), (300, 33)])
@pytest.mark.parametrize("mode", ["set", "add"])
def test_scatter_matches_ref(rows, d, mode, rng):
    table = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, rows, 50), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((50, d)), jnp.float32)
    out = ops.sorted_scatter(table, idx, vals, mode=mode)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.scatter_ref(table, idx, vals, mode)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 3, 4)])
def test_multidim_indices(shape, rng):
    table = jnp.asarray(rng.standard_normal((40, 12)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 40, shape), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((*shape, 12)), jnp.float32)
    out = ops.sorted_scatter(table, idx, vals)
    assert out.shape == table.shape
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.scatter_ref(table, idx, vals)),
        rtol=1e-5)


def test_untouched_rows_preserved(rng):
    """Rows never written must keep their original contents bit-exactly
    (the kernel is an in-place update via aliasing, not a rebuild)."""
    table = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    idx = jnp.asarray([3, 9, 3], jnp.int32)
    vals = jnp.asarray(rng.standard_normal((3, 8)), jnp.float32)
    out = np.asarray(ops.sorted_scatter(table, idx, vals))
    untouched = [r for r in range(64) if r not in (3, 9)]
    np.testing.assert_array_equal(out[untouched],
                                  np.asarray(table)[untouched])


def test_duplicate_rows_last_writer_wins(rng):
    """The stable sort keeps arrival order within an equal-row run, so the
    run's final flushed value is the *latest arrival* (weak consistency)."""
    table = jnp.zeros((8, 4), jnp.float32)
    idx = jnp.asarray([5, 5, 5, 5], jnp.int32)
    vals = jnp.asarray([[1.0] * 4, [2.0] * 4, [3.0] * 4, [4.0] * 4],
                       jnp.float32)
    out = ops.sorted_scatter(table, idx, vals)
    np.testing.assert_array_equal(np.asarray(out)[5], [4.0] * 4)


def test_add_accumulates_duplicates(rng):
    table = jnp.ones((8, 4), jnp.float32)
    idx = jnp.asarray([2, 2, 6, 2], jnp.int32)
    vals = jnp.ones((4, 4), jnp.float32)
    out = np.asarray(ops.sorted_scatter(table, idx, vals, mode="add"))
    np.testing.assert_allclose(out[2], [4.0] * 4)   # 1 + 3 adds
    np.testing.assert_allclose(out[6], [2.0] * 4)   # 1 + 1 add
    np.testing.assert_allclose(out[0], [1.0] * 4)


def test_kernel_requires_sorted_for_coalescing(rng):
    """scatter_rows itself with pre-sorted duplicates: one burst per row,
    last slot of each run wins."""
    table = jnp.zeros((16, 4), jnp.float32)
    sidx = jnp.asarray([1, 1, 4, 9, 9], jnp.int32)
    vals = jnp.asarray(rng.standard_normal((5, 4)), jnp.float32)
    out = np.asarray(scatter_rows(table, sidx, vals))
    np.testing.assert_allclose(out[1], np.asarray(vals)[1])
    np.testing.assert_allclose(out[4], np.asarray(vals)[2])
    np.testing.assert_allclose(out[9], np.asarray(vals)[4])


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 31), min_size=1, max_size=100),
       st.sampled_from(["set", "add"]))
def test_property_scatter_identity(ids, mode):
    table = jnp.arange(32 * 4, dtype=jnp.float32).reshape(32, 4)
    idx = jnp.asarray(ids, jnp.int32)
    vals = (jnp.arange(len(ids), dtype=jnp.float32)[:, None]
            * jnp.ones((1, 4)))
    out = ops.sorted_scatter(table, idx, vals, mode=mode)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.scatter_ref(table, idx, vals, mode)),
        rtol=1e-5, atol=1e-5)
