"""Cache-probe kernel vs the scan-LRU oracle and the python LRU oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache_engine import hit_rate_oracle, init_cache
from repro.core.config import CacheConfig
from repro.kernels.cache_lookup.kernel import cache_probe
from repro.kernels.cache_lookup.ops import cache_service
from repro.kernels.cache_lookup.ref import cache_probe_ref


def _run_both(cfg: CacheConfig, lids):
    st0 = init_cache(cfg, 4)
    args = (jnp.asarray(lids, jnp.int32), st0.tags,
            st0.valid.astype(jnp.int32), st0.age, st0.clock)
    return cache_probe(*args), cache_probe_ref(*args)


@pytest.mark.parametrize("ways", [1, 2, 4, 8])
@pytest.mark.parametrize("lines", [256, 1024])
def test_kernel_matches_scan_oracle(ways, lines, rng):
    cfg = CacheConfig(num_lines=lines, associativity=ways)
    lids = rng.integers(0, lines * 2, 96)
    out_k, out_r = _run_both(cfg, lids)
    for i, (a, b) in enumerate(zip(out_k, out_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"output {i}")


def test_kernel_matches_python_oracle(rng):
    cfg = CacheConfig(num_lines=512, associativity=4)
    lids = rng.integers(0, 700, 128)
    out_k, _ = _run_both(cfg, lids)
    hits_py, _ = hit_rate_oracle(cfg, lids)
    np.testing.assert_array_equal(np.asarray(out_k[0]) != 0, hits_py)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 600), min_size=1, max_size=60))
def test_property_three_way_agreement(lids):
    cfg = CacheConfig(num_lines=256, associativity=2)
    st0 = init_cache(cfg, 4)
    args = (jnp.asarray(lids, jnp.int32), st0.tags,
            st0.valid.astype(jnp.int32), st0.age, st0.clock)
    hits_k = np.asarray(cache_probe(*args)[0]) != 0
    hits_py, _ = hit_rate_oracle(cfg, np.asarray(lids))
    np.testing.assert_array_equal(hits_k, hits_py)


def test_lru_eviction_order():
    """Fill a set beyond its ways; the least-recently-used way must go."""
    cfg = CacheConfig(num_lines=256, associativity=2)  # 128 sets
    sets = cfg.num_sets
    # same set: line ids 0, sets, 2*sets all map to set 0
    seq = [0, sets, 0, 2 * sets, sets, 0]
    # beat3 evicts `sets` (LRU after the beat2 refresh of 0);
    # beat4 re-misses `sets` and evicts 0; beat5 therefore misses 0 again.
    out_k, out_r = _run_both(cfg, seq)
    hits = np.asarray(out_k[0]) != 0
    np.testing.assert_array_equal(
        hits, [False, False, True, False, False, False])
    np.testing.assert_array_equal(np.asarray(out_k[0]),
                                  np.asarray(out_r[0]))


def test_cache_service_value_identity(rng):
    cfg = CacheConfig(num_lines=256, associativity=4)
    table = jnp.asarray(rng.standard_normal((600, 8)), jnp.float32)
    lids = jnp.asarray(rng.integers(0, 600, 64), jnp.int32)
    state = init_cache(cfg, 8)
    lines, hits, new_state = cache_service(table, lids, state)
    np.testing.assert_allclose(np.asarray(lines), np.asarray(table[lids]))
    assert new_state.clock == 64
