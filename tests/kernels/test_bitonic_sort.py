"""Bitonic-sort kernel vs stable-sort oracle: sweeps + properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.bitonic_sort import ops, ref
from repro.kernels.bitonic_sort.kernel import sort_network
import jax


@pytest.mark.parametrize("n", [4, 8, 16, 64, 128, 512])
@pytest.mark.parametrize("key_range", [4, 1000])
def test_matches_stable_sort(n, key_range, rng):
    keys = jnp.asarray(rng.integers(0, key_range, n), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 10_000, n), jnp.int32)
    sk, perm, sv = ops.sort_with_indices(keys, vals)
    rk, rperm, rv = ref.sort_with_indices_ref(keys, vals)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(perm, rperm)   # stability ⇒ perm identical
    np.testing.assert_array_equal(sv, rv)


@pytest.mark.parametrize("n", [3, 5, 33, 100, 250])
def test_non_power_of_two_padding(n, rng):
    keys = jnp.asarray(rng.integers(0, 7, n), jnp.int32)
    sk, perm = ops.sort_with_indices(keys)
    rk, rperm, _ = ref.sort_with_indices_ref(keys, jnp.zeros_like(keys))
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(perm, rperm)


def test_batched_rows_sort_independently(rng):
    keys = jnp.asarray(rng.integers(0, 50, (7, 64)), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 9, (7, 64)), jnp.int32)
    sk, perm, sv = ops.sort_with_indices(keys, vals)
    rk, rperm, rv = ref.sort_with_indices_ref(keys, vals)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(perm, rperm)
    np.testing.assert_array_equal(sv, rv)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=2, max_size=128))
def test_property_sorted_permutation_stable(xs):
    """The output is (a) sorted, (b) a permutation, (c) stable on ties."""
    keys = jnp.asarray(xs, jnp.int32)
    sk, perm = ops.sort_with_indices(keys)
    sk, perm = np.asarray(sk), np.asarray(perm)
    assert (np.diff(sk) >= 0).all()                      # sorted
    assert sorted(perm.tolist()) == list(range(len(xs)))  # permutation
    # stability: among equal keys, original indices ascend
    for k in set(xs):
        idx = perm[sk == k]
        assert (np.diff(idx) > 0).all()


def test_network_stage_count_matches_eq1():
    """The network runs exactly log2(N)(log2(N)+1)/2 stages (Eq. 1 term)."""
    from repro.core.config import scheduler_sort_stages
    count = 0
    orig = __import__("repro.kernels.bitonic_sort.kernel",
                      fromlist=["_compare_exchange"])._compare_exchange

    def counting(*args, **kw):
        nonlocal count
        count += 1
        return orig(*args, **kw)

    import repro.kernels.bitonic_sort.kernel as km
    km_orig = km._compare_exchange
    km._compare_exchange = counting
    try:
        n = 64
        keys = jnp.arange(n, dtype=jnp.int32)
        sort_network(keys, keys, keys)
    finally:
        km._compare_exchange = km_orig
    assert count == scheduler_sort_stages(64)
