"""DMA-copy kernel: value identity across shapes, dtypes and channel counts."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import DMAConfig
from repro.kernels.dma_copy.ops import dma_copy
from repro.kernels.dma_copy.ref import dma_copy_ref


@pytest.mark.parametrize("shape", [(128,), (1000,), (17, 33), (4, 128, 9)])
@pytest.mark.parametrize("channels", [1, 2, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_identity(shape, channels, dtype, rng):
    x = jnp.asarray(rng.standard_normal(shape) * 5, dtype)
    cfg = DMAConfig(num_parallel_dma=channels, max_transaction_bytes=512)
    y = dma_copy(x, config=cfg)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(dma_copy_ref(x)))


@pytest.mark.parametrize("txn", [256, 1024, 65536])
def test_transaction_sizes(txn, rng):
    x = jnp.asarray(rng.standard_normal(5000), jnp.float32)
    y = dma_copy(x, config=DMAConfig(max_transaction_bytes=txn))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_more_channels_than_chunks(rng):
    """Prologue must not start copies past the last chunk."""
    x = jnp.asarray(rng.standard_normal(100), jnp.float32)  # 1 chunk
    y = dma_copy(x, config=DMAConfig(num_parallel_dma=8,
                                     max_transaction_bytes=65536))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
