"""Flash-attention Pallas kernel vs dense oracle: shape/dtype/mask sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.layers import flash_attention as flash_xla


def _qkv(key, B, S, H, KV, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd), dtype),
            jax.random.normal(ks[1], (B, S, KV, hd), dtype),
            jax.random.normal(ks[2], (B, S, KV, hd), dtype))


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (2, 128, 4, 2, 32),     # GQA
    (1, 256, 8, 8, 16),     # MHA
    (2, 128, 4, 1, 32),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_ref(B, S, H, KV, hd, causal, key):
    q, k, v = _qkv(key, B, S, H, KV, hd)
    out = flash_attention(q, k, v, causal=causal)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_sliding_window(window, key):
    q, k, v = _qkv(key, 1, 256, 4, 2, 32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_block=64, kv_block=64)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("qb,kb", [(32, 128), (128, 32), (64, 64)])
def test_block_shapes(qb, kb, key):
    q, k, v = _qkv(key, 1, 128, 2, 2, 16)
    out = flash_attention(q, k, v, q_block=qb, kv_block=kb)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_bf16_inputs(key):
    q, k, v = _qkv(key, 1, 128, 4, 2, 32, jnp.bfloat16)
    out = flash_attention(q, k, v)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_pallas_and_xla_paths_agree(key):
    """The kernel and the model's XLA flash path must match (same math,
    two execution strategies — VMEM-resident vs scanned accumulators)."""
    q, k, v = _qkv(key, 2, 128, 4, 2, 32)
    a = flash_attention(q, k, v, causal=True)
    b = flash_xla(q, k, v, causal=True, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=3e-5, rtol=3e-5)
