"""End-to-end system behaviour: the training loop learns, resume is exact,
serving produces coherent batches — the framework's top-level contract."""

import numpy as np
import pytest

from repro.launch.serve import Request, Server
from repro.launch.train import Trainer, TrainerConfig
from repro.optim.adamw import OptimizerConfig


def _tc(steps, ckpt_dir=None, arch="h2o-danube-1.8b", ckpt_every=50):
    return TrainerConfig(
        arch=arch, smoke=True, steps=steps, seed=0,
        batch_override=8, seq_override=64,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, log_every=1000,
        opt=OptimizerConfig(peak_lr=3e-3, warmup_steps=5, total_steps=200))


def test_training_reduces_loss():
    out = Trainer(_tc(steps=60)).run()
    first = np.mean(out["history"][:5])
    last = np.mean(out["history"][-5:])
    assert last < first - 0.1, (first, last)


def test_resume_is_bitwise_identical(tmp_path):
    ckpt = str(tmp_path / "ck")
    full = Trainer(_tc(steps=20, ckpt_dir=ckpt + "_a", ckpt_every=100)).run()
    # run 10 steps, checkpoint, resume for 10 more
    Trainer(_tc(steps=10, ckpt_dir=ckpt, ckpt_every=10)).run()
    resumed = Trainer(_tc(steps=20, ckpt_dir=ckpt, ckpt_every=10)).run()
    np.testing.assert_allclose(resumed["history"],
                               full["history"][10:], rtol=1e-6)


def test_serving_end_to_end():
    server = Server("h2o-danube-1.8b", smoke=True)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, server.cfg.vocab_size,
                                        12).astype(np.int32),
                    max_new_tokens=3, arrival_cycle=i)
            for i in range(5)]
    stats = server.serve(reqs)
    assert stats.requests == 5
    assert all(len(r.output) == 3 for r in reqs)
    assert all(0 <= t < server.cfg.vocab_size
               for r in reqs for t in r.output)


def test_serving_scheduler_batches_by_timeout():
    from repro.core.config import SchedulerConfig
    server = Server("h2o-danube-1.8b", smoke=True,
                    sched=SchedulerConfig(batch_size=64, timeout_cycles=4))
    rng = np.random.default_rng(1)
    # two bursts separated by > timeout
    reqs = [Request(rid=i, prompt=rng.integers(0, 100, 8).astype(np.int32),
                    max_new_tokens=2, arrival_cycle=(0 if i < 3 else 100))
            for i in range(6)]
    batches = server.admit(reqs)
    assert [len(b) for b in batches] == [3, 3]


def test_encoder_arch_refuses_decode():
    with pytest.raises(ValueError, match="encoder-only"):
        Server("hubert-xlarge", smoke=True)
