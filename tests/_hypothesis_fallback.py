"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The test suite uses a small slice of the hypothesis API (``given`` /
``settings`` / a handful of strategies). Some execution environments for
this repo cannot install third-party packages, so ``tests/conftest.py``
installs this deterministic fallback into ``sys.modules`` *only when the
real library is missing*. With hypothesis installed (as in CI, see
``pyproject.toml``), the real shrinking/coverage engine is used and this
file is inert.

The fallback draws ``max_examples`` pseudo-random examples from a
per-test seeded RNG — no shrinking, but the same property assertions run
over the same kinds of inputs, so a regression still fails the suite.
"""

from __future__ import annotations

import functools
import sys
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def floats(min_value=-1e6, max_value=1e6, **_ignored) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def tuples(*elems: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def lists(elem: _Strategy, *, min_size: int = 0,
          max_size: int = 20) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elem.example(rng) for _ in range(n)]
    return _Strategy(draw)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", 10)
            # Deterministic per-test stream: same examples every run.
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strategies)
                fn(*args, *drawn, **kwargs)
        # pytest resolves fixtures from the *unwrapped* signature; hide it
        # so drawn parameters are not mistaken for fixtures.
        del wrapper.__wrapped__
        return wrapper
    return deco


def install() -> None:
    """Register the fallback as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "sampled_from", "tuples",
                 "lists"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
