"""Golden-trace case definitions — shared by the regression test
(``tests/core/test_golden_pipeline.py``) and the regenerator
(``scripts/regen_goldens.py``), so the snapshot writer and the checker
can never disagree about what a case is.

Traces are built from ``np.random.default_rng`` *bit-generator* draws
only (``random`` / ``integers``) with the power-law shaping done in
plain arithmetic — no ``Generator.zipf`` — because numpy guarantees
stream stability for the bit generators while distribution methods may
be re-derived between releases.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.config import (CacheConfig, ChannelConfig, DRAMSchedConfig,
                               FaultConfig, MemoryControllerConfig,
                               PAPER_COMBINED_CONFIG, PAPER_EVAL_CONFIG,
                               SchedulerConfig)
from repro.core.controller import MemoryController

GOLDEN_DIR = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "goldens"))
N_REQUESTS = 4000
ROW_BYTES = 4096


def _powerlaw_rows(rng: np.random.Generator, n: int, n_rows: int,
                   alpha: float = 1.2) -> np.ndarray:
    """Zipf-shaped row ids from uniform draws (inverse-CDF arithmetic).

    The exponentiated tail is clamped below 2**62 before the int64
    cast: casting a float >= 2**63 is undefined behavior and lands on
    different values on x86 vs ARM, which would make the "stable"
    snapshots platform-dependent for unlucky seeds. (The pinned seeds
    stay well under the clamp, so it never changes a checked-in value.)
    """
    u = rng.random(n)
    ranks = np.floor(np.minimum(
        np.clip(u, 1e-12, 1.0) ** (-1.0 / (alpha - 1.0)), 2.0 ** 62))
    return (ranks.astype(np.int64) - 1) % n_rows


def gcn_trace(seed: int = 0, n: int = N_REQUESTS):
    """Zipf-popular adjacency/feature rows with ~10% write-backs."""
    rng = np.random.default_rng(seed)
    rows = _powerlaw_rows(rng, n, 8192)
    rw = (rng.random(n) < 0.1).astype(np.int32)
    return rows, rw


def cnn_trace(seed: int = 1, n: int = N_REQUESTS):
    """Sliding conv windows (overlapping re-reads) + periodic writes."""
    rng = np.random.default_rng(seed)
    sweep = (np.arange(n) // 4) % ((1 << 14) - 8)
    rows = (sweep + rng.integers(0, 8, n)).astype(np.int64)
    rw = (np.arange(n) % 8 == 7).astype(np.int32)
    return rows, rw


_SCHED_OFF = MemoryControllerConfig(
    scheduler=SchedulerConfig(enabled=False),
    cache=CacheConfig(enabled=False))

# name -> (config, trace builder, multiport?)
CASES: dict = {
    "paper_eval_gcn": (PAPER_EVAL_CONFIG, gcn_trace, False),
    "paper_eval_cnn": (PAPER_EVAL_CONFIG, cnn_trace, False),
    "paper_combined_gcn": (PAPER_COMBINED_CONFIG, gcn_trace, False),
    "paper_combined_cnn": (PAPER_COMBINED_CONFIG, cnn_trace, False),
    "paper_combined_multiport_gcn": (PAPER_COMBINED_CONFIG, gcn_trace,
                                     True),
    # the new order-dependent service models, pinned from PR 5 on
    "frfcfs16_combined_gcn": (
        dataclasses.replace(PAPER_COMBINED_CONFIG,
                            dram_sched=DRAMSchedConfig(
                                policy="frfcfs", reorder_window=16)),
        gcn_trace, False),
    "frfcfs_bare_gcn": (
        dataclasses.replace(_SCHED_OFF,
                            dram_sched=DRAMSchedConfig(
                                policy="frfcfs", reorder_window=32)),
        gcn_trace, False),
    "frfcfs_cap_refresh_gcn": (
        dataclasses.replace(_SCHED_OFF,
                            dram_sched=DRAMSchedConfig(
                                policy="frfcfs_cap", reorder_window=32,
                                starvation_cap=8, t_rfc=420,
                                t_refi=9363)),
        gcn_trace, False),
}


# ---------------------------------------------------------------------------
# Open-loop serving cases (PR 6): arrival-stamped streams through the
# serving datapath. Arrival processes come from ``repro.data.synthetic``
# which, like the traces above, draws only bit-generator primitives — the
# stamps are stream-stable across numpy releases.
# ---------------------------------------------------------------------------

N_SERVING = 3000


def _poisson_serving(seed: int = 3, n: int = N_SERVING):
    """Single-tenant Zipf-popular reads/writes arriving Poisson at a
    load near the knee of the frfcfs service curve."""
    from repro.data.synthetic import poisson_arrivals
    rng = np.random.default_rng(seed)
    rows = _powerlaw_rows(rng, n, 8192)
    rw = (rng.random(n) < 0.1).astype(np.int32)
    arr = poisson_arrivals(rng, n, 0.05)
    return rows, rw, None, arr


def _hog_victim_serving(seed: int = 4):
    """Two-tenant isolation stream: sparse SLO reads vs a bursty
    sequential hog (see ``repro.data.synthetic.hog_victim_workload``)."""
    from repro.data.synthetic import hog_victim_workload
    rows, rw, pe, arr = hog_victim_workload(
        np.random.default_rng(seed), n_victim=600, n_hog=2400,
        victim_rate=0.01, hog_rate=0.12)
    return rows, rw, pe, arr


# name -> (config, workload builder, arbiter policy, weights)
SERVING_CASES: dict = {
    "serving_poisson_frfcfs": (
        dataclasses.replace(_SCHED_OFF,
                            dram_sched=DRAMSchedConfig(
                                policy="frfcfs", reorder_window=16,
                                t_rfc=420, t_refi=9363)),
        _poisson_serving, "round_robin", None),
    "serving_hog_victim_weighted": (
        dataclasses.replace(_SCHED_OFF, num_pes=2,
                            dram_sched=DRAMSchedConfig(
                                policy="frfcfs_cap", reorder_window=32,
                                starvation_cap=8, t_rfc=420,
                                t_refi=9363)),
        _hog_victim_serving, "weighted", (4, 1)),
    # RAS layer (PR 7): the pinned records carry the FaultStats block —
    # the snapshot is the machine-readable witness that the storm and
    # the controller's response reproduce bit-for-bit.
    "faults_ecc_storm": (
        dataclasses.replace(_SCHED_OFF, num_pes=2,
                            dram_sched=DRAMSchedConfig(
                                policy="frfcfs_cap", reorder_window=32,
                                starvation_cap=8, t_rfc=420,
                                t_refi=9363),
                            faults=FaultConfig(
                                seed=11, transient_ber=0.004,
                                weak_row_fraction=0.02, weak_row_ber=0.5,
                                due_fraction=0.25, max_replays=4,
                                backoff_clocks=32,
                                row_retire_threshold=2,
                                refresh_escalate_threshold=40)),
        _hog_victim_serving, "weighted", (4, 1)),
    "faults_channel_outage": (
        dataclasses.replace(_SCHED_OFF,
                            channels=ChannelConfig(num_channels=2),
                            dram_sched=DRAMSchedConfig(
                                policy="frfcfs", reorder_window=16,
                                t_rfc=420, t_refi=9363),
                            faults=FaultConfig(
                                seed=5,
                                outage_windows=((0, 40000, 90000),
                                                (1, 120000, 150000)))),
        _poisson_serving, "round_robin", None),
}


def _serving_record(name: str) -> dict:
    config, workload, arb_policy, weights = SERVING_CASES[name]
    rows, rw, pe, arr = workload()
    res = MemoryController(config).simulate(
        pe, rows, rw, ROW_BYTES, arbiter_policy=arb_policy,
        weights=weights, arrival_cycle=arr)
    agg = res.as_channel_result()
    s = res.serving
    rec = {
        "n_requests": res.n_requests,
        "makespan_fpga_cycles": res.makespan_fpga_cycles,
        "dram_makespan_fpga_cycles": res.dram_makespan_fpga_cycles,
        "row_hits": agg.row_hits,
        "row_conflicts": agg.row_conflicts,
        "first_accesses": agg.first_accesses,
        "p50_sojourn": s.p50_sojourn,
        "p95_sojourn": s.p95_sojourn,
        "p99_sojourn": s.p99_sojourn,
        "mean_sojourn": s.mean_sojourn,
        "worst_sojourn": s.worst_sojourn,
        "sustained_req_per_cycle": s.sustained_req_per_cycle,
        "offered_req_per_cycle": s.offered_req_per_cycle,
        "idle_fpga_cycles": s.idle_fpga_cycles,
        # JSON keys are strings — stringify ports so the round-trip
        # compares equal in the checking test
        "per_tenant": {str(p): rec for p, rec in s.per_port.items()},
        "stage_requests": {st.name: [st.in_requests, st.out_requests]
                           for st in res.stages},
    }
    if res.fault is not None:
        # Fault cases pin the whole RAS observability block; fault-free
        # cases keep their pre-RAS schema (the zero-rate degeneracy is
        # "no fault key", not "a zero-filled fault key").
        rec["fault"] = res.fault.as_dict()
        rec["n_dropped_requests"] = int(res.dropped.sum())
    return rec


# ---------------------------------------------------------------------------
# Captured model-trace cases (PR 10): one pinned trace per model family
# (``tests/goldens/traces/<arch>.json``, written by
# ``scripts/regen_goldens.py --traces``) replayed closed-loop through the
# paper's combined configuration. The pinned *record* is the simulate()
# breakdown of the pinned *trace file* — byte-stable because both sides
# live on disk (model/numpy drift only shows up when the traces are
# deliberately recaptured).
# ---------------------------------------------------------------------------

def _model_trace_cases() -> dict:
    from repro.data.model_traces import FAMILY_REPRESENTATIVE
    return {f"model_trace_{family}": arch
            for family, arch in FAMILY_REPRESENTATIVE.items()}


MODEL_TRACE_CASES: dict = _model_trace_cases()


def _closed_loop_record(res) -> dict:
    agg = res.as_channel_result()
    return {
        "n_requests": res.n_requests,
        "makespan_fpga_cycles": res.makespan_fpga_cycles,
        "dram_makespan_fpga_cycles": res.dram_makespan_fpga_cycles,
        "arbitration_cycles": res.arbitration_cycles,
        "cache_hit_rate": res.cache_hit_rate,
        "requests_per_channel": list(res.requests_per_channel),
        "breakdown": res.breakdown(),
        "row_hits": agg.row_hits,
        "row_conflicts": agg.row_conflicts,
        "first_accesses": agg.first_accesses,
        "stage_requests": {s.name: [s.in_requests, s.out_requests]
                           for s in res.stages},
    }


def _model_trace_record(name: str) -> dict:
    from repro.data.model_traces import (REPLAY_ROW_BYTES,
                                         load_pinned_trace)
    arch = MODEL_TRACE_CASES[name]
    cap = load_pinned_trace(arch)
    pe, rows, rw = cap.replay_arrays(PAPER_COMBINED_CONFIG.num_pes)
    res = MemoryController(PAPER_COMBINED_CONFIG).simulate(
        pe, rows, rw, REPLAY_ROW_BYTES)
    rec = _closed_loop_record(res)
    rec["arch"] = arch
    rec["op_counts"] = cap.op_counts()
    return rec


def golden_record(name: str) -> dict:
    """Run one case through ``MemoryController.simulate`` and flatten
    the full ``PipelineResult`` view into a JSON-stable record."""
    if name in SERVING_CASES:
        return _serving_record(name)
    if name in MODEL_TRACE_CASES:
        return _model_trace_record(name)
    config, trace, multiport = CASES[name]
    rows, rw = trace()
    pe = None
    if multiport:
        pe = np.random.default_rng(2).integers(0, config.num_pes,
                                               rows.shape[0])
    res = MemoryController(config).simulate(pe, rows, rw, ROW_BYTES)
    return _closed_loop_record(res)
