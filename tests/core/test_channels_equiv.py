"""Multi-port / multi-channel front end vs its sequential oracles.

Every stage of the new front end keeps a request-at-a-time sibling:
``simulate_channels_seq`` (global walk over interleaved per-channel
bank/turnaround state), ``arbitrate_ports_seq`` (grant-per-slot loop over
per-port FIFOs), and the ``use_seq_oracle`` composition of the full
pipeline (seq arbiter + seq scheduler + per-request DRAM walk). These
property tests assert the fast paths are *bit-identical* across channel
counts, mapping policies, arbiter policies, timings presets and
multi-PE traces — the same contract as the set-parallel trace engine.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.channels import (AddressMap, arbitrate_ports,
                                 arbitrate_ports_seq, arbiter_fill_cycles,
                                 per_port_order_preserved,
                                 schedule_and_simulate_channels,
                                 simulate_channels, simulate_channels_seq,
                                 simulate_multiport_channels)
from repro.core.config import (ChannelConfig, MemoryControllerConfig,
                               SchedulerConfig)
from repro.core.controller import MemoryController
from repro.core.timing import DDR4_2400, HBM_V5E, simulate_dram_access

POLICIES = ("row_interleave", "block_interleave", "xor")


def _assert_channel_results_equal(a, b):
    assert a.makespan_fpga_cycles == b.makespan_fpga_cycles
    assert a.busy_fpga_cycles == b.busy_fpga_cycles
    assert a.arbitration_cycles == b.arbitration_cycles
    assert a.requests_per_channel == b.requests_per_channel
    assert [dataclasses.asdict(r) for r in a.per_channel] == \
        [dataclasses.asdict(r) for r in b.per_channel]
    if a.port_stats is not None or b.port_stats is not None:
        np.testing.assert_array_equal(a.port_stats.grants,
                                      b.port_stats.grants)
        np.testing.assert_array_equal(a.port_stats.stall_slots,
                                      b.port_stats.stall_slots)
        assert a.port_stats.fairness == b.port_stats.fairness


# ---------------------------------------------------------------------------
# Address map
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("num_channels", [1, 2, 4, 8])
def test_address_map_is_bijective(policy, num_channels, rng):
    cfg = ChannelConfig(num_channels=num_channels, policy=policy,
                        interleave_bytes=256)
    amap = AddressMap(cfg, DDR4_2400)
    addrs = np.unique(rng.integers(0, 1 << 26, 4096))
    ch = amap.channel_of(addrs)
    local = amap.local_addr(addrs)
    assert int(ch.min()) >= 0 and int(ch.max()) < num_channels
    # distinct addresses never collide in (channel, local)
    key = ch * (1 << 40) + local
    assert np.unique(key).size == addrs.size
    # decompose agrees with the per-channel open-row decode
    c2, bank, row = amap.decompose(addrs)
    np.testing.assert_array_equal(c2, ch)
    np.testing.assert_array_equal(bank, DDR4_2400.bank_of(local))
    np.testing.assert_array_equal(row, DDR4_2400.row_of(local))


def test_xor_policy_breaks_stride_camping():
    """A stride of granularity*num_channels camps on one channel under
    plain block interleave; the XOR fold spreads it."""
    cfg_block = ChannelConfig(num_channels=4, policy="block_interleave",
                              interleave_bytes=256)
    cfg_xor = ChannelConfig(num_channels=4, policy="xor",
                            interleave_bytes=256)
    addrs = np.arange(256, dtype=np.int64) * (256 * 4)
    camped = AddressMap(cfg_block, DDR4_2400).channel_of(addrs)
    spread = AddressMap(cfg_xor, DDR4_2400).channel_of(addrs)
    assert np.unique(camped).size == 1
    assert np.unique(spread).size == 4


# ---------------------------------------------------------------------------
# Channel-parallel simulator vs sequential oracle
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5000), st.integers(0, 1)),
                min_size=0, max_size=400),
       st.sampled_from([1, 2, 4, 8]),
       st.sampled_from(POLICIES),
       st.booleans(),
       st.booleans())
def test_property_channel_sim_identical(reqs, num_channels, policy,
                                        use_rw, hbm):
    timings = HBM_V5E if hbm else DDR4_2400
    cfg = ChannelConfig(num_channels=num_channels, policy=policy,
                        interleave_bytes=512)
    addrs = np.asarray([r[0] * 1024 for r in reqs], np.int64)
    rw = np.asarray([r[1] for r in reqs], np.int32) if use_rw else None
    fast = simulate_channels(addrs, timings, cfg, rw=rw)
    ref = simulate_channels_seq(addrs, timings, cfg, rw=rw)
    _assert_channel_results_equal(fast, ref)


def test_single_channel_matches_plain_simulator(rng):
    """C=1 is the paper's single-interface design: the channel layer must
    cost exactly what simulate_dram_access costs."""
    addrs = rng.integers(0, 1 << 24, 2000).astype(np.int64)
    rw = rng.integers(0, 2, 2000).astype(np.int32)
    plain = simulate_dram_access(addrs, DDR4_2400, rw=rw)
    chan = simulate_channels(addrs, DDR4_2400, ChannelConfig(), rw=rw)
    assert chan.makespan_fpga_cycles == plain.total_fpga_cycles
    assert chan.row_hits == plain.row_hits
    assert chan.row_conflicts == plain.row_conflicts


def test_makespan_bounded_by_single_channel(rng):
    """Splitting a trace over C channels can never cost more wall-clock
    than one channel serving everything (banks only get less loaded)."""
    addrs = (rng.integers(0, 1 << 16, 20000) * 64).astype(np.int64)
    one = simulate_channels(addrs, DDR4_2400, ChannelConfig())
    for c in (2, 4, 8):
        cfg = ChannelConfig(num_channels=c)
        multi = simulate_channels(addrs, DDR4_2400, cfg)
        assert multi.makespan_fpga_cycles <= one.makespan_fpga_cycles
        assert multi.busy_fpga_cycles <= one.busy_fpga_cycles * 1.5


# ---------------------------------------------------------------------------
# Arbiter vs sequential oracle
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=0, max_size=300),
       st.sampled_from(["round_robin", "priority", "weighted"]),
       st.sampled_from([1, 2, 3]))
def test_property_arbiter_identical(pe_ids, policy, wseed):
    num_ports = 8
    rng = np.random.default_rng(wseed)
    weights = rng.integers(1, 5, num_ports).tolist() \
        if policy == "weighted" else None
    pe = np.asarray(pe_ids, np.int64)
    p_fast, s_fast = arbitrate_ports(pe, num_ports=num_ports,
                                     policy=policy, weights=weights)
    p_seq, s_seq = arbitrate_ports_seq(pe, num_ports=num_ports,
                                       policy=policy, weights=weights)
    np.testing.assert_array_equal(p_fast, p_seq)
    np.testing.assert_array_equal(s_fast.grants, s_seq.grants)
    np.testing.assert_array_equal(s_fast.stall_slots, s_seq.stall_slots)
    assert s_fast.fairness == s_seq.fairness
    # grant order is a permutation and per-port arrival order survives
    assert sorted(p_fast.tolist()) == list(range(pe.size))
    for p in range(num_ports):
        mine = p_fast[pe[p_fast] == p]
        assert (np.diff(mine) > 0).all()


def test_round_robin_interleaves_and_priority_drains():
    pe = np.asarray([0, 0, 0, 1, 1, 2], np.int64)
    p_rr, _ = arbitrate_ports(pe, num_ports=3, policy="round_robin")
    np.testing.assert_array_equal(pe[p_rr], [0, 1, 2, 0, 1, 0])
    p_pr, _ = arbitrate_ports(pe, num_ports=3, policy="priority")
    np.testing.assert_array_equal(pe[p_pr], [0, 0, 0, 1, 1, 2])


def test_weighted_gives_heavy_port_consecutive_grants():
    pe = np.asarray([0, 1] * 6, np.int64)
    p, stats = arbitrate_ports(pe, num_ports=2, policy="weighted",
                               weights=[1, 3])
    np.testing.assert_array_equal(pe[p][:8], [0, 1, 1, 1, 0, 1, 1, 1])
    assert stats.grants.tolist() == [6, 6]


def test_arbiter_stats_stalls_and_fairness():
    # port 1 waits one slot for each of port 0's interleaved grants
    pe = np.asarray([0, 1, 0, 1], np.int64)
    _, stats = arbitrate_ports(pe, num_ports=2, policy="round_robin")
    assert stats.grants.tolist() == [2, 2]
    assert stats.stall_slots.tolist() == [1, 2]
    assert stats.fairness == 1.0
    _, skew = arbitrate_ports(np.asarray([0] * 9 + [1], np.int64),
                              num_ports=2, policy="priority")
    assert skew.fairness < 0.7
    assert arbiter_fill_cycles(1) == 0
    assert arbiter_fill_cycles(8) == 3


# ---------------------------------------------------------------------------
# Full front end: arbiter + mapping + scheduler + channels
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 800),
                          st.integers(0, 1)),
                min_size=0, max_size=250),
       st.sampled_from([1, 2, 4]),
       st.sampled_from(POLICIES),
       st.sampled_from(["round_robin", "priority", "weighted"]))
def test_property_multiport_pipeline_identical(reqs, num_channels,
                                               map_policy, arb_policy):
    """End-to-end bit-identity: vectorized arbiter + vectorized scheduler
    + channel-parallel simulation vs the all-sequential composition."""
    pe = np.asarray([r[0] for r in reqs], np.int64)
    addrs = np.asarray([r[1] * 4096 for r in reqs], np.int64)
    rw = np.asarray([r[2] for r in reqs], np.int32)
    weights = [1, 3, 2, 1] if arb_policy == "weighted" else None
    kwargs = dict(num_ports=4, policy=arb_policy, weights=weights,
                  timings=DDR4_2400,
                  channel_cfg=ChannelConfig(num_channels=num_channels,
                                            policy=map_policy),
                  sched_config=SchedulerConfig(batch_size=16))
    fast = simulate_multiport_channels(pe, addrs, rw, **kwargs)
    ref = simulate_multiport_channels(pe, addrs, rw, use_seq_oracle=True,
                                      **kwargs)
    _assert_channel_results_equal(fast, ref)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 600), st.integers(0, 1)),
                min_size=0, max_size=300),
       st.sampled_from([1, 2, 8]),
       st.booleans())
def test_property_scheduled_channel_pipeline_identical(reqs, num_channels,
                                                       coalesce):
    addrs = np.asarray([r[0] * 4096 for r in reqs], np.int64)
    rw = np.asarray([r[1] for r in reqs], np.int32)
    kwargs = dict(sched_config=SchedulerConfig(batch_size=32),
                  timings=DDR4_2400,
                  channel_cfg=ChannelConfig(num_channels=num_channels),
                  coalesce_writes=coalesce)
    fast = schedule_and_simulate_channels(addrs, rw, **kwargs)
    ref = schedule_and_simulate_channels(addrs, rw, use_seq_oracle=True,
                                         **kwargs)
    _assert_channel_results_equal(fast, ref)


def test_multiport_preserves_per_port_order_within_channel(rng):
    """The weak-consistency prerequisite the arbiter provides: inside
    every channel queue, each port's requests appear in arrival order
    (across channels a port's requests may complete out of order — that
    is the channel parallelism being modeled)."""
    n = 2000
    pe = rng.integers(0, 8, n)
    addrs = (rng.integers(0, 1 << 14, n) * 512).astype(np.int64)
    for policy, w in (("round_robin", None), ("priority", None),
                      ("weighted", [1, 2, 1, 4, 1, 1, 2, 1])):
        assert per_port_order_preserved(
            pe, addrs, num_ports=8,
            channel_cfg=ChannelConfig(num_channels=4),
            policy=policy, weights=w)


def test_controller_multichannel_makespan_improves(rng):
    """modeled_access_time with 4 channels beats the single-interface
    controller on an irregular trace, and the multiport entry point
    reports coherent stats."""
    rows = rng.integers(0, 1 << 14, 30000)
    rw = rng.integers(0, 2, 30000)
    pe = rng.integers(0, 8, 30000)
    mc1 = MemoryController(MemoryControllerConfig())
    mc4 = MemoryController(MemoryControllerConfig(
        channels=ChannelConfig(num_channels=4)))
    t1 = mc1.modeled_access_time(rows, rw, 512).total_fpga_cycles
    t4 = mc4.modeled_access_time(rows, rw, 512).total_fpga_cycles
    assert t4 < t1
    full = mc4.modeled_channel_access_time(rows, rw, 512)
    assert len(full.per_channel) == 4
    assert sum(full.requests_per_channel) == 30000
    mp = mc4.modeled_multiport_access_time(pe, rows, rw, 512)
    assert mp.port_stats.grants.sum() == 30000
    assert 0.9 < mp.port_stats.fairness <= 1.0
    assert mp.arbitration_cycles == arbiter_fill_cycles(8)
