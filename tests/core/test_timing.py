"""Timing model: Eq. 1-3 values and DRAM-simulator properties (paper §IV)."""

import numpy as np
import pytest

from repro.core.config import (ChannelConfig, MemoryControllerConfig,
                               SchedulerConfig, scheduler_sort_stages)
from repro.core.timing import (DDR4_2400, DRAMTimings, HBM_V5E,
                               simulate_dram_access, t_cache_trace,
                               t_dma_transfer, t_schedule,
                               turnaround_cycles)


def test_eq1_schedule_time():
    # T_sch = N + log2(N)(log2(N)+1)/2 + L_cond
    assert t_schedule(64, 2) == 64 + 6 * 7 / 2 + 2
    assert t_schedule(4, 2) == 4 + 2 * 3 / 2 + 2
    assert scheduler_sort_stages(128) == 7 * 8 // 2


def test_derived_dram_averages():
    t = DDR4_2400
    # T_mem_seq = T_cl * T_mem / T_fpga ; T_mem_rand adds rp+rcd
    np.testing.assert_allclose(t.t_mem_seq(), 17 * 0.833 / 3.333, rtol=1e-6)
    np.testing.assert_allclose(
        t.t_mem_rand(), (17 + 17 + 17) * 0.833 / 3.333, rtol=1e-6)
    # paper: row hits save 2-3x vs conflicts
    assert 2.0 <= t.t_mem_rand() / t.t_mem_seq() <= 3.0 + 1e-9


def test_sequential_beats_random_access():
    seq = np.arange(4096) * 64                      # walks rows in order
    rnd = np.random.default_rng(0).integers(0, 1 << 24, 4096) * 64
    r_seq = simulate_dram_access(seq)
    r_rnd = simulate_dram_access(rnd)
    assert r_seq.total_fpga_cycles < r_rnd.total_fpga_cycles
    assert r_seq.hit_rate > 0.9
    assert r_rnd.hit_rate < 0.2


def test_same_row_stream_is_all_hits():
    addrs = np.full(100, 8192 * 3) + np.arange(100) % 64
    r = simulate_dram_access(addrs)
    assert r.row_hits == 99 and r.first_accesses == 1


def test_turnaround_cycles_counts_direction_edges():
    t = DDR4_2400
    assert turnaround_cycles([0, 0, 0], t) == 0
    assert turnaround_cycles([0, 1], t) == t.t_rtw
    assert turnaround_cycles([1, 0], t) == t.t_wtr
    assert turnaround_cycles([0, 1, 0, 1], t) == 2 * t.t_rtw + t.t_wtr
    assert turnaround_cycles([1], t) == 0
    assert turnaround_cycles([], t) == 0


def test_rw_stream_pays_turnaround_over_batched():
    """Same addresses: alternating R/W costs more than reads-then-writes
    (the single-type-batch economics of the scheduler)."""
    addrs = np.tile(np.arange(64) * 64, 2)
    alternating = np.array([0, 1] * 64)
    batched = np.array([0] * 64 + [1] * 64)
    t_alt = simulate_dram_access(addrs, rw=alternating).total_fpga_cycles
    t_bat = simulate_dram_access(addrs, rw=batched).total_fpga_cycles
    assert t_bat < t_alt
    # without rw, request types don't exist and the two cost the same
    legacy = simulate_dram_access(addrs).total_fpga_cycles
    assert legacy < t_bat < t_alt


def test_rw_none_matches_legacy_costing():
    addrs = np.random.default_rng(0).integers(0, 1 << 20, 512) * 64
    legacy = simulate_dram_access(addrs)
    all_reads = simulate_dram_access(addrs, rw=np.zeros(512, np.int32))
    assert legacy.total_fpga_cycles == all_reads.total_fpga_cycles


def test_hbm_preset_overrides_ddr4_turnaround():
    """Regression: HBM_V5E used to inherit DDR4's bus-turnaround defaults
    (t_wtr=8 / t_rtw=4, DDR4 command clocks). The preset must carry its
    own HBM-appropriate values — single-cycle burst occupancy leaves far
    less bus tail to drain — and the two presets must actually differ."""
    assert (HBM_V5E.t_wtr, HBM_V5E.t_rtw) != (DDR4_2400.t_wtr,
                                              DDR4_2400.t_rtw)
    assert HBM_V5E.t_wtr < DDR4_2400.t_wtr
    assert HBM_V5E.t_rtw < DDR4_2400.t_rtw
    # turnarounds scale with burst occupancy: HBM streams a burst in 1
    # command clock vs DDR4's 4, so its direction-change gaps are smaller
    assert HBM_V5E.t_burst < DDR4_2400.t_burst
    rw = np.array([0, 1] * 32)
    assert turnaround_cycles(rw, HBM_V5E) < turnaround_cycles(rw, DDR4_2400)


def test_eq3_channel_overlap_is_slowest_channel():
    """Per-channel Eq. 3: with elements spread over channels the element
    term collapses to the slowest channel's share; camping every element
    on one channel recovers the single-interface equation exactly."""
    cfg = MemoryControllerConfig(channels=ChannelConfig(num_channels=4))
    n = 256
    mask = np.zeros(n, bool)
    single = t_dma_transfer(cfg, n, mask)
    balanced = t_dma_transfer(cfg, n, mask,
                              channel_ids=np.arange(n) % 4)
    camped = t_dma_transfer(cfg, n, mask,
                            channel_ids=np.zeros(n, np.int64))
    assert camped == single
    assert balanced < single
    np.testing.assert_allclose(single - balanced,
                               (single - t_dma_transfer(cfg, 0,
                                                        np.zeros(0, bool)))
                               * 0.75, rtol=1e-9)


def test_eq2_cache_trace_hits_cheaper():
    cfg = MemoryControllerConfig()
    all_hits = t_cache_trace(cfg, np.ones(100, bool), t_mem_access=20.0)
    all_miss = t_cache_trace(cfg, np.zeros(100, bool), t_mem_access=20.0)
    assert all_hits < all_miss


def test_eq3_dma_seq_vs_rand_and_channels():
    cfg1 = MemoryControllerConfig()
    seq = t_dma_transfer(cfg1, 256, np.ones(256, bool))
    rnd = t_dma_transfer(cfg1, 256, np.zeros(256, bool))
    assert seq < rnd
    import dataclasses
    from repro.core.config import DMAConfig
    cfg8 = dataclasses.replace(cfg1, dma=DMAConfig(num_parallel_dma=8))
    assert t_dma_transfer(cfg8, 256, np.zeros(256, bool)) < rnd


def test_dma_exclusive_access_type():
    cfg = MemoryControllerConfig()
    with pytest.raises(ValueError):
        t_dma_transfer(cfg, 10, np.ones(5, bool))   # wrong mask length
