"""autotune.tune — grid membership, improvement over the seed config, and
the new channel/mapping search axes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import _score, sweep_serving_loads, tune
from repro.core.config import (CacheConfig, DRAMSchedConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.controller import MemoryController
from repro.core.timing import DDR4_2400


@pytest.fixture
def trace(rng):
    # Zipf-hot rows: cacheable head, irregular tail — the regime where
    # batch size, cache shape and channel count all matter.
    return ((rng.zipf(1.3, 4096) - 1) % 2048).astype(np.int64)


def test_tune_returns_config_from_the_searched_grid(trace):
    grids = dict(batch_sizes=(16, 64), associativities=(1, 4),
                 num_lines=(1024, 4096), dma_channels=(1, 4),
                 num_channels=(1, 4),
                 mapping_policies=("row_interleave", "xor"))
    res = tune(trace, 512, **grids)
    cfg = res.config
    assert cfg.scheduler.batch_size in grids["batch_sizes"]
    assert cfg.cache.associativity in grids["associativities"]
    assert cfg.cache.num_lines in grids["num_lines"]
    assert cfg.dma.num_parallel_dma in grids["dma_channels"]
    assert cfg.channels.num_channels in grids["num_channels"]
    assert cfg.channels.policy in grids["mapping_policies"]
    # every feasible grid point was scored and the winner is the argmin
    assert res.candidates_evaluated == len(res.table)
    assert res.modeled_cycles == min(c for _, c in res.table)


def test_tune_beats_seed_config_on_fixed_trace(trace):
    """The tuned config's modeled score must be no worse than the
    Table-I default configuration scored on the same trace (the seed is
    in the search space, so the grid argmin can only improve on it)."""
    seed_cfg = MemoryControllerConfig()
    seed_cycles = _score(seed_cfg, trace, 512, timings=DDR4_2400)
    res = tune(trace, 512,
               batch_sizes=(seed_cfg.scheduler.batch_size, 128),
               associativities=(seed_cfg.cache.associativity,),
               num_lines=(seed_cfg.cache.num_lines,),
               dma_channels=(seed_cfg.dma.num_parallel_dma,),
               num_channels=(1, 2, 4))
    assert res.modeled_cycles <= seed_cycles
    # the channel axis is genuinely helping on an irregular trace: the
    # best multi-channel candidate beats every single-channel candidate
    best_multi = min(c for d, c in res.table if "mem_ch=4" in d)
    best_single = min(c for d, c in res.table if "mem_ch=1" in d)
    assert best_multi < best_single


def test_tune_exercises_channel_and_mapping_axes(trace):
    res = tune(trace, 512, batch_sizes=(64,), associativities=(4,),
               num_lines=(4096,), dma_channels=(4,),
               num_channels=(1, 2), mapping_policies=("row_interleave",
                                                      "block_interleave",
                                                      "xor"))
    descs = [d for d, _ in res.table]
    # one channel collapses the policy axis (identity map); two channels
    # score every policy
    assert sum("mem_ch=1" in d for d in descs) == 1
    assert sum("mem_ch=2" in d for d in descs) == 3
    assert {d.split("map=")[1].split()[0] for d in descs
            if "mem_ch=2" in d} == \
        {"row_interleave", "block_interleave", "xor"}


def test_tune_channel_axis_respects_vmem_budget(trace):
    """Per-channel scheduler queues multiply the footprint: a budget that
    fits one channel's queues but not eight must prune the 8-channel
    candidates rather than crash."""
    budget = 600 << 10          # fits 1-channel queues (~392KiB), not 8
    res = tune(trace, 512, vmem_budget_bytes=budget,
               batch_sizes=(512,), associativities=(4,),
               num_lines=(4096,), dma_channels=(1,),
               num_channels=(1, 8))
    assert res.config.vmem_footprint_bytes() <= budget
    assert all("mem_ch=8" not in d for d, _ in res.table)


def test_tune_exercises_dram_sched_axes(trace):
    """dram_sched_policies x reorder_windows join the grid; the
    FIFO/window-1 collapse is deduplicated (fifo is scored once, not
    once per window), and the winner carries a config from the grid."""
    res = tune(trace, 512, batch_sizes=(64,), associativities=(4,),
               num_lines=(4096,), dma_channels=(4,),
               dram_sched_policies=("fifo", "frfcfs"),
               reorder_windows=(1, 8, 32))
    descs = [d for d, _ in res.table]
    assert sum("dsched=fifo:1" in d for d in descs) == 1
    assert sum("dsched=frfcfs:8" in d for d in descs) == 1
    assert sum("dsched=frfcfs:32" in d for d in descs) == 1
    assert len(descs) == 3
    assert res.config.dram_sched.policy in ("fifo", "frfcfs")
    assert res.config.dram_sched.reorder_window in (1, 8, 32)
    # on a zipf-hot trace with the cache absorbing the head, deeper
    # reorder windows can only help the modeled DRAM service — the
    # frfcfs candidates must not lose to fifo
    best_fr = min(c for d, c in res.table if "frfcfs" in d)
    fifo_c = next(c for d, c in res.table if "dsched=fifo:1" in d)
    assert best_fr <= fifo_c


def test_tune_default_grid_unchanged(trace):
    """The default axes keep the pre-PR search space: every candidate
    is scored with the FIFO window-1 service model."""
    res = tune(trace, 512, batch_sizes=(16,), associativities=(4,),
               num_lines=(1024,), dma_channels=(1,))
    assert all("dsched=fifo:1" in d for d, _ in res.table)
    assert res.config.dram_sched == \
        MemoryControllerConfig().dram_sched


def test_tune_serving_constrained_selection():
    """tune_serving searches arbiter x scheduler QoS knobs: the winner
    comes from the grid, every candidate is tabulated, and a feasible
    SLO target flips the objective from p99-min to makespan-min among
    feasible candidates."""
    from repro.core.autotune import tune_serving
    from repro.data.synthetic import hog_victim_workload

    rows, rw, pe, arr = hog_victim_workload(
        np.random.default_rng(7), n_victim=200, n_hog=800,
        victim_rate=0.02, hog_rate=0.2)
    res = tune_serving(rows, rw, pe, arr, 4096, num_ports=2,
                       arb_policies=("round_robin", "weighted"),
                       weight_ratios=(4,),
                       dram_sched_policies=("frfcfs", "frfcfs_cap"),
                       reorder_windows=(16,), starvation_caps=(8,))
    # 2 arb x 2 sched candidates, all tabulated
    assert res.candidates_evaluated == len(res.table) == 4
    assert res.arb_policy in ("round_robin", "weighted")
    assert res.config.dram_sched.policy in ("frfcfs", "frfcfs_cap")
    assert res.slo_p99_cycles > 0 and res.makespan_cycles > 0
    # no target: objective is the SLO port's p99 outright
    assert not res.feasible
    assert res.slo_p99_cycles == min(p for _, p, _ in res.table)
    # a generous target makes every candidate feasible -> makespan-min
    res2 = tune_serving(rows, rw, pe, arr, 4096, num_ports=2,
                        slo_p99_cycles=1e12,
                        arb_policies=("round_robin", "weighted"),
                        weight_ratios=(4,),
                        dram_sched_policies=("frfcfs", "frfcfs_cap"),
                        reorder_windows=(16,), starvation_caps=(8,))
    assert res2.feasible
    assert res2.makespan_cycles == min(m for _, _, m in res2.table)


# ---------------------------------------------------------------------------
# Batched grid scorer == one-at-a-time oracle (ISSUE 9 tentpole c)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(1.05, 1 << 14, 64), (1.3, 2048, 512),
                        (1.2, 256, 4096)]),
       st.sampled_from([((16, 64), (1, 4), (1024, 4096)),
                        ((8,), (2,), (256, 16384))]),
       st.sampled_from([((1,), ("row_interleave",)),
                        ((1, 2, 4), ("row_interleave", "xor")),
                        ((2,), ("block_interleave",))]),
       st.sampled_from([(("fifo",), (1,)),
                        (("fifo", "frfcfs"), (1, 8)),
                        (("frfcfs", "frfcfs_cap"), (4, 32))]),
       st.booleans(),
       st.integers(0, 5))
def test_property_batched_tune_matches_oracle(workload, cache_axes,
                                              chan_axes, sched_axes,
                                              enable_cache, seed):
    """tune(engine='batched') must reproduce tune(engine='oracle') bit
    for bit — every table entry, the argmin config, the modeled score
    and the candidate count — across cache/channel/sched grids, skew
    levels and cache-off runs."""
    skew, n_rows, row_bytes = workload
    batches, ways, lines = cache_axes
    n_chans, mappings = chan_axes
    spols, wins = sched_axes
    rng = np.random.default_rng(seed)
    rows = ((rng.zipf(skew, 1500) - 1) % n_rows).astype(np.int64)
    grids = dict(batch_sizes=batches, associativities=ways,
                 num_lines=lines, dma_channels=(1, 4),
                 num_channels=n_chans, mapping_policies=mappings,
                 dram_sched_policies=spols, reorder_windows=wins,
                 enable_cache=enable_cache)
    a = tune(rows, row_bytes, engine="oracle", **grids)
    b = tune(rows, row_bytes, engine="batched", **grids)
    assert a.table == b.table
    assert a.config == b.config
    assert a.modeled_cycles == b.modeled_cycles
    assert a.candidates_evaluated == b.candidates_evaluated


def test_batched_tune_tiny_and_degenerate_traces():
    """Five-request and single-request traces: the vectorized batch
    plan and fused-key classification must survive the degenerate
    shapes (partial batches everywhere, empty channels after
    splitting)."""
    for rows in (np.asarray([7], np.int64),
                 np.asarray([3, 3, 9, 3, 11], np.int64)):
        a = tune(rows, 4096, engine="oracle",
                 batch_sizes=(4, 64), associativities=(1,),
                 num_lines=(1024,), dma_channels=(1,),
                 num_channels=(1, 4),
                 dram_sched_policies=("fifo", "frfcfs"),
                 reorder_windows=(1, 8))
        b = tune(rows, 4096, engine="batched",
                 batch_sizes=(4, 64), associativities=(1,),
                 num_lines=(1024,), dma_channels=(1,),
                 num_channels=(1, 4),
                 dram_sched_policies=("fifo", "frfcfs"),
                 reorder_windows=(1, 8))
        assert a.table == b.table and a.config == b.config


def test_tune_rejects_unknown_engine(trace):
    with pytest.raises(ValueError, match="unknown tune engine"):
        tune(trace, 512, engine="vmapped")


# ---------------------------------------------------------------------------
# sweep_serving_loads == MemoryController.simulate per point
# ---------------------------------------------------------------------------

def test_sweep_serving_loads_matches_controller(rng):
    n = 3000
    rows = ((rng.zipf(1.2, n) - 1) % 4096).astype(np.int64)
    rw = (rng.random(n) < 0.2).astype(np.int32)
    cfg = MemoryControllerConfig(
        scheduler=SchedulerConfig(enabled=False),
        cache=CacheConfig(enabled=False),
        dram_sched=DRAMSchedConfig(policy="frfcfs_cap",
                                   reorder_window=16, starvation_cap=8,
                                   t_rfc=420, t_refi=9363))
    cap = 0.09
    arrivals = [np.cumsum(rng.exponential(1.0 / (cap * f), n))
                for f in (0.5, 1.2)]
    swept = sweep_serving_loads(cfg, rows, rw, None, arrivals, 4096)
    mc = MemoryController(cfg)
    for arr, res in zip(arrivals, swept):
        ref = mc.simulate(None, rows, rw, 4096, arrival_cycle=arr)
        assert ref.makespan_fpga_cycles == res.makespan_fpga_cycles
        assert ref.serving.p50_sojourn == res.serving.p50_sojourn
        assert ref.serving.p99_sojourn == res.serving.p99_sojourn
        assert (ref.serving.sustained_req_per_cycle
                == res.serving.sustained_req_per_cycle)
        np.testing.assert_array_equal(ref.serving.sojourn_fpga_cycles,
                                      res.serving.sojourn_fpga_cycles)


def test_sweep_serving_loads_multiport_weighted(rng):
    n = 2000
    rows = ((rng.zipf(1.3, n) - 1) % 2048).astype(np.int64)
    pe = rng.integers(0, 2, n).astype(np.int32)
    arr = np.cumsum(rng.exponential(8.0, n))
    cfg = MemoryControllerConfig(
        num_pes=2,
        scheduler=SchedulerConfig(enabled=False),
        cache=CacheConfig(enabled=False),
        dram_sched=DRAMSchedConfig(policy="frfcfs", reorder_window=8))
    swept = sweep_serving_loads(cfg, rows, None, pe, [arr], 4096,
                                arbiter_policy="weighted",
                                weights=(4, 1))
    ref = MemoryController(cfg).simulate(
        pe, rows, None, 4096,
        arbiter_policy="weighted", weights=(4, 1), arrival_cycle=arr)
    res = swept[0]
    assert ref.makespan_fpga_cycles == res.makespan_fpga_cycles
    for p in ("0", "1"):
        assert (ref.serving.per_port[int(p)]["p99_sojourn"]
                == res.serving.per_port[int(p)]["p99_sojourn"])


def test_sweep_serving_loads_validates_arrivals(rng):
    rows = np.arange(64, dtype=np.int64)
    cfg = MemoryControllerConfig(
        scheduler=SchedulerConfig(enabled=False),
        cache=CacheConfig(enabled=False))
    with pytest.raises(ValueError, match="one entry per request"):
        sweep_serving_loads(cfg, rows, None, None,
                            [np.zeros(3)], 4096)
    with pytest.raises(ValueError, match="finite"):
        sweep_serving_loads(cfg, rows, None, None,
                            [np.full(64, np.nan)], 4096)
