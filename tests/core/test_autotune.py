"""autotune.tune — grid membership, improvement over the seed config, and
the new channel/mapping search axes."""

import numpy as np
import pytest

from repro.core.autotune import _score, tune
from repro.core.config import MemoryControllerConfig
from repro.core.timing import DDR4_2400


@pytest.fixture
def trace(rng):
    # Zipf-hot rows: cacheable head, irregular tail — the regime where
    # batch size, cache shape and channel count all matter.
    return ((rng.zipf(1.3, 4096) - 1) % 2048).astype(np.int64)


def test_tune_returns_config_from_the_searched_grid(trace):
    grids = dict(batch_sizes=(16, 64), associativities=(1, 4),
                 num_lines=(1024, 4096), dma_channels=(1, 4),
                 num_channels=(1, 4),
                 mapping_policies=("row_interleave", "xor"))
    res = tune(trace, 512, **grids)
    cfg = res.config
    assert cfg.scheduler.batch_size in grids["batch_sizes"]
    assert cfg.cache.associativity in grids["associativities"]
    assert cfg.cache.num_lines in grids["num_lines"]
    assert cfg.dma.num_parallel_dma in grids["dma_channels"]
    assert cfg.channels.num_channels in grids["num_channels"]
    assert cfg.channels.policy in grids["mapping_policies"]
    # every feasible grid point was scored and the winner is the argmin
    assert res.candidates_evaluated == len(res.table)
    assert res.modeled_cycles == min(c for _, c in res.table)


def test_tune_beats_seed_config_on_fixed_trace(trace):
    """The tuned config's modeled score must be no worse than the
    Table-I default configuration scored on the same trace (the seed is
    in the search space, so the grid argmin can only improve on it)."""
    seed_cfg = MemoryControllerConfig()
    seed_cycles = _score(seed_cfg, trace, 512, timings=DDR4_2400)
    res = tune(trace, 512,
               batch_sizes=(seed_cfg.scheduler.batch_size, 128),
               associativities=(seed_cfg.cache.associativity,),
               num_lines=(seed_cfg.cache.num_lines,),
               dma_channels=(seed_cfg.dma.num_parallel_dma,),
               num_channels=(1, 2, 4))
    assert res.modeled_cycles <= seed_cycles
    # the channel axis is genuinely helping on an irregular trace: the
    # best multi-channel candidate beats every single-channel candidate
    best_multi = min(c for d, c in res.table if "mem_ch=4" in d)
    best_single = min(c for d, c in res.table if "mem_ch=1" in d)
    assert best_multi < best_single


def test_tune_exercises_channel_and_mapping_axes(trace):
    res = tune(trace, 512, batch_sizes=(64,), associativities=(4,),
               num_lines=(4096,), dma_channels=(4,),
               num_channels=(1, 2), mapping_policies=("row_interleave",
                                                      "block_interleave",
                                                      "xor"))
    descs = [d for d, _ in res.table]
    # one channel collapses the policy axis (identity map); two channels
    # score every policy
    assert sum("mem_ch=1" in d for d in descs) == 1
    assert sum("mem_ch=2" in d for d in descs) == 3
    assert {d.split("map=")[1].split()[0] for d in descs
            if "mem_ch=2" in d} == \
        {"row_interleave", "block_interleave", "xor"}


def test_tune_channel_axis_respects_vmem_budget(trace):
    """Per-channel scheduler queues multiply the footprint: a budget that
    fits one channel's queues but not eight must prune the 8-channel
    candidates rather than crash."""
    budget = 600 << 10          # fits 1-channel queues (~392KiB), not 8
    res = tune(trace, 512, vmem_budget_bytes=budget,
               batch_sizes=(512,), associativities=(4,),
               num_lines=(4096,), dma_channels=(1,),
               num_channels=(1, 8))
    assert res.config.vmem_footprint_bytes() <= budget
    assert all("mem_ch=8" not in d for d, _ in res.table)


def test_tune_exercises_dram_sched_axes(trace):
    """dram_sched_policies x reorder_windows join the grid; the
    FIFO/window-1 collapse is deduplicated (fifo is scored once, not
    once per window), and the winner carries a config from the grid."""
    res = tune(trace, 512, batch_sizes=(64,), associativities=(4,),
               num_lines=(4096,), dma_channels=(4,),
               dram_sched_policies=("fifo", "frfcfs"),
               reorder_windows=(1, 8, 32))
    descs = [d for d, _ in res.table]
    assert sum("dsched=fifo:1" in d for d in descs) == 1
    assert sum("dsched=frfcfs:8" in d for d in descs) == 1
    assert sum("dsched=frfcfs:32" in d for d in descs) == 1
    assert len(descs) == 3
    assert res.config.dram_sched.policy in ("fifo", "frfcfs")
    assert res.config.dram_sched.reorder_window in (1, 8, 32)
    # on a zipf-hot trace with the cache absorbing the head, deeper
    # reorder windows can only help the modeled DRAM service — the
    # frfcfs candidates must not lose to fifo
    best_fr = min(c for d, c in res.table if "frfcfs" in d)
    fifo_c = next(c for d, c in res.table if "dsched=fifo:1" in d)
    assert best_fr <= fifo_c


def test_tune_default_grid_unchanged(trace):
    """The default axes keep the pre-PR search space: every candidate
    is scored with the FIFO window-1 service model."""
    res = tune(trace, 512, batch_sizes=(16,), associativities=(4,),
               num_lines=(1024,), dma_channels=(1,))
    assert all("dsched=fifo:1" in d for d, _ in res.table)
    assert res.config.dram_sched == \
        MemoryControllerConfig().dram_sched


def test_tune_serving_constrained_selection():
    """tune_serving searches arbiter x scheduler QoS knobs: the winner
    comes from the grid, every candidate is tabulated, and a feasible
    SLO target flips the objective from p99-min to makespan-min among
    feasible candidates."""
    from repro.core.autotune import tune_serving
    from repro.data.synthetic import hog_victim_workload

    rows, rw, pe, arr = hog_victim_workload(
        np.random.default_rng(7), n_victim=200, n_hog=800,
        victim_rate=0.02, hog_rate=0.2)
    res = tune_serving(rows, rw, pe, arr, 4096, num_ports=2,
                       arb_policies=("round_robin", "weighted"),
                       weight_ratios=(4,),
                       dram_sched_policies=("frfcfs", "frfcfs_cap"),
                       reorder_windows=(16,), starvation_caps=(8,))
    # 2 arb x 2 sched candidates, all tabulated
    assert res.candidates_evaluated == len(res.table) == 4
    assert res.arb_policy in ("round_robin", "weighted")
    assert res.config.dram_sched.policy in ("frfcfs", "frfcfs_cap")
    assert res.slo_p99_cycles > 0 and res.makespan_cycles > 0
    # no target: objective is the SLO port's p99 outright
    assert not res.feasible
    assert res.slo_p99_cycles == min(p for _, p, _ in res.table)
    # a generous target makes every candidate feasible -> makespan-min
    res2 = tune_serving(rows, rw, pe, arr, 4096, num_ports=2,
                        slo_p99_cycles=1e12,
                        arb_policies=("round_robin", "weighted"),
                        weight_ratios=(4,),
                        dram_sched_policies=("frfcfs", "frfcfs_cap"),
                        reorder_windows=(16,), starvation_caps=(8,))
    assert res2.feasible
    assert res2.makespan_cycles == min(m for _, _, m in res2.table)
