"""Set-parallel / vectorized fast paths vs their sequential oracles.

Every hot loop that was vectorized in the trace engine PR keeps its
original request-at-a-time implementation as a ``*_seq`` sibling; these
property tests assert the fast paths are *value- and state-identical*
(bit-for-bit, not approximately) across random configurations — sets,
ways, write policies, timeouts, mixed read/write streams, skewed traces
and chained (dirty) cache states.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cache_engine import (flush, hit_rate_oracle,
                                     hit_rate_oracle_seq, init_cache,
                                     simulate_trace, simulate_trace_seq,
                                     simulate_trace_rw,
                                     simulate_trace_rw_seq)
from repro.core.config import CacheConfig, SchedulerConfig
from repro.core.scheduler import (form_batches, form_batches_seq,
                                  form_batches_typed,
                                  form_batches_typed_seq,
                                  schedule_trace_rw, schedule_trace_rw_seq)
from repro.core.timing import (DDR4_2400, simulate_dram_access_windowed,
                               simulate_dram_access_windowed_seq)


def _assert_state_equal(a, b):
    for field in ("tags", "valid", "age", "data", "clock", "dirty"):
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=field)


# ---------------------------------------------------------------------------
# Cache engine: set-parallel vs sequential scan
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 900), min_size=1, max_size=250),
       st.sampled_from([1, 2, 8]),
       st.booleans())
def test_property_read_trace_set_parallel_identical(lids, ways, warm):
    cfg = CacheConfig(num_lines=256, associativity=ways)
    rng = np.random.default_rng(len(lids) + ways)
    table = jnp.asarray(rng.standard_normal((1024, 3)), jnp.float32)
    state = init_cache(cfg, 3)
    if warm:    # chained state, same lineage (clean reads keep coherence)
        state, _, _ = simulate_trace_seq(
            state, jnp.asarray(rng.integers(0, 1024, 64), jnp.int32), table)
    lids = jnp.asarray(lids, jnp.int32)
    f_seq, h_seq, l_seq = simulate_trace_seq(state, lids, table)
    f_par, h_par, l_par = simulate_trace(state, lids, table,
                                         engine="parallel")
    _assert_state_equal(f_seq, f_par)
    np.testing.assert_array_equal(np.asarray(h_seq), np.asarray(h_par))
    np.testing.assert_array_equal(np.asarray(l_seq), np.asarray(l_par))


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 600), st.integers(0, 1)),
                min_size=1, max_size=200),
       st.sampled_from(["write_back", "write_through"]),
       st.sampled_from([1, 4]),
       st.booleans())
def test_property_rw_trace_set_parallel_identical(reqs, policy, ways, warm):
    """Mixed read/write stream: final state, backing table (raw and
    flushed), hit flags and served lines all match the one-beat-at-a-time
    scan — including when starting from a chained dirty state."""
    cfg = CacheConfig(num_lines=256, associativity=ways,
                      write_policy=policy)
    rng = np.random.default_rng(len(reqs) * 2 + ways)
    table = jnp.asarray(rng.standard_normal((640, 2)), jnp.float32)
    state = init_cache(cfg, 2)
    if warm:    # enter with dirty lines from a prior trace (same lineage)
        n0 = 48
        state, table, _, _ = simulate_trace_rw_seq(
            state, jnp.asarray(rng.integers(0, 640, n0), jnp.int32),
            jnp.asarray(rng.integers(0, 2, n0), jnp.int32),
            jnp.asarray(rng.standard_normal((n0, 2)), jnp.float32),
            table, config=cfg)
    n = len(reqs)
    lids = jnp.asarray([r[0] for r in reqs], jnp.int32)
    rw = jnp.asarray([r[1] for r in reqs], jnp.int32)
    wlines = jnp.asarray(rng.standard_normal((n, 2)), jnp.float32)
    seq = simulate_trace_rw_seq(state, lids, rw, wlines, table, config=cfg)
    par = simulate_trace_rw(state, lids, rw, wlines, table, config=cfg,
                            engine="parallel")
    _assert_state_equal(seq[0], par[0])
    np.testing.assert_array_equal(np.asarray(seq[1]), np.asarray(par[1]))
    np.testing.assert_array_equal(np.asarray(seq[2]), np.asarray(par[2]))
    np.testing.assert_array_equal(np.asarray(seq[3]), np.asarray(par[3]))
    _, t_seq = flush(seq[0], seq[1])
    _, t_par = flush(par[0], par[1])
    np.testing.assert_array_equal(np.asarray(t_seq), np.asarray(t_par))


def test_auto_dispatch_falls_back_and_stays_identical(rng):
    """engine='auto' must be safe everywhere: tiny traces, out-of-table
    ids and dirty read-states take the sequential path transparently."""
    cfg = CacheConfig(num_lines=256, associativity=2)
    table = jnp.asarray(rng.standard_normal((64, 2)), jnp.float32)
    state = init_cache(cfg, 2)
    lids = jnp.asarray(rng.integers(0, 500, 40), jnp.int32)  # ids > rows
    rw = jnp.asarray(rng.integers(0, 2, 40), jnp.int32)
    wl = jnp.asarray(rng.standard_normal((40, 2)), jnp.float32)
    auto = simulate_trace_rw(state, lids, rw, wl, table, config=cfg)
    seq = simulate_trace_rw_seq(state, lids, rw, wl, table, config=cfg)
    _assert_state_equal(auto[0], seq[0])
    np.testing.assert_array_equal(np.asarray(auto[1]), np.asarray(seq[1]))


def test_auto_dispatch_incoherent_state_falls_back(rng):
    """A state warmed against a *different* table violates the
    clean-line coherence precondition; engine='auto' must detect it and
    serve the seed semantics (hits serve the Data RAM copy, not the
    passed table)."""
    cfg = CacheConfig(num_lines=256, associativity=2)
    table_a = jnp.asarray(rng.standard_normal((512, 2)), jnp.float32)
    table_b = jnp.asarray(rng.standard_normal((512, 2)), jnp.float32)
    state = init_cache(cfg, 2)
    warm = jnp.asarray(rng.integers(0, 512, 300), jnp.int32)
    state, _, _ = simulate_trace_seq(state, warm, table_a)
    lids = jnp.asarray(rng.integers(0, 512, 400), jnp.int32)
    f_auto, h_auto, l_auto = simulate_trace(state, lids, table_b)
    f_seq, h_seq, l_seq = simulate_trace_seq(state, lids, table_b)
    _assert_state_equal(f_auto, f_seq)
    np.testing.assert_array_equal(np.asarray(h_auto), np.asarray(h_seq))
    np.testing.assert_array_equal(np.asarray(l_auto), np.asarray(l_seq))


def test_auto_dispatch_out_of_table_dirty_line_falls_back(rng):
    """A resident dirty way caching a line beyond the (smaller) passed
    table would flush out of bounds; auto must fall back to the clipping
    sequential semantics instead of crashing or diverging."""
    cfg = CacheConfig(num_lines=256, associativity=1,
                      write_policy="write_back")
    big = jnp.asarray(rng.standard_normal((2048, 2)), jnp.float32)
    state = init_cache(cfg, 2)
    n0 = 64
    state, big, _, _ = simulate_trace_rw_seq(
        state, jnp.asarray(rng.integers(1500, 2048, n0), jnp.int32),
        jnp.ones(n0, jnp.int32),
        jnp.asarray(rng.standard_normal((n0, 2)), jnp.float32),
        big, config=cfg)
    small = jnp.asarray(rng.standard_normal((640, 2)), jnp.float32)
    n = 400
    lids = jnp.asarray(rng.integers(0, 640, n), jnp.int32)
    rw = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    wl = jnp.asarray(rng.standard_normal((n, 2)), jnp.float32)
    auto = simulate_trace_rw(state, lids, rw, wl, small, config=cfg)
    seq = simulate_trace_rw_seq(state, lids, rw, wl, small, config=cfg)
    _assert_state_equal(auto[0], seq[0])
    np.testing.assert_array_equal(np.asarray(auto[1]), np.asarray(seq[1]))


def test_schedule_trace_rw_negative_addresses_identical():
    """Negative addresses produce negative row indices; the fused-key
    sort must not be used there (batch key ranges would overlap)."""
    addrs = np.array([-8192, 8192, -16384, 0, 8192, -8192, 0, -16384])
    rw = np.zeros(8, np.int32)
    cfg = SchedulerConfig(batch_size=4, bypass_sequential=False)
    fast = schedule_trace_rw(addrs, rw, config=cfg)
    ref = schedule_trace_rw_seq(addrs, rw, config=cfg)
    np.testing.assert_array_equal(fast[0], ref[0])
    np.testing.assert_array_equal(fast[1], ref[1])


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 4000), min_size=0, max_size=400),
       st.sampled_from([(256, 1), (256, 4), (1024, 8)]))
def test_property_hit_rate_oracle_identical(lids, shape):
    num_lines, ways = shape
    cfg = CacheConfig(num_lines=num_lines, associativity=ways)
    lids = np.asarray(lids, np.int64)
    h_seq, r_seq = hit_rate_oracle_seq(cfg, lids)
    h_vec, r_vec = hit_rate_oracle(cfg, lids)
    np.testing.assert_array_equal(h_seq, h_vec)
    assert r_seq == r_vec


# ---------------------------------------------------------------------------
# Scheduler: vectorized batch planning vs request-at-a-time walk
# ---------------------------------------------------------------------------

def _assert_batches_equal(fast, ref):
    fast, ref = list(fast), list(ref)
    assert len(fast) == len(ref)
    for bf, br in zip(fast, ref):
        assert bf.rw == br.rw
        for field in ("pe_id", "addr", "size", "seq"):
            np.testing.assert_array_equal(getattr(bf, field),
                                          getattr(br, field),
                                          err_msg=field)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 1),
                          st.integers(0, 9)),
                min_size=0, max_size=300),
       st.sampled_from([4, 16, 64]),
       st.sampled_from([4, 10, 40]))
def test_property_batch_formers_identical(reqs, batch_size, timeout):
    addrs = np.array([r[0] * 4096 for r in reqs], np.int64)
    rw = np.array([r[1] for r in reqs], np.int32)
    arrival = np.cumsum([r[2] for r in reqs]).astype(np.int64) \
        if reqs else None
    cfg = SchedulerConfig(batch_size=batch_size, timeout_cycles=timeout)
    for arr in (None, arrival):
        _assert_batches_equal(
            form_batches(addrs, rw, arr, config=cfg),
            form_batches_seq(addrs, rw, arr, config=cfg))
        _assert_batches_equal(
            form_batches_typed(addrs, rw, arr, config=cfg),
            form_batches_typed_seq(addrs, rw, arr, config=cfg))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40), st.integers(0, 1)),
                min_size=0, max_size=300),
       st.sampled_from([4, 64]),
       st.booleans(), st.booleans())
def test_property_schedule_trace_rw_identical(reqs, batch_size, bypass,
                                              coalesce):
    addrs = np.array([r[0] * 8192 for r in reqs], np.int64)
    rw = np.array([r[1] for r in reqs], np.int32)
    cfg = SchedulerConfig(batch_size=batch_size,
                          bypass_sequential=bypass)
    fast = schedule_trace_rw(addrs, rw, config=cfg,
                             coalesce_writes=coalesce)
    ref = schedule_trace_rw_seq(addrs, rw, config=cfg,
                                coalesce_writes=coalesce)
    np.testing.assert_array_equal(fast[0], ref[0])
    np.testing.assert_array_equal(fast[1], ref[1])


# ---------------------------------------------------------------------------
# Commercial-IP baseline: chunked drain vs per-request greedy walk
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 500), min_size=0, max_size=600),
       st.sampled_from([1, 2, 4, 7]))
def test_property_windowed_baseline_identical(rows, window):
    addrs = np.asarray(rows, np.int64) * 8192 // 4   # mix rows and banks
    fast = simulate_dram_access_windowed(addrs, DDR4_2400, window=window)
    ref = simulate_dram_access_windowed_seq(addrs, DDR4_2400,
                                            window=window)
    assert dataclasses.asdict(fast) == dataclasses.asdict(ref)


def test_windowed_negative_addresses_identical():
    """Negative addresses yield negative row indices — legal values that
    must not collide with any 'bank closed' sentinel."""
    addrs = np.array([-8192, -8192, -8192, 8192, -16384, -8192])
    for window in (1, 2, 4):
        fast = simulate_dram_access_windowed(addrs, window=window)
        ref = simulate_dram_access_windowed_seq(addrs, window=window)
        assert dataclasses.asdict(fast) == dataclasses.asdict(ref)


def test_simulate_trace_auto_negative_ids_identical(rng):
    """Negative line ids wrap python-style through the sequential jnp
    gather; auto must keep them on the sequential path."""
    cfg = CacheConfig(num_lines=256, associativity=2)
    table = jnp.asarray(rng.standard_normal((512, 2)), jnp.float32)
    state = init_cache(cfg, 2)
    lids_np = rng.integers(0, 512, 300)
    lids_np[5] = -3
    lids = jnp.asarray(lids_np, jnp.int32)
    f_auto, h_auto, l_auto = simulate_trace(state, lids, table)
    f_seq, h_seq, l_seq = simulate_trace_seq(state, lids, table)
    _assert_state_equal(f_auto, f_seq)
    np.testing.assert_array_equal(np.asarray(h_auto), np.asarray(h_seq))
    np.testing.assert_array_equal(np.asarray(l_auto), np.asarray(l_seq))


def test_simulate_trace_auto_is_jittable(rng):
    """The seed read path ran inside jit; engine='auto' must keep that
    working (traced table ⇒ sequential scan, no host round-trip)."""
    import jax

    cfg = CacheConfig(num_lines=256, associativity=2)
    table = jnp.asarray(rng.standard_normal((512, 2)), jnp.float32)
    state = init_cache(cfg, 2)
    lids = jnp.asarray(rng.integers(0, 512, 300), jnp.int32)

    @jax.jit
    def run(tbl):
        return simulate_trace(state, lids, tbl)

    _, hits, lines = run(table)
    _, h_ref, l_ref = simulate_trace_seq(state, lids, table)
    np.testing.assert_array_equal(np.asarray(hits), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(lines), np.asarray(l_ref))


def test_windowed_interleaved_streams_identical():
    """The fig7 baseline shape: several sequential bursts round-robin
    interleaved — exercises long hit-run draining."""
    rng = np.random.default_rng(0)
    streams = [b + np.arange(400) * 64 for b in
               rng.integers(0, 1 << 22, 8)]
    addrs = np.stack(streams, axis=1).reshape(-1)
    for window in (1, 4):
        fast = simulate_dram_access_windowed(addrs, window=window)
        ref = simulate_dram_access_windowed_seq(addrs, window=window)
        assert dataclasses.asdict(fast) == dataclasses.asdict(ref)


# ---------------------------------------------------------------------------
# Edge cases around the vectorized engines' mode boundaries (ISSUE 9):
# single-request traces, all-miss streams (no reuse anywhere), and
# lengths straddling the compaction threshold / tail-staircase chunks.
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 4000), st.sampled_from([1, 2, 8]),
       st.sampled_from([256, 4096]))
def test_property_single_request_trace_identical(lid, ways, lines):
    """One-request traces exercise every engine's n==1 corner: the
    compacted oracle, the set-parallel scan, and the seq walk must all
    agree — one miss, zero hits."""
    cfg = CacheConfig(num_lines=lines, associativity=ways)
    ids = np.asarray([lid], np.int64)
    h_vec, r_vec = hit_rate_oracle(cfg, ids)
    h_seq, r_seq = hit_rate_oracle_seq(cfg, ids)
    np.testing.assert_array_equal(h_vec, h_seq)
    assert r_vec == r_seq == 0.0
    table = jnp.asarray(np.zeros((4096, 2)), jnp.float32)
    state = init_cache(cfg, 2)
    f_seq, h_seq, l_seq = simulate_trace_seq(
        state, jnp.asarray(ids, jnp.int32), table)
    f_par, h_par, l_par = simulate_trace(
        state, jnp.asarray(ids, jnp.int32), table, engine="parallel")
    _assert_state_equal(f_seq, f_par)
    np.testing.assert_array_equal(np.asarray(h_seq), np.asarray(h_par))
    assert not bool(np.asarray(h_par)[0])


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([64, 255, 256, 257, 1024, 4095, 4096, 4097]),
       st.sampled_from([(256, 4), (1024, 1)]))
def test_property_all_miss_trace_identical(n, shape):
    """Distinct line ids everywhere — zero reuse, the worst case for
    both the tail staircase (every lane survives to the finisher) and
    the compacted layout (every set is cold). Lengths straddle the
    TAIL_CHUNKS steps and the MIN_LOCKSTEP_TRACE=4096 compaction
    threshold. Hit rate must be exactly 0 and both engines identical."""
    lines, ways = shape
    cfg = CacheConfig(num_lines=lines, associativity=ways)
    ids = np.arange(n, dtype=np.int64)
    h_vec, r_vec = hit_rate_oracle(cfg, ids)
    h_seq, r_seq = hit_rate_oracle_seq(cfg, ids)
    np.testing.assert_array_equal(h_vec, h_seq)
    assert r_vec == r_seq == 0.0
    assert not h_vec.any()


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([4090, 4096, 4104, 8192]),
       st.integers(0, 3))
def test_property_compaction_threshold_boundary(n, salt):
    """Reuse-heavy traces at the MIN_LOCKSTEP_TRACE boundary: the
    compacted-lane layout kicks in exactly at n==4096, and the verdict
    must not depend on which side of the threshold the dispatch
    lands."""
    cfg = CacheConfig(num_lines=1024, associativity=4)
    rng = np.random.default_rng(n + salt * 7919)
    ids = (rng.zipf(1.3, n).astype(np.int64) - 1) % 2048
    h_vec, r_vec = hit_rate_oracle(cfg, ids)
    h_seq, r_seq = hit_rate_oracle_seq(cfg, ids)
    np.testing.assert_array_equal(h_vec, h_seq)
    assert r_vec == r_seq
