"""DMA engine planner properties + windowed-baseline simulator checks."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.config import DMAConfig
from repro.core.dma_engine import (channel_vmem_bytes,
                                   modeled_transfer_cycles, plan_transfer)
from repro.core.timing import (DDR4_2400, simulate_dram_access,
                               simulate_dram_access_windowed)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 10_000_000), st.integers(1, 8),
       st.sampled_from([256, 4096, 65536]))
def test_plan_covers_payload_exactly(total, channels, txn):
    plan = plan_transfer(total, DMAConfig(num_parallel_dma=channels,
                                          max_transaction_bytes=txn))
    assert plan.size.sum() == total
    # transactions tile the payload without gaps or overlap
    order = np.argsort(plan.offset)
    offs, sizes = plan.offset[order], plan.size[order]
    assert offs[0] == 0
    np.testing.assert_array_equal(offs[1:], (offs + sizes)[:-1])
    assert plan.size.max() <= txn
    assert set(plan.channel.tolist()) <= set(range(channels))


def test_channels_round_robin():
    plan = plan_transfer(10 * 1024, DMAConfig(num_parallel_dma=4,
                                              max_transaction_bytes=1024))
    np.testing.assert_array_equal(plan.channel,
                                  np.arange(10) % 4)


def test_more_channels_reduce_modeled_time():
    cfg1 = DMAConfig(num_parallel_dma=1, max_transaction_bytes=4096)
    cfg8 = DMAConfig(num_parallel_dma=8, max_transaction_bytes=4096)
    plan1 = plan_transfer(1 << 20, cfg1)
    plan8 = plan_transfer(1 << 20, cfg8)
    assert modeled_transfer_cycles(plan8, cfg8) < \
        modeled_transfer_cycles(plan1, cfg1)
    assert channel_vmem_bytes(cfg8) == 8 * channel_vmem_bytes(cfg1)


def test_plan_rejects_empty():
    with pytest.raises(ValueError):
        plan_transfer(0, DMAConfig())


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=1, max_size=300))
def test_windowed_sim_window1_equals_fifo(rows):
    """The MIG-like baseline at window=1 must match the pure FIFO
    simulator on every trace (same hit/conflict classification)."""
    addrs = np.asarray(rows, np.int64) * DDR4_2400.row_bytes
    fifo = simulate_dram_access(addrs)
    w1 = simulate_dram_access_windowed(addrs, window=1)
    assert (fifo.row_hits, fifo.row_conflicts, fifo.first_accesses) == \
        (w1.row_hits, w1.row_conflicts, w1.first_accesses)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 31), min_size=2, max_size=200),
       st.sampled_from([2, 4, 8]))
def test_windowed_reorder_never_hurts(rows, window):
    """Greedy open-row promotion can only reduce total cycles."""
    addrs = np.asarray(rows, np.int64) * DDR4_2400.row_bytes
    fifo = simulate_dram_access_windowed(addrs, window=1)
    win = simulate_dram_access_windowed(addrs, window=window)
    assert win.total_fpga_cycles <= fifo.total_fpga_cycles + 1e-9