"""Out-of-order DRAM command scheduling (FR-FCFS + refresh) — the
property harness that locks the new order-dependent service model down.

This is the first model in the repo where the makespan depends on the
service *order*, not just the stream contents, so every property here is
stated against the request-at-a-time oracle
(:func:`repro.core.timing.simulate_dram_sched_seq`) or against the
pre-PR simulators the scheduler must degenerate to:

* vectorized path == oracle, bit for bit, over policy x window x cap x
  refresh x rw x timings;
* window=1 (and policy=fifo at any window) == the per-bank FIFO
  classification of ``simulate_dram_access`` — today's model;
* frfcfs without cap/refresh on read-only traces == the pre-PR windowed
  baseline ``simulate_dram_access_windowed(_seq)`` (same greedy
  oldest-ready-first walk);
* FR-FCFS never loses to FIFO on read-only traces (row-hit superset),
  and never pays more open-row class cycles on mixed rw traces (the
  *turnaround* term can go either way — reordering can split a
  same-direction run, which is why the dominance property is stated on
  the class cycles, see docs/ARCHITECTURE.md §8);
* the starvation cap bounds per-request slip: no request is passed by
  more than ``starvation_cap`` younger requests.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import channels as channels_mod
from repro.core.config import (ChannelConfig, DRAMSchedConfig,
                               MemoryControllerConfig, SchedulerConfig,
                               CacheConfig)
from repro.core.controller import MemoryController
from repro.core.timing import (DDR4_2400, HBM_V5E, simulate_dram_access,
                               simulate_dram_access_windowed,
                               simulate_dram_access_windowed_seq,
                               simulate_dram_sched,
                               simulate_dram_sched_seq)

ROW = DDR4_2400.row_bytes


def _trace(reqs, row_scale=ROW // 2):
    addrs = np.asarray([r[0] for r in reqs], np.int64) * row_scale
    rw = np.asarray([r[1] for r in reqs], np.int32)
    return addrs, rw


def _assert_sched_equal(a, b):
    assert a.total_fpga_cycles == b.total_fpga_cycles
    assert a.row_hits == b.row_hits
    assert a.row_conflicts == b.row_conflicts
    assert a.first_accesses == b.first_accesses
    assert a.n_refreshes == b.n_refreshes
    assert a.refresh_dram_cycles == b.refresh_dram_cycles
    assert a.turnaround_dram_cycles == b.turnaround_dram_cycles
    np.testing.assert_array_equal(a.service_order, b.service_order)


def _slips(service_order: np.ndarray) -> np.ndarray:
    """slip[i] = number of younger requests issued before request i."""
    order = np.asarray(service_order, np.int64)
    n = order.shape[0]
    pos = np.empty(n, np.int64)
    pos[order] = np.arange(n)
    # O(n^2) reference (test-sized traces): count j > i with pos[j] < pos[i]
    younger = np.arange(n)[None, :] > np.arange(n)[:, None]
    earlier = pos[None, :] < pos[:, None]
    return (younger & earlier).sum(axis=1)


def _class_dram_cycles(res, timings) -> int:
    """Open-row class cycles only — no burst/turnaround/refresh terms."""
    return (res.first_accesses * (timings.t_rcd + timings.t_cl)
            + res.row_hits * timings.t_cl
            + res.row_conflicts * (timings.t_rp + timings.t_rcd
                                   + timings.t_cl))


# ---------------------------------------------------------------------------
# Vectorized path == request-at-a-time oracle (the co-headline identity)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 60), st.integers(0, 1)),
                min_size=0, max_size=220),
       st.sampled_from(["fifo", "frfcfs", "frfcfs_cap"]),
       st.sampled_from([1, 2, 3, 4, 8, 16, 64]),
       st.sampled_from([1, 2, 3, 8, 100]),
       st.sampled_from([(0, 0), (0, 37), (5, 37), (30, 100), (30, 500)]),
       st.booleans(),
       st.booleans())
def test_property_fast_path_matches_oracle(reqs, policy, window, cap,
                                           refresh, use_rw, hbm):
    t_rfc, t_refi = refresh
    timings = HBM_V5E if hbm else DDR4_2400
    addrs, rw = _trace(reqs, row_scale=timings.row_bytes // 2)
    sched = DRAMSchedConfig(policy=policy, reorder_window=window,
                            starvation_cap=cap, t_rfc=t_rfc,
                            t_refi=t_refi)
    a = simulate_dram_sched_seq(addrs, timings, sched,
                                rw if use_rw else None)
    b = simulate_dram_sched(addrs, timings, sched,
                            rw if use_rw else None)
    _assert_sched_equal(a, b)
    # the order is a true permutation of the trace
    assert np.array_equal(np.sort(a.service_order), np.arange(len(reqs)))


# ---------------------------------------------------------------------------
# Degeneracies: window=1 / fifo == today's FIFO classification
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 80), st.integers(0, 1)),
                min_size=0, max_size=250),
       st.sampled_from(["fifo", "frfcfs", "frfcfs_cap"]),
       st.booleans())
def test_property_window1_is_fifo_classification(reqs, policy, hbm):
    """Any policy at window=1 (and fifo at any window) is bit-identical
    to the pre-PR ``simulate_dram_access`` per-bank FIFO model,
    turnarounds included."""
    timings = HBM_V5E if hbm else DDR4_2400
    addrs, rw = _trace(reqs, row_scale=timings.row_bytes // 2)
    legacy = simulate_dram_access(addrs, timings, rw=rw)
    for sched in (DRAMSchedConfig(policy=policy, reorder_window=1),
                  DRAMSchedConfig(policy="fifo", reorder_window=64)):
        for engine in ("auto", "sequential"):
            got = simulate_dram_sched(addrs, timings, sched, rw,
                                      engine=engine)
            assert got.total_fpga_cycles == legacy.total_fpga_cycles
            assert (got.row_hits, got.row_conflicts,
                    got.first_accesses) == (legacy.row_hits,
                                            legacy.row_conflicts,
                                            legacy.first_accesses)
            np.testing.assert_array_equal(got.service_order,
                                          np.arange(len(reqs)))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 70), min_size=0, max_size=250),
       st.sampled_from([1, 2, 4, 8, 16]))
def test_property_frfcfs_matches_windowed_baseline(rows, window):
    """Pure FR-FCFS (no cap, no refresh) on a read-only trace runs the
    same greedy oldest-ready-first walk as the pre-PR commercial-IP
    baseline ``simulate_dram_access_windowed`` — counts and total must
    be bit-identical (the windowed baseline does not expose order)."""
    addrs = np.asarray(rows, np.int64) * (ROW // 2)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=window)
    new = simulate_dram_sched(addrs, DDR4_2400, sched)
    for old in (simulate_dram_access_windowed(addrs, DDR4_2400,
                                              window=window),
                simulate_dram_access_windowed_seq(addrs, DDR4_2400,
                                                  window=window)):
        assert new.total_fpga_cycles == old.total_fpga_cycles
        assert (new.row_hits, new.row_conflicts, new.first_accesses) == \
            (old.row_hits, old.row_conflicts, old.first_accesses)


# ---------------------------------------------------------------------------
# Dominance: FR-FCFS never loses to FIFO
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=0, max_size=250),
       st.sampled_from(["frfcfs", "frfcfs_cap"]),
       st.sampled_from([2, 4, 8, 32, 128]),
       st.sampled_from([1, 4, 16]))
def test_property_frfcfs_makespan_le_fifo_read_only(rows, policy, window,
                                                    cap):
    """On read-only traces (no refresh) the reorder can only *convert*
    conflicts into row hits — FIFO's hits are a subset of FR-FCFS's
    (misses are issued oldest-first in both, so every same-bank
    adjacent same-row pair survives) — hence makespan <= FIFO."""
    addrs = np.asarray(rows, np.int64) * (ROW // 2)
    fr = simulate_dram_sched(addrs, DDR4_2400,
                             DRAMSchedConfig(policy=policy,
                                             reorder_window=window,
                                             starvation_cap=cap))
    fifo = simulate_dram_sched(addrs, DDR4_2400, DRAMSchedConfig())
    assert fr.total_fpga_cycles <= fifo.total_fpga_cycles
    assert fr.row_hits >= fifo.row_hits


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 1)),
                min_size=0, max_size=250),
       st.sampled_from([2, 8, 32]))
def test_property_frfcfs_class_cycles_le_fifo_mixed_rw(reqs, window):
    """On mixed read/write traces the *open-row class* cycles still
    dominate FIFO's; the bus-turnaround term alone can regress (hit
    promotion may split a same-direction run — ARCHITECTURE §8), which
    is why the guarantee is stated on the class cycles."""
    addrs, rw = _trace(reqs)
    fr = simulate_dram_sched(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy="frfcfs", reorder_window=window), rw)
    fifo = simulate_dram_sched(addrs, DDR4_2400, DRAMSchedConfig(), rw)
    assert _class_dram_cycles(fr, DDR4_2400) <= \
        _class_dram_cycles(fifo, DDR4_2400)


def test_turnaround_can_regress_under_reorder():
    """The documented counterexample (ARCHITECTURE §8): promoting a
    read hit between two writes adds a W->R->W double turnaround that
    FIFO's W,W,R order does not pay. Pinning it keeps the class-cycles
    statement of the dominance property honest."""
    t = DDR4_2400
    # open bank 0 row 0 with a write, then [W miss(bank1), W miss(bank1),
    # R hit(bank0)]: FIFO issues W,W,W,R (one tWTR); window 2 promotes
    # the read between the two bank-1 writes — issued W,W,R,W pays
    # tWTR + tRTW
    addrs = np.asarray([0, 1 * ROW, 17 * ROW, 0], np.int64)
    rw = np.asarray([1, 1, 1, 0], np.int32)
    fr = simulate_dram_sched(
        addrs, t, DRAMSchedConfig(policy="frfcfs", reorder_window=2), rw)
    fifo = simulate_dram_sched(addrs, t, DRAMSchedConfig(), rw)
    assert fr.turnaround_dram_cycles > fifo.turnaround_dram_cycles
    # ... yet the class cycles never regress
    assert _class_dram_cycles(fr, t) <= _class_dram_cycles(fifo, t)


# ---------------------------------------------------------------------------
# Starvation cap bounds per-request slip
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40), st.integers(0, 1)),
                min_size=0, max_size=200),
       st.sampled_from([1, 2, 3, 8]),
       st.sampled_from([4, 16, 64]),
       st.booleans())
def test_property_starvation_cap_bounds_slip(reqs, cap, window,
                                             with_refresh):
    """With policy=frfcfs_cap no request is ever passed by more than
    ``starvation_cap`` younger requests, for any window and with
    refresh on or off; plain frfcfs has no such bound (witnessed
    below)."""
    addrs, rw = _trace(reqs)
    sched = DRAMSchedConfig(policy="frfcfs_cap", reorder_window=window,
                            starvation_cap=cap,
                            t_rfc=30 if with_refresh else 0,
                            t_refi=100 if with_refresh else 0)
    res = simulate_dram_sched(addrs, DDR4_2400, sched, rw)
    if len(reqs):
        assert int(_slips(res.service_order).max()) <= cap


def test_uncapped_frfcfs_can_starve_but_cap_binds():
    """A hot-row stream behind one cold miss: plain FR-FCFS slips the
    cold request past every hit; the cap cuts that slip to the
    configured bound."""
    # request 0: bank 1 (cold miss); requests 1..40: bank 0, same row —
    # all hits once open — window covers the whole stream
    addrs = np.asarray([17 * ROW] + [0] * 40, np.int64)
    # open bank 0's row first so the hot run hits from the start
    addrs = np.concatenate([[0], addrs])
    free = simulate_dram_sched(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy="frfcfs", reorder_window=64))
    slip_free = int(_slips(free.service_order)[1])
    assert slip_free == 40          # passed by the entire hot run
    capped = simulate_dram_sched(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy="frfcfs_cap", reorder_window=64,
                        starvation_cap=5))
    assert int(_slips(capped.service_order).max()) <= 5


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 60), st.integers(0, 1)),
                min_size=0, max_size=200),
       st.sampled_from([2, 8, 32]))
def test_property_huge_cap_equals_uncapped(reqs, window):
    addrs, rw = _trace(reqs)
    capped = simulate_dram_sched(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy="frfcfs_cap", reorder_window=window,
                        starvation_cap=1 << 20), rw)
    free = simulate_dram_sched(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy="frfcfs", reorder_window=window), rw)
    _assert_sched_equal(capped, free)


# ---------------------------------------------------------------------------
# Refresh accounting
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 60), st.integers(0, 1)),
                min_size=1, max_size=200),
       st.sampled_from(["fifo", "frfcfs"]),
       st.sampled_from([(5, 37), (30, 100), (100, 500)]))
def test_property_refresh_charged_and_never_helps(reqs, policy, refresh):
    t_rfc, t_refi = refresh
    addrs, rw = _trace(reqs)
    base = simulate_dram_sched(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy=policy, reorder_window=8), rw)
    ref = simulate_dram_sched(
        addrs, DDR4_2400,
        DRAMSchedConfig(policy=policy, reorder_window=8,
                        t_rfc=t_rfc, t_refi=t_refi), rw)
    assert ref.refresh_dram_cycles == ref.n_refreshes * t_rfc
    # a refresh closes every row: it can only stall and lose hits
    assert ref.total_fpga_cycles >= base.total_fpga_cycles
    assert ref.row_hits <= base.row_hits


def test_refresh_closes_rows_hand_case():
    """Two same-row accesses with a refresh boundary between them: the
    second re-activates (charged like a first access) instead of
    hitting."""
    t = DDR4_2400
    addrs = np.asarray([0, 0], np.int64)
    no_ref = simulate_dram_sched(addrs, t, DRAMSchedConfig())
    assert (no_ref.first_accesses, no_ref.row_hits) == (1, 1)
    # first access costs t_rcd+t_cl+t_burst = 38 > t_refi=10: refresh
    # fires before the second issue and precharges bank 0
    ref = simulate_dram_sched(
        addrs, t, DRAMSchedConfig(t_rfc=7, t_refi=10))
    assert ref.n_refreshes >= 1
    assert (ref.first_accesses, ref.row_hits) == (2, 0)


# ---------------------------------------------------------------------------
# Config validation + footprint
# ---------------------------------------------------------------------------

def test_dram_sched_config_validation():
    with pytest.raises(ValueError, match="policy"):
        DRAMSchedConfig(policy="open_page")
    with pytest.raises(ValueError, match="reorder_window"):
        DRAMSchedConfig(reorder_window=0)
    with pytest.raises(ValueError, match="reorder_window"):
        DRAMSchedConfig(reorder_window=1024)
    with pytest.raises(ValueError, match="starvation_cap"):
        DRAMSchedConfig(starvation_cap=0)
    with pytest.raises(ValueError, match="t_rfc"):
        DRAMSchedConfig(t_rfc=-1)
    with pytest.raises(ValueError, match="refresh longer"):
        DRAMSchedConfig(t_rfc=200, t_refi=100)
    with pytest.raises(ValueError, match="refresh longer"):
        # t_rfc == t_refi would refresh forever between two issues
        DRAMSchedConfig(t_rfc=100, t_refi=100)
    assert DRAMSchedConfig(policy="fifo", reorder_window=64) \
        .effective_window == 1
    assert DRAMSchedConfig(policy="frfcfs", reorder_window=64) \
        .effective_window == 64


def test_reorder_window_costs_vmem():
    small = MemoryControllerConfig()
    big = dataclasses.replace(
        small, dram_sched=DRAMSchedConfig(policy="frfcfs",
                                          reorder_window=256))
    assert big.vmem_footprint_bytes() > small.vmem_footprint_bytes()


# ---------------------------------------------------------------------------
# Pipeline / channels integration
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 500),
                          st.integers(0, 1)),
                min_size=0, max_size=200),
       st.sampled_from([1, 4]),
       st.sampled_from(["frfcfs", "frfcfs_cap"]),
       st.booleans())
def test_property_pipeline_matches_seq_composition(reqs, num_channels,
                                                   policy, sched_on):
    """The DRAMServiceStage under a non-trivial DRAMSchedConfig is
    bit-identical to the request-at-a-time composition (per-channel
    arbiter + scheduler oracles + simulate_dram_sched_seq)."""
    rows = np.asarray([r[1] for r in reqs], np.int64)
    pe = np.asarray([r[0] for r in reqs], np.int64)
    rw = np.asarray([r[2] for r in reqs], np.int32)
    dsched = DRAMSchedConfig(policy=policy, reorder_window=8,
                             starvation_cap=4, t_rfc=30, t_refi=300)
    ccfg = ChannelConfig(num_channels=num_channels)
    scfg = SchedulerConfig(batch_size=16) if sched_on else None
    new = channels_mod.simulate_multiport_channels(
        pe, rows * 4096, rw, num_ports=4, channel_cfg=ccfg,
        sched_config=scfg, dram_sched=dsched)
    old = channels_mod.simulate_multiport_channels(
        pe, rows * 4096, rw, num_ports=4, channel_cfg=ccfg,
        sched_config=scfg, dram_sched=dsched, use_seq_oracle=True)
    assert new.makespan_fpga_cycles == old.makespan_fpga_cycles
    assert new.busy_fpga_cycles == old.busy_fpga_cycles
    assert new.row_hits == old.row_hits
    assert new.row_conflicts == old.row_conflicts
    assert new.first_accesses == old.first_accesses


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 400), min_size=0, max_size=200),
       st.sampled_from([1, 2, 4]))
def test_property_simulate_channels_sched_fast_vs_seq(rows, num_channels):
    addrs = np.asarray(rows, np.int64) * 4096
    dsched = DRAMSchedConfig(policy="frfcfs", reorder_window=8)
    ccfg = ChannelConfig(num_channels=num_channels)
    a = channels_mod.simulate_channels(addrs, DDR4_2400, ccfg,
                                       dram_sched=dsched)
    b = channels_mod.simulate_channels_seq(addrs, DDR4_2400, ccfg,
                                           dram_sched=dsched)
    assert a.makespan_fpga_cycles == b.makespan_fpga_cycles
    assert a.row_hits == b.row_hits
    assert a.row_conflicts == b.row_conflicts
    assert a.first_accesses == b.first_accesses


def test_simulate_respects_dram_sched_config(rng):
    """End to end through MemoryController.simulate: FR-FCFS with a
    deep window strictly beats FIFO service on a row-reuse-heavy
    irregular trace (engines off isolates the DRAM scheduler), and
    window=1 reproduces the FIFO numbers bit for bit."""
    rows = (rng.zipf(1.2, 20000) - 1) % 4096
    rw = (rng.random(20000) < 0.1).astype(np.int32)
    base = MemoryControllerConfig(
        scheduler=SchedulerConfig(enabled=False),
        cache=CacheConfig(enabled=False))
    fifo = MemoryController(base).simulate(None, rows, rw, 4096)
    w1 = MemoryController(dataclasses.replace(
        base, dram_sched=DRAMSchedConfig(policy="frfcfs",
                                         reorder_window=1))
    ).simulate(None, rows, rw, 4096)
    assert w1.makespan_fpga_cycles == fifo.makespan_fpga_cycles
    deep = MemoryController(dataclasses.replace(
        base, dram_sched=DRAMSchedConfig(policy="frfcfs",
                                         reorder_window=16))
    ).simulate(None, rows, rw, 4096)
    assert deep.makespan_fpga_cycles < fifo.makespan_fpga_cycles
    stage = deep.stage("dram_service")
    assert stage.info["sched_policy"] == "frfcfs"
    assert stage.info["reorder_window"] == 16


# ---------------------------------------------------------------------------
# Edge cases the fixed-point fast paths must pin (ISSUE 9): window
# covering the whole trace, single-request traces, all-miss streams,
# and the miss-heavy micro-step-budget boundary.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40), st.integers(0, 1)),
                min_size=1, max_size=96),
       st.sampled_from(["frfcfs", "frfcfs_cap"]),
       st.sampled_from([(0, 0), (5, 37)]),
       st.booleans())
def test_property_window_equals_trace_length(reqs, policy, refresh,
                                             use_rw):
    """reorder_window == len(trace): the whole stream is in flight at
    once — the deepest reordering the config admits for this trace."""
    t_rfc, t_refi = refresh
    addrs, rw = _trace(reqs)
    sched = DRAMSchedConfig(policy=policy, reorder_window=len(reqs),
                            starvation_cap=3, t_rfc=t_rfc, t_refi=t_refi)
    a = simulate_dram_sched(addrs, DDR4_2400, sched,
                            rw=rw if use_rw else None)
    b = simulate_dram_sched_seq(addrs, DDR4_2400, sched,
                                rw=rw if use_rw else None)
    _assert_sched_equal(a, b)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 1),
       st.sampled_from(["fifo", "frfcfs", "frfcfs_cap"]),
       st.sampled_from([1, 4, 64, 512]),
       st.sampled_from([(0, 0), (5, 37)]),
       st.booleans())
def test_property_single_request(row, is_write, policy, window, refresh,
                                 hbm):
    """A one-request trace costs exactly one first access + one burst
    under every policy/window/refresh combination, and the fast path
    agrees with the oracle bit for bit."""
    t_rfc, t_refi = refresh
    timings = HBM_V5E if hbm else DDR4_2400
    addrs = np.asarray([row], np.int64) * (timings.row_bytes // 2)
    rw = np.asarray([is_write], np.int32)
    sched = DRAMSchedConfig(policy=policy, reorder_window=window,
                            starvation_cap=2, t_rfc=t_rfc, t_refi=t_refi)
    a = simulate_dram_sched(addrs, timings, sched, rw=rw)
    b = simulate_dram_sched_seq(addrs, timings, sched, rw=rw)
    _assert_sched_equal(a, b)
    assert a.first_accesses == 1
    assert a.row_hits == 0 and a.row_conflicts == 0
    assert a.turnaround_dram_cycles == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 180),
       st.sampled_from(["frfcfs", "frfcfs_cap"]),
       st.sampled_from([2, 8, 64]),
       st.booleans())
def test_property_all_miss_single_bank(n, policy, window, use_rw):
    """Strictly increasing rows in one bank: nothing to reorder, every
    access after the first conflicts, and no window/cap setting may
    change that — reordering can only exploit row hits, and there are
    none."""
    timings = DDR4_2400
    # stride num_banks rows -> same bank, all distinct rows
    addrs = (np.arange(n, dtype=np.int64) * timings.num_banks
             * timings.row_bytes)
    rw = (np.arange(n, dtype=np.int32) % 3 == 0).astype(np.int32)
    sched = DRAMSchedConfig(policy=policy, reorder_window=window,
                            starvation_cap=2)
    a = simulate_dram_sched(addrs, timings, sched,
                            rw=rw if use_rw else None)
    b = simulate_dram_sched_seq(addrs, timings, sched,
                                rw=rw if use_rw else None)
    _assert_sched_equal(a, b)
    assert a.first_accesses == 1
    assert a.row_hits == 0
    assert a.row_conflicts == n - 1


@settings(max_examples=15, deadline=None)
@given(st.integers(80, 220),
       st.sampled_from([2, 3, 8]),
       st.sampled_from([1, 2, 5]),
       st.booleans())
def test_property_micro_step_budget_boundary(n, window, cap, use_rw):
    """Miss-heavy capped traces around the fast path's python-step
    budget (MICRO=96 scalar steps per drain): the mode switch between
    the scalar drain and the bucketed scan must be invisible in the
    results. All-conflict single-bank streams maximize scalar steps, so
    drawing n across [80, 220] brackets the boundary from both sides."""
    timings = DDR4_2400
    rng = np.random.default_rng(n * 7 + window)
    # same-bank all-distinct rows with a few duplicates sprinkled in so
    # the window occasionally finds a hit right at the budget edge
    rows = np.arange(n, dtype=np.int64)
    dup = rng.integers(0, n, max(1, n // 16))
    rows[dup] = rows[(dup + 1) % n]
    addrs = rows * timings.num_banks * timings.row_bytes
    rw = rng.integers(0, 2, n).astype(np.int32)
    sched = DRAMSchedConfig(policy="frfcfs_cap", reorder_window=window,
                            starvation_cap=cap)
    a = simulate_dram_sched(addrs, timings, sched,
                            rw=rw if use_rw else None)
    b = simulate_dram_sched_seq(addrs, timings, sched,
                                rw=rw if use_rw else None)
    _assert_sched_equal(a, b)
