"""Scheduler control plane: batch formation rules + weak-consistency
properties (paper §IV 'Memory Consistency Model')."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.config import SchedulerConfig
from repro.core.scheduler import (READ, WRITE, form_batches,
                                  form_batches_typed, reorder_batch,
                                  schedule_trace, schedule_trace_rw,
                                  sort_requests)
from repro.core.timing import DDR4_2400


def _batches(addrs, rw, cfg, arrival=None):
    return list(form_batches(addrs, rw, arrival, config=cfg))


def test_batch_closes_when_full():
    cfg = SchedulerConfig(batch_size=8)
    b = _batches(np.arange(20), np.zeros(20, int), cfg)
    assert [len(x) for x in b] == [8, 8, 4]


def test_batch_closes_on_type_flip():
    cfg = SchedulerConfig(batch_size=64)
    rw = [READ] * 5 + [WRITE] * 3 + [READ] * 2
    b = _batches(np.arange(10), rw, cfg)
    assert [x.rw for x in b] == [READ, WRITE, READ]
    assert [len(x) for x in b] == [5, 3, 2]


def test_batch_closes_on_timeout():
    cfg = SchedulerConfig(batch_size=64, timeout_cycles=10)
    arrival = [0, 1, 2, 50, 51, 52]      # gap > timeout after 3rd
    b = _batches(np.arange(6), np.zeros(6, int), cfg, arrival)
    assert [len(x) for x in b] == [3, 3]


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 1)),
                min_size=1, max_size=200),
       st.sampled_from([4, 16, 64]))
def test_property_weak_consistency(reqs, batch_size):
    """For every batch: single type, output is a permutation, and requests
    to the same address keep arrival order. Across batches: arrival order
    of batches preserved (FIFO service)."""
    addrs = np.array([r[0] * 8192 for r in reqs])
    rw = np.array([r[1] for r in reqs])
    cfg = SchedulerConfig(batch_size=batch_size, bypass_sequential=False)
    start = 0
    for batch in form_batches(addrs, rw, config=cfg):
        n = len(batch)
        assert (rw[start:start + n] == batch.rw).all()       # purity
        ordered = reorder_batch(batch, DDR4_2400)
        assert sorted(ordered.addr.tolist()) == \
            sorted(addrs[start:start + n].tolist())          # permutation
        for a in set(ordered.addr.tolist()):
            seqs = ordered.seq[ordered.addr == a]
            assert (np.diff(seqs) > 0).all()                 # same-addr order
        start += n
    assert start == len(reqs)


def test_typed_batches_survive_interleaved_rw():
    """Dual queues: an alternating R/W stream still forms full batches of
    each type (the single-queue former degenerates to size-1 batches)."""
    cfg = SchedulerConfig(batch_size=8)
    n = 32
    rw = [READ, WRITE] * (n // 2)
    single = _batches(np.arange(n), rw, cfg)
    assert max(len(b) for b in single) == 1
    typed = list(form_batches_typed(np.arange(n), rw, config=cfg))
    assert [len(b) for b in typed] == [8, 8, 8, 8]
    rw_arr = np.asarray(rw)
    assert all((rw_arr[b.seq] == b.rw).all() for b in typed)  # purity


def test_typed_batches_preserve_same_type_order():
    """Within a type, arrival order of requests is preserved (stable
    queues) — the weak-consistency guarantee for writes."""
    cfg = SchedulerConfig(batch_size=64)
    addrs = [3, 10, 3, 7, 3]
    rw = [WRITE, READ, WRITE, READ, WRITE]
    typed = list(form_batches_typed(addrs, rw, config=cfg))
    wbatch = [b for b in typed if b.rw == WRITE][0]
    np.testing.assert_array_equal(wbatch.addr, [3, 3, 3])
    assert (np.diff(wbatch.seq) > 0).all()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 12),
                          st.integers(0, 1)),
                min_size=0, max_size=250),
       st.sampled_from([4, 16, 64]))
def test_property_per_pe_order_survives_batching_and_sorting(reqs,
                                                             batch_size):
    """The invariant the multi-port arbiter depends on: each PE's stream
    enters the controller in arrival order, and neither batching nor the
    row sort may break it. Precisely: (a) within a request type, the
    dual-queue former emits every PE's requests in arrival order across
    the concatenated batch sequence (stable FIFO queues); (b) after the
    bitonic row sort, same-(pe, addr) same-type requests still keep
    arrival order (stable sort) — the per-port weak-consistency rule."""
    pe = np.array([r[0] for r in reqs], np.int32)
    addrs = np.array([r[1] * 8192 for r in reqs], np.int64)
    rw = np.array([r[2] for r in reqs], np.int32)
    cfg = SchedulerConfig(batch_size=batch_size, bypass_sequential=False)
    batches = list(form_batches_typed(addrs, rw, pe_id=pe, config=cfg))
    for t in (READ, WRITE):
        formed = [b for b in batches if b.rw == t]
        # (a) batch formation: per-PE arrival order across batches
        if formed:
            pe_cat = np.concatenate([b.pe_id for b in formed])
            seq_cat = np.concatenate([b.seq for b in formed])
            for p in np.unique(pe_cat):
                assert (np.diff(seq_cat[pe_cat == p]) > 0).all()
        # (b) row sort: per-(PE, addr) arrival order inside each batch
        sorted_batches = [reorder_batch(b, DDR4_2400) for b in formed]
        if sorted_batches:
            pe_s = np.concatenate([b.pe_id for b in sorted_batches])
            ad_s = np.concatenate([b.addr for b in sorted_batches])
            sq_s = np.concatenate([b.seq for b in sorted_batches])
            for key in set(zip(pe_s.tolist(), ad_s.tolist())):
                m = (pe_s == key[0]) & (ad_s == key[1])
                assert (np.diff(sq_s[m]) > 0).all()


def test_typed_batches_close_on_timeout():
    cfg = SchedulerConfig(batch_size=64, timeout_cycles=10)
    arrival = [0, 1, 2, 50, 51, 52]
    typed = list(form_batches_typed(np.arange(6), np.zeros(6, int),
                                    arrival, config=cfg))
    assert [len(b) for b in typed] == [3, 3]


def test_schedule_trace_rw_is_permutation_with_single_type_runs():
    rng = np.random.default_rng(3)
    addrs = rng.integers(0, 64, 512) * 8192
    rw = rng.integers(0, 2, 512)
    cfg = SchedulerConfig(batch_size=64, bypass_sequential=False)
    served, served_rw = schedule_trace_rw(addrs, rw, config=cfg)
    assert sorted(served.tolist()) == sorted(addrs.tolist())
    assert (np.sort(served_rw) == np.sort(rw)).all()
    # single-type batches ⇒ far fewer bus-direction flips than arrival
    flips_in = int((rw[1:] != rw[:-1]).sum())
    flips_out = int((served_rw[1:] != served_rw[:-1]).sum())
    assert flips_out < flips_in / 4


def test_schedule_trace_rw_disabled_passthrough():
    addrs = np.arange(16) * 64
    rw = np.array([READ, WRITE] * 8)
    served, served_rw = schedule_trace_rw(
        addrs, rw, config=SchedulerConfig(enabled=False))
    np.testing.assert_array_equal(served, addrs)
    np.testing.assert_array_equal(served_rw, rw)


def test_reorder_improves_row_hits(rng):
    rows = rng.integers(0, 64, 4096)
    from repro.core.timing import simulate_dram_access
    base = simulate_dram_access(rows * 8192)
    served = schedule_trace(rows * 8192, np.zeros(4096, int),
                            config=SchedulerConfig(batch_size=128))
    opt = simulate_dram_access(served)
    assert opt.hit_rate > base.hit_rate
    assert opt.total_fpga_cycles < base.total_fpga_cycles


def test_bypass_leaves_sequential_untouched():
    addrs = np.arange(256) * 64
    served = schedule_trace(addrs, np.zeros(256, int),
                            config=SchedulerConfig(batch_size=64))
    np.testing.assert_array_equal(served, addrs)


def test_sort_requests_roundtrip(rng):
    import jax.numpy as jnp
    keys = jnp.asarray(rng.integers(0, 50, 100), jnp.int32)
    skeys, perm, inv = sort_requests(keys)
    assert (np.diff(np.asarray(skeys)) >= 0).all()
    np.testing.assert_array_equal(np.asarray(skeys)[np.asarray(inv)],
                                  np.asarray(keys))
