"""Per-request lifecycle tracing (repro.core.telemetry) — the event
stream, attribution and export contracts of ARCHITECTURE §11.

Four properties lock the subsystem down:

1. **Reconstruction fidelity** — the fast paths' replayed event
   streams are *event-for-event equal* (same tuples, same order) to
   what the seq oracles emit natively, across the serving ×
   dram_sched × faults grid. The oracle stream IS the spec; the fast
   path must not invent or lose a single event.
2. **Tracing is free when off and invisible when on** — ``trace=None``
   changes nothing (it's the default everywhere), and passing a
   recorder must leave every modeled number bit-identical to the
   untraced run (golden-pinned cases included).
3. **The attribution identity** — the nine per-request components sum
   *exactly* (left-to-right, bit-for-bit) to the run's sojourns.
4. **The export contract** — the Chrome-trace JSON validates against
   the structural schema the CI trace-smoke step enforces.
"""

import dataclasses
import json

import numpy as np
import pytest

from golden_cases import CASES, ROW_BYTES, SERVING_CASES
from repro.core import timing
from repro.core.config import (DRAMSchedConfig, FaultConfig,
                               MemoryControllerConfig)
from repro.core.controller import MemoryController
from repro.core.telemetry import (COMPONENTS, ChannelTrace,
                                  CycleAttribution, TraceRecorder)
from repro.launch import tracing


def _norm(events):
    """Plain-python view of an event list (numpy scalars stripped) so
    equality failures render readably."""
    return [tuple(float(x) if isinstance(x, (float, np.floating))
                  else int(x) if isinstance(x, (int, np.integer))
                  else x for x in e) for e in events]


def _trace_pair(fn, *args, **kwargs):
    """Run ``fn`` with engine sequential vs fast, each under a fresh
    ChannelTrace; assert results agree and return both event lists."""
    seq_t, fast_t = ChannelTrace(), ChannelTrace()
    seq = fn(*args, engine="sequential", trace=seq_t, **kwargs)
    fast = fn(*args, engine="fast", trace=fast_t, **kwargs)
    assert seq.total_fpga_cycles == fast.total_fpga_cycles
    return _norm(seq_t.events), _norm(fast_t.events)


def _addrs(rng, n, n_rows=256):
    rows = np.minimum((1.0 / np.clip(rng.random(n), 1e-9, 1.0)) ** 0.8,
                      n_rows - 1).astype(np.int64)
    return rows * timing.DDR4_2400.row_bytes


# ---------------------------------------------------------------------------
# 1. reconstruction fidelity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,window,cap,t_rfc,t_refi", [
    ("fifo", 1, 16, 0, 0),
    ("fifo", 1, 16, 420, 9363),         # refresh on the FIFO walk
    ("frfcfs", 16, 16, 0, 0),
    ("frfcfs", 16, 16, 420, 9363),
    ("frfcfs_cap", 32, 8, 420, 9363),
])
def test_sched_events_fast_equals_oracle(policy, window, cap, t_rfc,
                                         t_refi):
    rng = np.random.default_rng(17)
    addrs = _addrs(rng, 1500)
    rw = (rng.random(1500) < 0.3).astype(np.int32)
    sched = DRAMSchedConfig(policy=policy, reorder_window=window,
                            starvation_cap=cap, t_rfc=t_rfc,
                            t_refi=t_refi)
    seq_ev, fast_ev = _trace_pair(timing.simulate_dram_sched, addrs,
                                  timing.DDR4_2400, sched, rw)
    assert seq_ev == fast_ev
    assert any(e[0] == "issue" for e in seq_ev)
    if t_refi:
        assert any(e[0] == "refresh" for e in seq_ev)


@pytest.mark.parametrize("num_ports,arb,weights,rate", [
    (None, "round_robin", None, 0.05),
    (1, "round_robin", None, 0.02),
    (3, "round_robin", None, 0.05),
    (3, "weighted", (4, 1, 1), 0.05),
    (3, "priority", None, 0.08),
])
def test_arrival_events_fast_equals_oracle(num_ports, arb, weights,
                                           rate):
    rng = np.random.default_rng(23)
    n = 1200
    addrs = _addrs(rng, n)
    rw = (rng.random(n) < 0.2).astype(np.int32)
    arr = np.cumsum(rng.exponential(1.0 / rate, n))
    pe = None if num_ports is None \
        else rng.integers(0, num_ports, n)
    sched = DRAMSchedConfig(policy="frfcfs", reorder_window=16,
                            t_rfc=420, t_refi=9363)
    seq_ev, fast_ev = _trace_pair(
        timing.simulate_arrivals, addrs, timing.DDR4_2400, sched, rw,
        arrival_fpga=arr, pe_id=pe, num_ports=num_ports,
        arb_policy=arb, weights=weights)
    assert seq_ev == fast_ev
    kinds = {e[0] for e in seq_ev}
    assert {"grant", "issue", "complete"} <= kinds


@pytest.mark.parametrize("fc", [
    FaultConfig(seed=11, transient_ber=0.004, weak_row_fraction=0.02,
                weak_row_ber=0.5, due_fraction=0.25, max_replays=4,
                backoff_clocks=32, row_retire_threshold=2,
                refresh_escalate_threshold=40),
    FaultConfig(seed=5, outage_windows=((0, 4000, 9000),)),
    FaultConfig(seed=3),                # inactive: fault-free stream
])
def test_fault_events_fast_equals_oracle(fc):
    rng = np.random.default_rng(31)
    n = 1200
    addrs = _addrs(rng, n)
    rw = (rng.random(n) < 0.2).astype(np.int32)
    arr = np.cumsum(rng.exponential(18.0, n))
    pe = rng.integers(0, 2, n)
    sched = DRAMSchedConfig(policy="frfcfs_cap", reorder_window=32,
                            starvation_cap=8, t_rfc=420, t_refi=9363)
    seq_ev, fast_ev = _trace_pair(
        timing.simulate_faults, addrs, timing.DDR4_2400, sched, rw,
        faults=fc, channel=0, arrival_fpga=arr, pe_id=pe, num_ports=2,
        arb_policy="weighted", weights=(4, 1))
    assert seq_ev == fast_ev
    if fc.injects and fc.transient_ber:
        assert any(e[0] == "replay" for e in seq_ev)
    if fc.outage_windows:
        assert any(e[0] == "outage" for e in seq_ev)


# ---------------------------------------------------------------------------
# 2. tracing never perturbs the model
# ---------------------------------------------------------------------------

def _run_case(name, trace=None):
    if name in SERVING_CASES:
        config, workload, arb_policy, weights = SERVING_CASES[name]
        rows, rw, pe, arr = workload()
        return MemoryController(config).simulate(
            pe, rows, rw, ROW_BYTES, arbiter_policy=arb_policy,
            weights=weights, arrival_cycle=arr, trace=trace)
    config, trace_fn, multiport = CASES[name]
    rows, rw = trace_fn()
    pe = None
    if multiport:
        pe = np.random.default_rng(2).integers(0, config.num_pes,
                                               rows.shape[0])
    return MemoryController(config).simulate(pe, rows, rw, ROW_BYTES,
                                             trace=trace)


@pytest.mark.parametrize("name", [
    "paper_combined_gcn", "paper_combined_multiport_gcn",
    "frfcfs_cap_refresh_gcn", "serving_poisson_frfcfs",
    "serving_hog_victim_weighted", "faults_ecc_storm",
    "faults_channel_outage",
])
def test_traced_run_bit_identical_to_untraced(name):
    base = _run_case(name)
    rec = TraceRecorder()
    traced = _run_case(name, trace=rec)
    assert rec.n_events > 0
    assert base.makespan_fpga_cycles == traced.makespan_fpga_cycles
    assert base.dram_makespan_fpga_cycles \
        == traced.dram_makespan_fpga_cycles
    assert base.breakdown() == traced.breakdown()
    if base.serving is not None:
        for f in ("completion_fpga_cycles", "arrival_fpga_cycles",
                  "service_fpga_cycles"):
            assert np.array_equal(getattr(base.serving, f),
                                  getattr(traced.serving, f))
        assert base.serving.offered_req_per_cycle \
            == traced.serving.offered_req_per_cycle
    if base.dropped is not None:
        assert np.array_equal(base.dropped, traced.dropped)


# ---------------------------------------------------------------------------
# 3. the attribution identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["serving_poisson_frfcfs",
                                  "serving_hog_victim_weighted",
                                  "faults_ecc_storm",
                                  "faults_channel_outage"])
def test_attribution_components_sum_exactly_to_sojourn(name):
    rec = TraceRecorder()
    res = _run_case(name, trace=rec)
    att = CycleAttribution.from_pipeline(res, rec)
    assert att.n == res.n_requests
    # the exact-sum identity, bit for bit, every request
    assert np.array_equal(att.ltr_sum(),
                          res.serving.sojourn_fpga_cycles)
    # every component is the documented non-negative interval length
    # (service carries the ULP residue, so give it one float of slack)
    for k in COMPONENTS:
        lo = -1e-6 if k == "service" else 0.0
        assert (att.components[k] >= lo).all(), k
    # rollups are consistent with the per-request arrays
    tot = att.totals()
    assert sum(tot.values()) == pytest.approx(
        float(res.serving.sojourn_fpga_cycles.sum()))
    per_tenant = att.per_tenant()
    assert sum(r["n"] for r in per_tenant.values()) == att.n
    top = att.top_rows(5)
    assert len(top) <= 5
    assert all(top[i]["sojourn_fpga_cycles"]
               >= top[i + 1]["sojourn_fpga_cycles"]
               for i in range(len(top) - 1))


def test_attribution_blames_the_faulty_machinery():
    """Semantic sanity on the storm case: ECC replays and refresh must
    show up as nonzero components, and the weighted arbiter's hog
    tenant must be dominated by arbitration wait."""
    rec = TraceRecorder()
    res = _run_case("faults_ecc_storm", trace=rec)
    att = CycleAttribution.from_pipeline(res, rec)
    tot = att.totals()
    assert tot["replay"] > 0
    assert tot["refresh"] > 0
    per_tenant = att.per_tenant()
    hog = per_tenant[1]
    assert max(COMPONENTS, key=lambda k: hog[k]) == "arbitration"


def test_closed_loop_attribution_aggregate_view():
    rec = TraceRecorder()
    res = _run_case("frfcfs_cap_refresh_gcn", trace=rec)
    att = CycleAttribution.from_pipeline(res, rec)
    assert att.aggregate_totals is not None
    assert sum(att.totals().values()) == pytest.approx(
        res.makespan_fpga_cycles)
    assert att.totals()["refresh"] > 0
    assert "aggregate" in att.summary_text()


# ---------------------------------------------------------------------------
# 4. the export contract
# ---------------------------------------------------------------------------

def test_chrome_trace_exports_and_validates(tmp_path):
    rec = TraceRecorder()
    _run_case("serving_hog_victim_weighted", trace=rec)
    path = tmp_path / "hog.trace.json"
    counts = tracing.write_chrome_trace(path, rec)
    assert counts["X"] > 0 and counts["C"] > 0 and counts["M"] > 0
    obj = json.loads(path.read_text())
    assert tracing.validate_chrome_trace(obj) == counts
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M"}
    assert {"channel 0", "timeline", "ports"} <= names
    assert any(n.startswith("bank ") for n in names)
    assert any(n.startswith("port ") for n in names)
    # counters exist for both documented series
    cnames = {e["name"] for e in obj["traceEvents"] if e["ph"] == "C"}
    assert "ch0 queue_depth" in cnames
    assert "ch0 reorder_occupancy" in cnames
    assert obj["otherData"]["open_loop"] is True
    assert obj["otherData"]["request_slices_dropped"] == 0


def test_validator_rejects_malformed_traces():
    rec = TraceRecorder()
    _run_case("serving_poisson_frfcfs", trace=rec)
    obj = tracing.to_chrome_trace(rec)
    tracing.validate_chrome_trace(obj)
    with pytest.raises(ValueError):
        tracing.validate_chrome_trace({"no": "traceEvents"})
    bad = json.loads(json.dumps(obj))
    bad["traceEvents"][0]["ph"] = "Q"
    with pytest.raises(ValueError, match="phase"):
        tracing.validate_chrome_trace(bad)
    bad2 = json.loads(json.dumps(obj))
    for e in bad2["traceEvents"]:
        if e["ph"] == "X":
            e["dur"] = -1.0
            break
    with pytest.raises(ValueError, match="dur"):
        tracing.validate_chrome_trace(bad2)


def test_export_slice_cap_is_loud():
    rec = TraceRecorder()
    _run_case("serving_poisson_frfcfs", trace=rec)
    obj = tracing.to_chrome_trace(rec, max_request_slices=100)
    assert obj["otherData"]["request_slices_dropped"] > 0


def test_trace_cli_smoke(tmp_path, capsys):
    from repro.trace import main
    out = tmp_path / "case.trace.json"
    attr = tmp_path / "case.attr.json"
    assert main(["serving_hog_victim_weighted", "--out", str(out),
                 "--attr", str(attr), "--validate"]) == 0
    printed = capsys.readouterr().out
    assert "validated" in printed
    assert "cycle attribution" in printed
    tracing.validate_chrome_trace(json.loads(out.read_text()))
    rollup = json.loads(attr.read_text())
    assert set(rollup["components_total"]) == set(COMPONENTS)
    assert rollup["n_requests"] == 3000


# ---------------------------------------------------------------------------
# satellite regression: closed-loop offered load is 0, never inf
# ---------------------------------------------------------------------------

def test_forced_open_loop_zero_arrivals_offers_zero():
    """A nonempty all-zero-arrival stream pushed through the serving
    datapath (open_loop=True) has no arrival process — offered load
    must report 0.0, not n/0 = inf."""
    rng = np.random.default_rng(1)
    n = 400
    rows = rng.integers(0, 128, n)
    rw = np.zeros(n, np.int32)
    res = MemoryController(MemoryControllerConfig()).simulate(
        None, rows, rw, ROW_BYTES, arrival_cycle=np.zeros(n),
        open_loop=True)
    assert res.serving is not None
    assert res.serving.offered_req_per_cycle == 0.0
    assert np.isfinite(res.serving.offered_req_per_cycle)


def test_open_loop_offered_load_unchanged():
    res = _run_case("serving_poisson_frfcfs")
    s = res.serving
    assert s.offered_req_per_cycle == pytest.approx(
        s.arrival_fpga_cycles.shape[0]
        / float(s.arrival_fpga_cycles.max()))
