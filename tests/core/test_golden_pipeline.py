"""Golden-trace regression suite: the full ``PipelineResult`` breakdown
of every pinned configuration (paper Table IV, the combined config, and
the PR-5 FR-FCFS service models) on fixed seeded traces must reproduce
the checked-in snapshots in ``tests/goldens/`` exactly.

A failure here means the *modeled numbers changed*. If the change is
intentional, regenerate with

    PYTHONPATH=src:tests/core python scripts/regen_goldens.py

and review the JSON diff — it is the machine-readable record of what
the model change did to every pinned configuration. The case
definitions are shared with the regenerator via
``tests/core/golden_cases.py``.
"""

import json
import os

import pytest

from golden_cases import (CASES, GOLDEN_DIR, MODEL_TRACE_CASES,
                          SERVING_CASES, golden_record)

_REGEN = ("snapshot mismatch for {name!r} at key {key!r}:\n"
          "  golden:   {want!r}\n"
          "  computed: {got!r}\n"
          "If this model change is intentional, run\n"
          "  PYTHONPATH=src:tests/core python scripts/regen_goldens.py\n"
          "and commit the reviewed JSON diff.")


def _load(name: str) -> dict:
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    assert os.path.exists(path), (
        f"missing golden {path} — run scripts/regen_goldens.py")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("name", sorted(CASES) + sorted(SERVING_CASES)
                         + sorted(MODEL_TRACE_CASES))
def test_golden_snapshot(name):
    golden = _load(name)
    got = golden_record(name)
    assert sorted(golden) == sorted(got), (
        f"golden {name} schema drift — regenerate goldens")
    for key in sorted(golden):
        assert golden[key] == got[key], _REGEN.format(
            name=name, key=key, want=golden[key], got=got[key])


def test_goldens_have_no_strays():
    """Every checked-in golden corresponds to a defined case (stale
    files would silently stop being checked)."""
    on_disk = {f[:-5] for f in os.listdir(GOLDEN_DIR)
               if f.endswith(".json")}
    assert on_disk == set(CASES) | set(SERVING_CASES) \
        | set(MODEL_TRACE_CASES)
    # pinned trace files (traces/ subdir) must match the case set too
    from repro.data.model_traces import TRACE_DIR
    trace_files = {f[:-5] for f in os.listdir(TRACE_DIR)
                   if f.endswith(".json")}
    assert trace_files == set(MODEL_TRACE_CASES.values())


def test_golden_frfcfs_beats_fifo_on_record():
    """The pinned snapshots themselves witness the PR-5 acceptance
    criterion: the bare FR-FCFS window-32 service beats the FIFO DRAM
    service of the same engines-off controller on the GCN trace."""
    frfcfs = _load("frfcfs_bare_gcn")
    # paper_eval runs the batch scheduler; the honest FIFO reference for
    # the bare config is recomputed (cheap) rather than pinned twice
    import dataclasses

    import golden_cases
    fifo_cfg, trace, _ = golden_cases.CASES["frfcfs_bare_gcn"]
    fifo_cfg = dataclasses.replace(
        fifo_cfg, dram_sched=golden_cases.DRAMSchedConfig())
    rows, rw = trace()
    from repro.core.controller import MemoryController
    fifo = MemoryController(fifo_cfg).simulate(
        None, rows, rw, golden_cases.ROW_BYTES)
    assert frfcfs["makespan_fpga_cycles"] < fifo.makespan_fpga_cycles


def test_golden_serving_isolation_on_record():
    """The pinned hog-vs-victim snapshot witnesses the PR-6 acceptance
    criterion: under weighted arbitration + starvation cap, the SLO
    tenant's p99 sojourn stays well under the hog's, and the recomputed
    unprotected reference (round_robin + uncapped FR-FCFS — the arbiter
    splits grants evenly and hog row-hits may starve the victim's
    conflicts) is strictly worse for the victim on the same stream."""
    import dataclasses

    import golden_cases
    from repro.core.config import DRAMSchedConfig
    from repro.core.controller import MemoryController

    rec = _load("serving_hog_victim_weighted")
    victim, hog = rec["per_tenant"]["0"], rec["per_tenant"]["1"]
    assert victim["p99_sojourn"] * 3 < hog["p99_sojourn"]
    cfg, workload, _, _ = golden_cases.SERVING_CASES[
        "serving_hog_victim_weighted"]
    uncapped = dataclasses.replace(
        cfg, dram_sched=dataclasses.replace(cfg.dram_sched,
                                            policy="frfcfs"))
    rows, rw, pe, arr = workload()
    rr = MemoryController(uncapped).simulate(
        pe, rows, rw, golden_cases.ROW_BYTES,
        arbiter_policy="round_robin", arrival_cycle=arr)
    assert victim["p99_sojourn"] < rr.serving.per_port[0]["p99_sojourn"]
