"""Staged pipeline (repro.core.pipeline) — refactor bit-identity, stage
composition, cache-filter semantics, and the validated RequestStream
ingestion point.

The legacy ``modeled_*`` entry points are compared against the
*pre-refactor* compositions, which survive verbatim as the
``use_seq_oracle=True`` paths in ``channels.py`` (and, for
``modeled_gather_time``, as the inline seed formula).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache_engine import filter_trace_rw, filter_trace_rw_seq
from repro.core.channels import (AddressMap, schedule_and_simulate_channels,
                                 simulate_multiport_channels)
from repro.core.config import (CacheConfig, ChannelConfig,
                               MemoryControllerConfig, SchedulerConfig)
from repro.core.controller import MemoryController
from repro.core.pipeline import (CacheFilterStage, PipelineContext,
                                 RequestStream, default_stages,
                                 run_pipeline)
from repro.core.scheduler import schedule_trace
from repro.core.timing import DDR4_2400, HBM_V5E, simulate_dram_access

MAP_POLICIES = ("row_interleave", "block_interleave", "xor")


def _assert_channel_results_equal(a, b):
    assert a.makespan_fpga_cycles == b.makespan_fpga_cycles
    assert a.busy_fpga_cycles == b.busy_fpga_cycles
    assert a.arbitration_cycles == b.arbitration_cycles
    assert a.requests_per_channel == b.requests_per_channel
    assert a.row_hits == b.row_hits
    assert a.row_conflicts == b.row_conflicts
    assert a.first_accesses == b.first_accesses


# ---------------------------------------------------------------------------
# Legacy entry points are bit-identical to their pre-refactor outputs
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 900), st.integers(0, 1)),
                min_size=0, max_size=250),
       st.sampled_from([1, 2, 4]),
       st.sampled_from(MAP_POLICIES),
       st.booleans(),
       st.booleans())
def test_property_modeled_access_time_unchanged(reqs, num_channels,
                                                policy, coalesce, hbm):
    """AddressMap → BatchScheduler → DRAMService subset == the
    pre-refactor per-channel schedule+simulate composition, bit for
    bit (SimResult and full ChannelSimResult)."""
    rows = np.asarray([r[0] for r in reqs], np.int64)
    rw = np.asarray([r[1] for r in reqs], np.int32)
    cfg = MemoryControllerConfig(
        channels=ChannelConfig(num_channels=num_channels, policy=policy),
        scheduler=SchedulerConfig(batch_size=32))
    mc = MemoryController(cfg, timings=HBM_V5E if hbm else DDR4_2400)
    new = mc.modeled_channel_access_time(rows, rw, 4096,
                                         coalesce_writes=coalesce)
    old = schedule_and_simulate_channels(
        rows * 4096, rw, sched_config=cfg.scheduler, timings=mc.timings,
        channel_cfg=cfg.channels, coalesce_writes=coalesce,
        use_seq_oracle=True)
    _assert_channel_results_equal(new, old)
    flat = mc.modeled_access_time(rows, rw, 4096, coalesce_writes=coalesce)
    assert flat.total_fpga_cycles == old.makespan_fpga_cycles


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 700),
                          st.integers(0, 1)),
                min_size=0, max_size=200),
       st.sampled_from([1, 4]),
       st.sampled_from(["round_robin", "priority", "weighted"]),
       st.booleans())
def test_property_modeled_multiport_unchanged(reqs, num_channels,
                                              arb_policy, sched_on):
    rows = np.asarray([r[1] for r in reqs], np.int64)
    pe = np.asarray([r[0] for r in reqs], np.int64)
    rw = np.asarray([r[2] for r in reqs], np.int32)
    weights = [2, 1, 3, 1] if arb_policy == "weighted" else None
    cfg = MemoryControllerConfig(
        num_pes=4, channels=ChannelConfig(num_channels=num_channels),
        scheduler=SchedulerConfig(enabled=sched_on, batch_size=16))
    mc = MemoryController(cfg)
    new = mc.modeled_multiport_access_time(pe, rows, rw, 4096,
                                           policy=arb_policy,
                                           weights=weights)
    old = simulate_multiport_channels(
        pe, rows * 4096, rw, num_ports=4, policy=arb_policy,
        weights=weights, timings=mc.timings, channel_cfg=cfg.channels,
        sched_config=cfg.scheduler if sched_on else None,
        use_seq_oracle=True)
    _assert_channel_results_equal(new, old)
    np.testing.assert_array_equal(new.port_stats.grants,
                                  old.port_stats.grants)
    np.testing.assert_array_equal(new.port_stats.stall_slots,
                                  old.port_stats.stall_slots)
    assert new.port_stats.fairness == old.port_stats.fairness


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 2000), min_size=0, max_size=300),
       st.booleans())
def test_property_modeled_gather_time_seed_identity(rows, sched_on):
    """At num_channels=1 the pipelined modeled_gather_time reproduces the
    seed ``schedule_trace`` + ``simulate_dram_access`` composition."""
    rows = np.asarray(rows, np.int64)
    cfg = MemoryControllerConfig(
        scheduler=SchedulerConfig(enabled=sched_on))
    mc = MemoryController(cfg)
    new = mc.modeled_gather_time(rows, 512)
    served = schedule_trace(rows * 512, np.zeros(rows.shape[0], np.int32),
                            config=cfg.scheduler, timings=mc.timings)
    old = simulate_dram_access(served, mc.timings)
    assert new.total_fpga_cycles == old.total_fpga_cycles
    assert (new.row_hits, new.row_conflicts, new.first_accesses) == \
        (old.row_hits, old.row_conflicts, old.first_accesses)


def test_modeled_gather_time_respects_channels(rng):
    """Regression (ISSUE 4 satellite): modeled_gather_time used to call
    schedule_trace + simulate_dram_access directly, so a multi-channel
    controller reported single-channel numbers for read-only traces. It
    must now agree with the channel-decomposed read path and beat the
    single-interface makespan on an irregular trace."""
    rows = rng.integers(0, 1 << 14, 20000)
    mc1 = MemoryController(MemoryControllerConfig())
    mc4 = MemoryController(MemoryControllerConfig(
        channels=ChannelConfig(num_channels=4)))
    t1 = mc1.modeled_gather_time(rows, 512)
    t4 = mc4.modeled_gather_time(rows, 512)
    assert t4.total_fpga_cycles < t1.total_fpga_cycles
    via_channels = mc4.modeled_channel_access_time(
        rows, np.zeros(rows.shape[0], np.int32), 512).as_sim_result()
    assert t4.total_fpga_cycles == via_channels.total_fpga_cycles
    assert t4.row_hits == via_channels.row_hits


# ---------------------------------------------------------------------------
# RequestStream — the validated ingestion point
# ---------------------------------------------------------------------------

def test_from_rows_rejects_bad_inputs(rng):
    good = rng.integers(0, 100, 16)
    with pytest.raises(ValueError, match="negative"):
        RequestStream.from_rows(np.asarray([3, -1, 2]), row_bytes=64)
    with pytest.raises(ValueError, match="overflow"):
        RequestStream.from_rows(np.asarray([1 << 60]), row_bytes=1024)
    with pytest.raises(ValueError, match="row_bytes"):
        RequestStream.from_rows(good, row_bytes=0)
    with pytest.raises(TypeError, match="integer"):
        RequestStream.from_rows(good.astype(np.float32), row_bytes=64)
    with pytest.raises(ValueError, match="one entry per request"):
        RequestStream.from_rows(good, np.zeros(5, np.int32), row_bytes=64)
    with pytest.raises(ValueError, match="0 .*read.* or 1"):
        RequestStream.from_rows(good, np.full(16, 2), row_bytes=64)
    with pytest.raises(ValueError, match="pe_id"):
        RequestStream.from_rows(good, pe_id=np.zeros(3), row_bytes=64)
    s = RequestStream.from_rows(good, rng.integers(0, 2, 16),
                                row_bytes=64, pe_id=rng.integers(0, 4, 16))
    assert len(s) == 16
    np.testing.assert_array_equal(s.addr, good.astype(np.int64) * 64)
    np.testing.assert_array_equal(s.seq, np.arange(16))


# ---------------------------------------------------------------------------
# Cache filter — oracle identity, write policies, channel commutation
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3000), st.integers(0, 1)),
                min_size=0, max_size=300),
       st.sampled_from([(1, 256), (2, 512), (4, 1024), (8, 4096)]),
       st.sampled_from(["write_back", "write_through"]))
def test_property_cache_filter_fast_vs_seq(reqs, shape, policy):
    ways, lines = shape
    cfg = CacheConfig(num_lines=lines, associativity=ways,
                      write_policy=policy)
    lids = np.asarray([r[0] for r in reqs], np.int64)
    rw = np.asarray([r[1] for r in reqs], np.int32)
    fast = filter_trace_rw(cfg, lids, rw, engine="parallel")
    ref = filter_trace_rw_seq(cfg, lids, rw)
    np.testing.assert_array_equal(fast.hits, ref.hits)
    np.testing.assert_array_equal(fast.keep, ref.keep)
    np.testing.assert_array_equal(fast.wb_pos, ref.wb_pos)
    np.testing.assert_array_equal(fast.wb_line, ref.wb_line)


def test_cache_filter_write_policies_hand_case():
    """Direct-mapped 1-set view of the policy split: write-back absorbs
    the write hit and flushes the dirty victim on eviction; write-through
    forwards every write and never writes back."""
    cfg_wb = CacheConfig(num_lines=256, associativity=1,
                         write_policy="write_back")
    # conflict chain within one set: lines 0, 256, 512 all map to set 0
    lids = np.asarray([0, 0, 256, 512], np.int64)
    rw = np.asarray([1, 1, 0, 0], np.int32)   # write, write-hit, evict, evict
    r = filter_trace_rw_seq(cfg_wb, lids, rw)
    np.testing.assert_array_equal(r.hits, [False, True, False, False])
    np.testing.assert_array_equal(r.keep, [True, False, True, True])
    np.testing.assert_array_equal(r.wb_pos, [2])   # dirty line 0 flushed
    np.testing.assert_array_equal(r.wb_line, [0])  # ... when 256 evicts it
    cfg_wt = CacheConfig(num_lines=256, associativity=1,
                         write_policy="write_through")
    r = filter_trace_rw_seq(cfg_wt, lids, rw)
    np.testing.assert_array_equal(r.hits, [False, True, False, False])
    np.testing.assert_array_equal(r.keep, [True, True, True, True])
    assert r.n_writebacks == 0


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 500), st.integers(0, 1)),
                min_size=0, max_size=250),
       st.sampled_from([1, 2, 4]),
       st.sampled_from(MAP_POLICIES),
       st.sampled_from(["write_back", "write_through"]))
def test_property_filter_commutes_with_channel_split(reqs, num_channels,
                                                     map_policy, wpolicy):
    """The cache is banked per channel, so filtering the global annotated
    stream (per-channel states, arrival order) == splitting by channel
    and filtering each substream independently."""
    cache = CacheConfig(num_lines=256, associativity=2,
                        write_policy=wpolicy)
    ccfg = ChannelConfig(num_channels=num_channels, policy=map_policy)
    amap = AddressMap(ccfg, DDR4_2400)
    addrs = np.asarray([r[0] * 4096 for r in reqs], np.int64)
    rw = np.asarray([r[1] for r in reqs], np.int32)
    ch = amap.channel_of(addrs)
    lids = amap.local_addr(addrs) // cache.line_bytes

    # filter-then-split: one walk over the global stream with per-channel
    # banked dict states (independent reference implementation)
    sets, ways = cache.num_sets, cache.associativity
    wb = wpolicy == "write_back"
    states: dict = {}
    g_hits = np.zeros(addrs.shape[0], bool)
    g_keep = np.ones(addrs.shape[0], bool)
    g_wb: list[tuple[int, int, int]] = []     # (pos, channel, line)
    for i in range(addrs.shape[0]):
        k, lid = int(ch[i]), int(lids[i])
        s, t = lid % sets, lid // sets
        e = states.setdefault((k, s), {})
        w = int(rw[i]) == 1
        if t in e:
            g_hits[i] = True
            e[t] = [i, wb if w else e[t][1]]
            g_keep[i] = w and not wb
        else:
            if len(e) >= ways:
                vt = min(e, key=lambda x: e[x][0])
                if e[vt][1]:
                    g_wb.append((i, k, vt * sets + s))
                del e[vt]
            e[t] = [i, w and wb]

    # split-then-filter: what the pipeline's CacheFilter stage runs
    for k in range(num_channels):
        sel = np.flatnonzero(ch == k)
        res = filter_trace_rw(cache, lids[sel], rw[sel])
        np.testing.assert_array_equal(res.hits, g_hits[sel])
        np.testing.assert_array_equal(res.keep, g_keep[sel])
        mine = [(p, line) for p, kk, line in g_wb if kk == k]
        # global position → position within the channel substream
        np.testing.assert_array_equal(
            res.wb_pos, [int(np.searchsorted(sel, p)) for p, _ in mine])
        np.testing.assert_array_equal(res.wb_line,
                                      [line for _, line in mine])


def test_cache_filter_stage_stream_is_coherent(rng):
    """Stage-level invariants of the filtered stream: write-backs are
    tagged, every address recomposes onto its annotated channel (the
    AddressMap bijection inverse), and the kept requests are exactly the
    filter's keep set."""
    cfg = MemoryControllerConfig(
        cache=CacheConfig(num_lines=256, associativity=2),
        channels=ChannelConfig(num_channels=4))
    ctx = PipelineContext.from_config(cfg, DDR4_2400)
    rows = rng.integers(0, 2000, 3000)
    rw = rng.integers(0, 2, 3000)
    stream = RequestStream.from_rows(rows, rw, row_bytes=4096)
    stages = default_stages(ctx, cache=True)
    annotated, _ = stages[0].run(stream, ctx)
    filtered, stats = CacheFilterStage().run(annotated, ctx)
    assert stats.info["n_writebacks"] == int(
        filtered.tags["writeback"].sum())
    assert len(filtered) == stats.out_requests
    amap = ctx.address_map()
    np.testing.assert_array_equal(amap.channel_of(filtered.addr),
                                  filtered.channel)
    np.testing.assert_array_equal(amap.local_addr(filtered.addr),
                                  filtered.local_addr)
    # under write-back every hit (read or write) is absorbed, so the
    # forwarded originals are exactly the misses
    n_orig = int((~filtered.tags["writeback"]).sum())
    assert n_orig == len(annotated) - stats.info["n_hits"]
    assert (filtered.rw[filtered.tags["writeback"]] == 1).all()


# ---------------------------------------------------------------------------
# Full-pipeline composition
# ---------------------------------------------------------------------------

def test_pipeline_cache_disabled_matches_legacy_entry_points(rng):
    """simulate() with the cache engine disabled is bit-identical to
    modeled_access_time / modeled_multiport_access_time (the stage
    subset the wrappers run)."""
    rows = rng.integers(0, 4096, 5000)
    rw = rng.integers(0, 2, 5000)
    pe = rng.integers(0, 8, 5000)
    cfg = MemoryControllerConfig(
        cache=CacheConfig(enabled=False),
        channels=ChannelConfig(num_channels=4))
    mc = MemoryController(cfg)
    res = mc.simulate(None, rows, rw, 512)
    _assert_channel_results_equal(
        res.as_channel_result(),
        mc.modeled_channel_access_time(rows, rw, 512))
    assert res.as_sim_result().total_fpga_cycles == \
        mc.modeled_access_time(rows, rw, 512).total_fpga_cycles
    resp = mc.simulate(pe, rows, rw, 512)
    _assert_channel_results_equal(
        resp.as_channel_result(),
        mc.modeled_multiport_access_time(pe, rows, rw, 512))


@pytest.mark.parametrize("n", [0, 1])
def test_pipeline_empty_and_single_request_streams(n, rng):
    """Boundary streams flow through the *full* composition (arbiter +
    cache + scheduler + channels) without special-casing. The
    controller's ``simulate()`` refuses the empty trace (input
    hardening — an all-zero result silently poisons derived numbers),
    so the degenerate run is built from the pipeline primitives."""
    cfg = MemoryControllerConfig(
        channels=ChannelConfig(num_channels=4))
    mc = MemoryController(cfg)
    rows = rng.integers(0, 100, n)
    rw = rng.integers(0, 2, n)
    pe = rng.integers(0, cfg.num_pes, n)
    if n == 0:
        with pytest.raises(ValueError, match="empty trace"):
            mc.simulate(pe, rows, rw, 512)
        ctx = PipelineContext.from_config(cfg, mc.timings)
        stream = RequestStream.from_rows(rows, rw, row_bytes=512,
                                         pe_id=pe)
        res = run_pipeline(stream, ctx,
                           default_stages(ctx, ports=cfg.num_pes))
    else:
        res = mc.simulate(pe, rows, rw, 512)
    assert res.n_requests == n
    assert sum(res.requests_per_channel) == n
    assert len(res.per_channel) == 4
    assert res.makespan_fpga_cycles >= cfg.ctrl_overhead_cycles
    if n == 0:
        assert res.dram_makespan_fpga_cycles == 0.0
        assert res.cache_hit_rate == 0.0
    else:
        assert res.dram_makespan_fpga_cycles > 0.0
    assert res.port_stats is not None
    assert int(res.port_stats.grants.sum()) == n


def test_pipeline_breakdown_sums_to_makespan(rng):
    mc = MemoryController(MemoryControllerConfig(
        channels=ChannelConfig(num_channels=2)))
    rows = rng.integers(0, 1 << 13, 8000)
    rw = rng.integers(0, 2, 8000)
    res = mc.simulate(None, rows, rw, 512)
    bd = res.breakdown()
    assert bd["ctrl_overhead"] == mc.config.ctrl_overhead_cycles
    assert abs(sum(bd.values()) - res.makespan_fpga_cycles) < 1e-6
    assert [s.name for s in res.stages] == [
        "address_map", "cache_filter", "batch_scheduler",
        "dram_service", "dma_overlap"]


def test_combined_cache_channels_beats_scheduler_only(rng):
    """The headline composition: cache + scheduler + channels together
    beat the scheduler-only controller on a cache-friendly irregular
    trace — the paper's claim that the win comes from the composition."""
    rows = (rng.zipf(1.2, 30000) - 1) % (1 << 14)
    rw = rng.integers(0, 2, 30000)
    combined = MemoryController(MemoryControllerConfig(
        channels=ChannelConfig(num_channels=4)))
    sched_only = MemoryController(MemoryControllerConfig(
        cache=CacheConfig(enabled=False),
        channels=ChannelConfig(num_channels=4)))
    a = combined.simulate(None, rows, rw, 512)
    b = sched_only.simulate(None, rows, rw, 512)
    assert a.cache_hit_rate > 0.3
    assert a.makespan_fpga_cycles < b.makespan_fpga_cycles
    # the cache filter genuinely shrank the DRAM stream
    assert a.dram_makespan_fpga_cycles < b.dram_makespan_fpga_cycles


# ---------------------------------------------------------------------------
# RequestStream.select / _concat_streams round-trips (direct coverage —
# previously exercised only through full pipeline runs)
# ---------------------------------------------------------------------------

def _full_stream(rng, n=257):
    s = RequestStream.from_rows(
        rng.integers(0, 5000, n), rng.integers(0, 2, n),
        row_bytes=512, pe_id=rng.integers(0, 8, n))
    amap = AddressMap(ChannelConfig(num_channels=4), DDR4_2400)
    s.channel = amap.channel_of(s.addr)
    s.local_addr = amap.local_addr(s.addr)
    s.tags["writeback"] = rng.random(n) < 0.25
    return s


def _assert_streams_equal(a, b):
    np.testing.assert_array_equal(a.addr, b.addr)
    np.testing.assert_array_equal(a.rw, b.rw)
    np.testing.assert_array_equal(a.pe_id, b.pe_id)
    np.testing.assert_array_equal(a.seq, b.seq)
    np.testing.assert_array_equal(a.channel, b.channel)
    np.testing.assert_array_equal(a.local_addr, b.local_addr)
    assert sorted(a.tags) == sorted(b.tags)
    for k in a.tags:
        np.testing.assert_array_equal(a.tags[k], b.tags[k])


def test_select_permutation_round_trip(rng):
    """select(perm) then select(inverse) restores every array,
    annotations and tags included."""
    s = _full_stream(rng)
    perm = rng.permutation(len(s))
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(s))
    _assert_streams_equal(s.select(perm).select(inv), s)
    # sub-selection keeps the tag rows aligned with the requests
    sel = np.flatnonzero(s.rw == 1)
    sub = s.select(sel)
    assert len(sub) == sel.size
    np.testing.assert_array_equal(sub.tags["writeback"],
                                  s.tags["writeback"][sel])


def test_select_without_annotations_keeps_none(rng):
    s = RequestStream.from_rows(rng.integers(0, 100, 16), row_bytes=64)
    sub = s.select(np.arange(8))
    assert sub.channel is None and sub.local_addr is None


def test_concat_streams_split_round_trip(rng):
    """Splitting a stream into chunks and concatenating restores it —
    the invariant the CacheFilter's per-channel merge relies on."""
    from repro.core.pipeline import _concat_streams
    s = _full_stream(rng)
    cuts = [0, 40, 41, 150, len(s)]
    parts = [s.select(np.arange(a, b)) for a, b in zip(cuts, cuts[1:])]
    _assert_streams_equal(_concat_streams(parts), s)


def test_concat_streams_mixed_annotations_and_tags(rng):
    """A part without annotations poisons the concat to None (a later
    AddressMap run re-annotates); missing tags raise rather than
    silently misalign."""
    from repro.core.pipeline import _concat_streams
    s = _full_stream(rng, n=64)
    bare = RequestStream.from_rows(rng.integers(0, 100, 8),
                                   row_bytes=512)
    bare.tags["writeback"] = np.zeros(8, bool)
    merged = _concat_streams([s, bare])
    assert merged.channel is None and merged.local_addr is None
    assert len(merged) == len(s) + 8
    no_tag = RequestStream.from_rows(rng.integers(0, 100, 8),
                                     row_bytes=512)
    with pytest.raises(KeyError):
        _concat_streams([s, no_tag])


def test_concat_streams_empty_list():
    from repro.core.pipeline import _concat_streams
    out = _concat_streams([])
    assert len(out) == 0 and out.tags == {}


# ---------------------------------------------------------------------------
# PipelineResult legacy-view adapters (direct coverage)
# ---------------------------------------------------------------------------

def test_as_channel_result_and_as_sim_result_fields(rng):
    """The adapters reproduce the DRAM-service + arbitration view:
    makespan = slowest channel + arbiter fill, counts aggregate over
    channels, and the SimResult view collapses the same numbers."""
    cfg = MemoryControllerConfig(
        channels=ChannelConfig(num_channels=4))
    mc = MemoryController(cfg)
    rows = rng.integers(0, 4096, 4000)
    rw = rng.integers(0, 2, 4000)
    pe = rng.integers(0, cfg.num_pes, 4000)
    res = mc.simulate(pe, rows, rw, 512)
    ch = res.as_channel_result()
    assert ch.arbitration_cycles == res.arbitration_cycles
    assert ch.per_channel == res.per_channel
    assert ch.requests_per_channel == res.requests_per_channel
    assert ch.makespan_fpga_cycles == pytest.approx(
        max(r.total_fpga_cycles for r in res.per_channel)
        + res.arbitration_cycles)
    assert ch.busy_fpga_cycles == pytest.approx(
        sum(r.total_fpga_cycles for r in res.per_channel))
    assert ch.port_stats is res.port_stats
    assert ch.row_hits == sum(r.row_hits for r in res.per_channel)
    sim = res.as_sim_result()
    assert sim.total_fpga_cycles == ch.makespan_fpga_cycles
    assert (sim.row_hits, sim.row_conflicts, sim.first_accesses) == \
        (ch.row_hits, ch.row_conflicts, ch.first_accesses)
    assert sim.hit_rate == pytest.approx(ch.hit_rate)


def test_adapters_on_empty_pipeline():
    """simulate() hard-fails on an empty trace; the legacy result
    adapters still handle the degenerate pipeline run cleanly."""
    cfg = MemoryControllerConfig(channels=ChannelConfig(num_channels=2))
    mc = MemoryController(cfg)
    with pytest.raises(ValueError, match="empty trace"):
        mc.simulate(None, np.empty(0, np.int64), None, 512)
    ctx = PipelineContext.from_config(cfg, mc.timings)
    stream = RequestStream.from_rows(np.empty(0, np.int64), None,
                                     row_bytes=512)
    res = run_pipeline(stream, ctx, default_stages(ctx))
    ch = res.as_channel_result()
    assert ch.makespan_fpga_cycles == 0.0
    assert ch.requests_per_channel == [0, 0]
    sim = res.as_sim_result()
    assert (sim.total_fpga_cycles, sim.row_hits) == (0.0, 0)
