"""Controller value-identity contract: engines may only change performance,
never results — the property that makes them paper-style 'plug and play'."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (HotRowCache, MemoryController, PAPER_EVAL_CONFIG,
                        sorted_gather, sorted_scatter)
from repro.kernels.sorted_scatter.ref import scatter_ref
from repro.core.autotune import tune
from repro.core.config import (CacheConfig, DMAConfig,
                               MemoryControllerConfig, SchedulerConfig)


def _cfg(sched=True, cache=True, dma=True):
    return MemoryControllerConfig(
        scheduler=SchedulerConfig(enabled=sched),
        cache=CacheConfig(enabled=cache),
        dma=DMAConfig(enabled=dma))


@pytest.mark.parametrize("sched", [True, False])
@pytest.mark.parametrize("cache", [True, False])
def test_gather_identity_across_engine_configs(sched, cache, rng):
    mc = MemoryController(_cfg(sched=sched, cache=cache))
    table = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 128, (4, 9)), jnp.int32)
    out = mc.gather(table, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[idx]))


def test_hot_row_cache_identity(rng):
    table = jnp.asarray(rng.standard_normal((256, 8)), jnp.float32)
    cache = HotRowCache.build(table, hot_ids=rng.choice(256, 32,
                                                        replace=False))
    mc = MemoryController(_cfg())
    idx = jnp.asarray(rng.integers(0, 256, 100), jnp.int32)
    out = mc.cached_gather(table, idx, cache)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[idx]))
    # hot ids actually hit
    hot_idx = jnp.asarray(np.asarray(cache.hot_ids)[:5])
    assert bool(cache.hit_mask(hot_idx).all())


def test_hot_row_cache_empty_hot_set_is_all_miss(rng):
    """Regression: with zero pinned rows, searchsorted positions clipped
    to H-1 = -1 used to index from the *end* of hot_ids and could report
    spurious hits; the empty set must be all-miss and gather must be
    value-identical to table[idx]."""
    table = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
    cache = HotRowCache.build(table, hot_ids=np.empty(0, np.int32))
    idx = jnp.asarray(rng.integers(0, 64, 33), jnp.int32)
    assert not bool(cache.hit_mask(idx).any())
    np.testing.assert_array_equal(np.asarray(cache.gather(table, idx)),
                                  np.asarray(table[idx]))


def test_bulk_read_identity(rng):
    mc = MemoryController(PAPER_EVAL_CONFIG)
    x = jnp.asarray(rng.standard_normal((64, 100)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(mc.bulk_read(x)), np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
def test_property_sorted_gather_identity(ids):
    table = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
    idx = jnp.asarray(ids, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(sorted_gather(table, idx)), np.asarray(table[idx]))


@pytest.mark.parametrize("sched", [True, False])
@pytest.mark.parametrize("cache", [True, False])
@pytest.mark.parametrize("dma", [True, False])
@pytest.mark.parametrize("mode", ["set", "add"])
def test_scatter_identity_across_engine_configs(sched, cache, dma, mode,
                                                rng):
    if not (sched or cache or dma):
        pytest.skip("MemoryControllerConfig requires at least one engine")
    mc = MemoryController(_cfg(sched=sched, cache=cache, dma=dma))
    table = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 128, (4, 9)), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((4, 9, 16)), jnp.float32)
    out = mc.scatter(table, idx, vals, mode=mode)
    # scatter_ref is the sequential in-order oracle — deterministic for
    # duplicate rows on every backend (unlike raw .at[].set)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(scatter_ref(table, idx, vals, mode)),
        rtol=1e-5, atol=1e-5)


def test_scatter_duplicate_addresses_last_writer_wins(rng):
    """Same-address writes keep arrival order through the scheduler's
    stable sort — the weak-consistency rule on the write path."""
    table = jnp.zeros((16, 4), jnp.float32)
    idx = jnp.asarray([7, 2, 7, 7, 2], jnp.int32)
    vals = jnp.asarray(
        [[i + 1.0] * 4 for i in range(5)], jnp.float32)
    for sched in (True, False):
        out = np.asarray(MemoryController(_cfg(sched=sched)).scatter(
            table, idx, vals))
        np.testing.assert_array_equal(out[7], [4.0] * 4)  # arrival 3 last
        np.testing.assert_array_equal(out[2], [5.0] * 4)  # arrival 4 last


def test_scatter_add_toggle_identity_bf16():
    """bf16 tables: scheduler on/off must agree — both accumulate runs
    in f32 and round once, so small addends aren't swallowed on one
    path only (the failure mode of per-element bf16 adds)."""
    table = jnp.full((4, 2), 256.0, jnp.bfloat16)
    idx = jnp.zeros((128,), jnp.int32)
    vals = jnp.full((128, 2), 0.5, jnp.bfloat16)
    on = MemoryController(_cfg(sched=True)).scatter(table, idx, vals,
                                                    mode="add")
    off = MemoryController(_cfg(sched=False)).scatter(table, idx, vals,
                                                      mode="add")
    np.testing.assert_array_equal(np.asarray(on, np.float32),
                                  np.asarray(off, np.float32))
    assert float(on[0, 0]) == 320.0     # 256 + 128*0.5, not swallowed


def test_cached_scatter_keeps_hot_rows_coherent(rng):
    table = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    hot = np.sort(rng.choice(64, 16, replace=False))
    cache = HotRowCache.build(table, hot_ids=hot)
    mc = MemoryController(_cfg())
    idx = jnp.asarray(rng.integers(0, 64, 40), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((40, 8)), jnp.float32)
    new_table, new_cache = mc.cached_scatter(table, idx, vals, cache)
    # a cached gather after the write must see the written values
    out = mc.cached_gather(new_table, idx, new_cache)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(new_table[idx]), rtol=1e-6)


@pytest.mark.parametrize("dma", [True, False])
def test_bulk_write_identity(dma, rng):
    mc = MemoryController(_cfg(dma=dma))
    dst = jnp.asarray(rng.standard_normal((32, 100)), jnp.float32)
    src = jnp.asarray(rng.standard_normal((7, 100)), jnp.float32)
    out = mc.bulk_write(dst, src, offset_elems=250)
    ref = np.array(dst).reshape(-1)
    ref[250:250 + 700] = np.asarray(src).reshape(-1)
    np.testing.assert_array_equal(np.asarray(out).reshape(-1), ref)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=1, max_size=120),
       st.sampled_from(["set", "add"]))
def test_property_sorted_scatter_identity(ids, mode):
    table = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
    idx = jnp.asarray(ids, jnp.int32)
    vals = (jnp.arange(len(ids), dtype=jnp.float32)[:, None]
            * jnp.ones((1, 4)))
    out = sorted_scatter(table, idx, vals, mode=mode)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(scatter_ref(table, idx, vals, mode)),
        rtol=1e-5, atol=1e-5)


def test_modeled_access_time_improves_with_scheduler(rng):
    rows = rng.integers(0, 256, 2048)
    rw = rng.integers(0, 2, 2048)
    on = MemoryController(_cfg(sched=True)).modeled_access_time(rows, rw,
                                                                512)
    off = MemoryController(_cfg(sched=False)).modeled_access_time(rows, rw,
                                                                  512)
    assert on.total_fpga_cycles < off.total_fpga_cycles


def test_modeled_gather_time_improves_with_scheduler(rng):
    rows = rng.integers(0, 256, 2048)
    on = MemoryController(_cfg(sched=True)).modeled_gather_time(rows, 512)
    off = MemoryController(_cfg(sched=False)).modeled_gather_time(rows, 512)
    assert on.total_fpga_cycles <= off.total_fpga_cycles


def test_autotune_respects_vmem_budget(rng):
    res = tune(rng.integers(0, 4096, 1024), 512,
               vmem_budget_bytes=1 << 20,
               batch_sizes=(16, 64), associativities=(1, 4),
               num_lines=(1024, 16384), dma_channels=(1,))
    assert res.config.vmem_footprint_bytes() <= 1 << 20
    assert res.candidates_evaluated > 0


def test_autotune_rejects_impossible_budget(rng):
    with pytest.raises(ValueError):
        tune(rng.integers(0, 64, 64), 512, vmem_budget_bytes=16,
             batch_sizes=(16,), associativities=(1,), num_lines=(1024,),
             dma_channels=(1,))
