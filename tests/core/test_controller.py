"""Controller value-identity contract: engines may only change performance,
never results — the property that makes them paper-style 'plug and play'."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (HotRowCache, MemoryController, PAPER_EVAL_CONFIG,
                        sorted_gather)
from repro.core.autotune import tune
from repro.core.config import (CacheConfig, DMAConfig,
                               MemoryControllerConfig, SchedulerConfig)


def _cfg(sched=True, cache=True, dma=True):
    return MemoryControllerConfig(
        scheduler=SchedulerConfig(enabled=sched),
        cache=CacheConfig(enabled=cache),
        dma=DMAConfig(enabled=dma))


@pytest.mark.parametrize("sched", [True, False])
@pytest.mark.parametrize("cache", [True, False])
def test_gather_identity_across_engine_configs(sched, cache, rng):
    mc = MemoryController(_cfg(sched=sched, cache=cache))
    table = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 128, (4, 9)), jnp.int32)
    out = mc.gather(table, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[idx]))


def test_hot_row_cache_identity(rng):
    table = jnp.asarray(rng.standard_normal((256, 8)), jnp.float32)
    cache = HotRowCache.build(table, hot_ids=rng.choice(256, 32,
                                                        replace=False))
    mc = MemoryController(_cfg())
    idx = jnp.asarray(rng.integers(0, 256, 100), jnp.int32)
    out = mc.cached_gather(table, idx, cache)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[idx]))
    # hot ids actually hit
    hot_idx = jnp.asarray(np.asarray(cache.hot_ids)[:5])
    assert bool(cache.hit_mask(hot_idx).all())


def test_bulk_read_identity(rng):
    mc = MemoryController(PAPER_EVAL_CONFIG)
    x = jnp.asarray(rng.standard_normal((64, 100)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(mc.bulk_read(x)), np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
def test_property_sorted_gather_identity(ids):
    table = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
    idx = jnp.asarray(ids, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(sorted_gather(table, idx)), np.asarray(table[idx]))


def test_modeled_gather_time_improves_with_scheduler(rng):
    rows = rng.integers(0, 256, 2048)
    on = MemoryController(_cfg(sched=True)).modeled_gather_time(rows, 512)
    off = MemoryController(_cfg(sched=False)).modeled_gather_time(rows, 512)
    assert on.total_fpga_cycles <= off.total_fpga_cycles


def test_autotune_respects_vmem_budget(rng):
    res = tune(rng.integers(0, 4096, 1024), 512,
               vmem_budget_bytes=1 << 20,
               batch_sizes=(16, 64), associativities=(1, 4),
               num_lines=(1024, 16384), dma_channels=(1,))
    assert res.config.vmem_footprint_bytes() <= 1 << 20
    assert res.candidates_evaluated > 0


def test_autotune_rejects_impossible_budget(rng):
    with pytest.raises(ValueError):
        tune(rng.integers(0, 64, 64), 512, vmem_budget_bytes=16,
             batch_sizes=(16,), associativities=(1,), num_lines=(1024,),
             dma_channels=(1,))
