"""Cache engine (functional scan LRU) behavioural tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache_engine import (flush, hit_rate_oracle, init_cache,
                                     simulate_trace, simulate_trace_rw)
from repro.core.config import CacheConfig


def test_trace_serves_correct_lines(rng):
    cfg = CacheConfig(num_lines=256, associativity=2)
    table = jnp.asarray(rng.standard_normal((1024, 4)), jnp.float32)
    lids = jnp.asarray(rng.integers(0, 1024, 200), jnp.int32)
    st0 = init_cache(cfg, 4)
    _, hits, lines = simulate_trace(st0, lids, table)
    np.testing.assert_allclose(np.asarray(lines), np.asarray(table[lids]))


def test_repeat_access_hits():
    cfg = CacheConfig(num_lines=256, associativity=4)
    table = jnp.ones((512, 4))
    lids = jnp.asarray([7, 7, 7, 7], jnp.int32)
    _, hits, _ = simulate_trace(init_cache(cfg, 4), lids, table)
    np.testing.assert_array_equal(np.asarray(hits), [False, True, True,
                                                     True])


def test_direct_mapped_conflict_misses():
    """ways=1: two lines mapping to the same set always evict each other."""
    cfg = CacheConfig(num_lines=256, associativity=1)
    sets = cfg.num_sets
    table = jnp.ones((4 * sets, 4))
    lids = jnp.asarray([5, 5 + sets, 5, 5 + sets], jnp.int32)
    _, hits, _ = simulate_trace(init_cache(cfg, 4), lids, table)
    assert not np.asarray(hits).any()


def test_higher_associativity_never_hurts_this_workload(rng):
    lids = rng.integers(0, 2048, 2000)
    rates = []
    for ways in (1, 2, 4, 8):
        cfg = CacheConfig(num_lines=1024, associativity=ways)
        _, rate = hit_rate_oracle(cfg, lids)
        rates.append(rate)
    assert rates == sorted(rates) or max(rates) - min(rates) < 0.02


# ---------------------------------------------------------------------------
# Write policy (write-allocate; write-back / write-through)
# ---------------------------------------------------------------------------

def _run_rw(cfg, lids, rw, wlines, table):
    st0 = init_cache(cfg, table.shape[1])
    st1, tbl, hits, lines = simulate_trace_rw(
        st0, jnp.asarray(lids, jnp.int32), jnp.asarray(rw, jnp.int32),
        wlines, table, config=cfg)
    return st1, tbl, hits, lines


def test_write_back_round_trip():
    """write → force eviction → re-read returns the written data (victim
    flush pushed the dirty line to DRAM before the way was reused)."""
    cfg = CacheConfig(num_lines=256, associativity=1,
                      write_policy="write_back")
    sets = cfg.num_sets
    table = jnp.zeros((4 * sets, 4), jnp.float32)
    target = 5
    written = jnp.full((4,), 7.5, jnp.float32)
    # write line 5, then read 5+sets and 5+2*sets (both map to set 5,
    # ways=1 ⇒ each evicts the previous occupant), then re-read 5
    lids = [target, target + sets, target + 2 * sets, target]
    rw = [1, 0, 0, 0]
    wlines = jnp.stack([written, jnp.zeros(4), jnp.zeros(4), jnp.zeros(4)])
    st1, tbl, hits, lines = _run_rw(cfg, lids, rw, wlines, table)
    np.testing.assert_array_equal(np.asarray(lines)[3], np.asarray(written))
    np.testing.assert_array_equal(np.asarray(tbl)[target],
                                  np.asarray(written))


def test_write_back_dirty_stays_cached_until_eviction():
    """Under write-back a write must NOT reach DRAM while the line is
    resident; flush() pushes the residue."""
    cfg = CacheConfig(num_lines=256, associativity=4,
                      write_policy="write_back")
    table = jnp.zeros((1024, 4), jnp.float32)
    written = jnp.full((1, 4), 3.25, jnp.float32)
    st1, tbl, _, _ = _run_rw(cfg, [9], [1], written, table)
    assert not np.asarray(tbl[9]).any()          # DRAM still stale
    st2, tbl2 = flush(st1, tbl)
    np.testing.assert_array_equal(np.asarray(tbl2)[9], np.asarray(written)[0])
    assert not np.asarray(st2.dirty).any()


def test_write_through_updates_dram_immediately():
    cfg = CacheConfig(num_lines=256, associativity=4,
                      write_policy="write_through")
    table = jnp.zeros((1024, 4), jnp.float32)
    written = jnp.full((1, 4), 2.5, jnp.float32)
    st1, tbl, _, _ = _run_rw(cfg, [9], [1], written, table)
    np.testing.assert_array_equal(np.asarray(tbl)[9], np.asarray(written)[0])
    assert not np.asarray(st1.dirty).any()


def test_read_after_write_hit_serves_written_line(rng):
    cfg = CacheConfig(num_lines=256, associativity=4)
    table = jnp.asarray(rng.standard_normal((1024, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((1, 4)), jnp.float32)
    wlines = jnp.concatenate([w, jnp.zeros((1, 4))])
    _, _, hits, lines = _run_rw(cfg, [33, 33], [1, 0], wlines, table)
    assert bool(np.asarray(hits)[1])
    np.testing.assert_array_equal(np.asarray(lines)[1], np.asarray(w)[0])


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 600), st.integers(0, 1)),
                min_size=1, max_size=60),
       st.sampled_from(["write_back", "write_through"]),
       st.sampled_from([1, 4]))
def test_property_rw_trace_matches_sequential_oracle(reqs, policy, ways):
    """Flushed table == naive in-order write stream; reads see the latest
    same-address write (read-your-writes through the cache)."""
    cfg = CacheConfig(num_lines=256, associativity=ways,
                      write_policy=policy)
    n = len(reqs)
    lids = np.array([r[0] for r in reqs])
    rw = np.array([r[1] for r in reqs])
    wlines = (np.arange(n, dtype=np.float32)[:, None] + 1.0
              ) * np.ones((1, 2), np.float32)
    table = jnp.zeros((1024, 2), jnp.float32)
    st1, tbl, _, lines = _run_rw(cfg, lids, rw, jnp.asarray(wlines), table)
    _, tbl = flush(st1, tbl)
    ref = np.zeros((1024, 2), np.float32)
    ref_lines = []
    for i in range(n):
        if rw[i]:
            ref[lids[i]] = wlines[i]
            ref_lines.append(wlines[i])
        else:
            ref_lines.append(ref[lids[i]].copy())
    np.testing.assert_allclose(np.asarray(tbl), ref, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(lines), np.stack(ref_lines),
                               rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=80))
def test_property_scan_matches_python_oracle(lids):
    cfg = CacheConfig(num_lines=256, associativity=4)
    table = jnp.zeros((1024, 2))
    _, hits, _ = simulate_trace(init_cache(cfg, 2),
                                jnp.asarray(lids, jnp.int32), table)
    hits_py, _ = hit_rate_oracle(cfg, np.asarray(lids))
    np.testing.assert_array_equal(np.asarray(hits), hits_py)
