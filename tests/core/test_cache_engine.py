"""Cache engine (functional scan LRU) behavioural tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache_engine import (hit_rate_oracle, init_cache,
                                     simulate_trace)
from repro.core.config import CacheConfig


def test_trace_serves_correct_lines(rng):
    cfg = CacheConfig(num_lines=256, associativity=2)
    table = jnp.asarray(rng.standard_normal((1024, 4)), jnp.float32)
    lids = jnp.asarray(rng.integers(0, 1024, 200), jnp.int32)
    st0 = init_cache(cfg, 4)
    _, hits, lines = simulate_trace(st0, lids, table)
    np.testing.assert_allclose(np.asarray(lines), np.asarray(table[lids]))


def test_repeat_access_hits():
    cfg = CacheConfig(num_lines=256, associativity=4)
    table = jnp.ones((512, 4))
    lids = jnp.asarray([7, 7, 7, 7], jnp.int32)
    _, hits, _ = simulate_trace(init_cache(cfg, 4), lids, table)
    np.testing.assert_array_equal(np.asarray(hits), [False, True, True,
                                                     True])


def test_direct_mapped_conflict_misses():
    """ways=1: two lines mapping to the same set always evict each other."""
    cfg = CacheConfig(num_lines=256, associativity=1)
    sets = cfg.num_sets
    table = jnp.ones((4 * sets, 4))
    lids = jnp.asarray([5, 5 + sets, 5, 5 + sets], jnp.int32)
    _, hits, _ = simulate_trace(init_cache(cfg, 4), lids, table)
    assert not np.asarray(hits).any()


def test_higher_associativity_never_hurts_this_workload(rng):
    lids = rng.integers(0, 2048, 2000)
    rates = []
    for ways in (1, 2, 4, 8):
        cfg = CacheConfig(num_lines=1024, associativity=ways)
        _, rate = hit_rate_oracle(cfg, lids)
        rates.append(rate)
    assert rates == sorted(rates) or max(rates) - min(rates) < 0.02


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=80))
def test_property_scan_matches_python_oracle(lids):
    cfg = CacheConfig(num_lines=256, associativity=4)
    table = jnp.zeros((1024, 2))
    _, hits, _ = simulate_trace(init_cache(cfg, 2),
                                jnp.asarray(lids, jnp.int32), table)
    hits_py, _ = hit_rate_oracle(cfg, np.asarray(lids))
    np.testing.assert_array_equal(np.asarray(hits), hits_py)
